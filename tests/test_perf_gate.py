"""CI perf-regression gate: wall-time band, exact memory proxies, parity
bounds, and shape-signature alignment between quick and full runs."""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.perf_gate import gate  # noqa: E402


def _write(d, name, payload):
    (d / name).write_text(json.dumps(payload))


def _dirs(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()
    return base, fresh


def _shape(n, t_s, mem, diff=0.0):
    return {"n": n, "v": 64, "d": 128, "t_fused_s": t_s,
            "fused_peak_intermediate_bytes": mem, "loss_abs_diff": diff}


def test_gate_passes_within_band(tmp_path):
    base, fresh = _dirs(tmp_path)
    _write(base, "BENCH_x.json", {"shapes": [_shape(4096, 0.010, 1024)]})
    _write(fresh, "BENCH_x.json", {"shapes": [_shape(4096, 0.018, 1024)]})
    checked, failures = gate(base, fresh, tolerance=2.0)
    assert checked == ["BENCH_x.json"] and not failures


def test_gate_fails_on_walltime_regression(tmp_path):
    base, fresh = _dirs(tmp_path)
    _write(base, "BENCH_x.json", {"shapes": [_shape(4096, 0.010, 1024)]})
    _write(fresh, "BENCH_x.json", {"shapes": [_shape(4096, 0.025, 1024)]})
    _, failures = gate(base, fresh, tolerance=2.0)
    assert len(failures) == 1 and "t_fused_s" in failures[0]


def test_gate_fails_on_memory_growth(tmp_path):
    base, fresh = _dirs(tmp_path)
    _write(base, "BENCH_x.json", {"shapes": [_shape(4096, 0.010, 1024)]})
    _write(fresh, "BENCH_x.json", {"shapes": [_shape(4096, 0.010, 1025)]})
    _, failures = gate(base, fresh, tolerance=2.0)
    assert len(failures) == 1 and "memory proxy" in failures[0]


def test_gate_fails_on_parity_blowup(tmp_path):
    base, fresh = _dirs(tmp_path)
    _write(base, "BENCH_x.json", {"shapes": [_shape(4096, 0.01, 1024, 0.0)]})
    _write(fresh, "BENCH_x.json", {"shapes": [_shape(4096, 0.01, 1024, 0.5)]})
    _, failures = gate(base, fresh)
    assert len(failures) == 1 and "parity" in failures[0]


def test_gate_aligns_by_shape_signature(tmp_path):
    """A quick fresh run covering a subset of the baseline's shapes gates
    only the overlap — full-only shapes are skipped, reordering is fine."""
    base, fresh = _dirs(tmp_path)
    _write(base, "BENCH_x.json", {"shapes": [_shape(4096, 0.010, 1024),
                                             _shape(65536, 0.500, 4096)]})
    _write(fresh, "BENCH_x.json", {"shapes": [_shape(4096, 0.012, 1024)]})
    checked, failures = gate(base, fresh, tolerance=2.0)
    assert checked and not failures
    _write(fresh, "BENCH_x.json", {"shapes": [_shape(4096, 0.099, 1024)]})
    _, failures = gate(base, fresh, tolerance=2.0)
    assert failures and "n=4096" in failures[0]


def test_gate_fails_on_missing_gated_key(tmp_path):
    """Renaming/removing a gated metric must fail, not silently un-gate."""
    base, fresh = _dirs(tmp_path)
    rec = _shape(4096, 0.010, 1024)
    _write(base, "BENCH_x.json", {"shapes": [rec]})
    renamed = {k: v for k, v in rec.items()
               if k != "fused_peak_intermediate_bytes"}
    renamed["fused_peak_bytes_v2"] = 999999
    _write(fresh, "BENCH_x.json", {"shapes": [renamed]})
    _, failures = gate(base, fresh, tolerance=2.0)
    assert len(failures) == 1 and "missing" in failures[0]


def test_gate_fails_on_missing_gated_container(tmp_path):
    """Renaming a container that HOLDS gated metrics (e.g. the 'shapes'
    list) must fail too — otherwise zero metrics get compared while the
    gate reports OK."""
    base, fresh = _dirs(tmp_path)
    _write(base, "BENCH_x.json", {"shapes": [_shape(4096, 0.010, 1024)]})
    _write(fresh, "BENCH_x.json", {"results": [_shape(4096, 0.010, 1024)]})
    _, failures = gate(base, fresh, tolerance=2.0)
    assert len(failures) == 1 and "missing" in failures[0]


def test_gate_fails_on_missing_fresh_file(tmp_path):
    base, fresh = _dirs(tmp_path)
    _write(base, "BENCH_x.json", {"shapes": []})
    _, failures = gate(base, fresh)
    assert failures and "missing" in failures[0]


def test_gate_ignores_non_bench_files(tmp_path):
    base, fresh = _dirs(tmp_path)
    _write(base, "throughput.json", {"sps_env_s": 1.0})   # not BENCH_*
    checked, failures = gate(base, fresh)
    assert not checked and not failures
