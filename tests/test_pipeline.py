"""Pipelined training runtime (runtime/pipeline_exec.py): static schedule
invariants, executor parity against the fused single-mesh path (the
acceptance criterion: >=2 micro-batches, 2 stages), 1F1B memory bounding,
bubble accounting, TrainerWorker wiring, and disjoint submeshes under a
forced multi-device CPU backend."""
import os
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import RLConfig, RuntimeConfig
from repro.core.train_step import init_train_state
from repro.data.trajectory import dummy_batch
from repro.runtime.pipeline_exec import (Instruction, PipelineExecutor,
                                         PipelineOp, SubmeshLayout,
                                         build_train_schedules,
                                         host_microbatches,
                                         validate_schedules)
from repro.runtime.service import MetricsRegistry
from repro.runtime.step_program import build_train_step_program

CFG = reduced(get_config("deepseek-7b"), layers=2, d_model=64)


def _batch(b=4, seed=0):
    return dummy_batch(b, 4, 12, CFG.action_dim, CFG.vocab_size,
                       CFG.action_vocab_size, seed=seed)


def _max_diff(t1, t2):
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), t1, t2)
    return max(jax.tree.leaves(d))


def _feeds(k, wm=0):
    return (["host:policy:state"]
            + [f"host:policy:micro{m}" for m in range(k)]
            + [f"host:wm:micro{m}" for m in range(wm)])


COLLECTS = ["pipe:policy:state", "pipe:policy:metrics", "pipe:wm:out"]


# ---------------------------------------------------------------------------
# static schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,wm", [(1, 0), (2, 1), (4, 3), (8, 2)])
def test_schedules_validate(k, wm):
    sch = build_train_schedules(k, wm)
    stats = validate_schedules(sch, feeds=_feeds(k, wm), collects=COLLECTS)
    # the 1F1B guarantee: grads fold immediately, never two live
    assert stats["policy"]["peak_micro_grads"] == 1
    # one RECV per feed, schedule length linear in K
    recvs = [i for i in sch["policy"] if i.op == PipelineOp.RECV]
    assert len(recvs) == k + 1
    assert len([i for i in sch["wm"] if i.op == PipelineOp.RUN]) == wm


def test_every_buffer_freed():
    """No leaks: each stream ends with zero live buffers (the validator
    raises otherwise) and FREEs cover every RECV/RUN output."""
    sch = build_train_schedules(3, 2)
    for name, stream in sch.items():
        produced = set()
        freed = set()
        sent = set()
        for ins in stream:
            if ins.op in (PipelineOp.RECV,):
                produced.add(ins.buffer)
            elif ins.op == PipelineOp.RUN:
                produced.update(ins.outputs)
            elif ins.op == PipelineOp.FREE:
                freed.add(ins.buffer)
            elif ins.op == PipelineOp.SEND:
                sent.add(ins.buffer)
        assert produced == freed, (name, produced - freed)


def test_validator_catches_use_after_free():
    bad = {"s": (
        Instruction(PipelineOp.RECV, buffer="x", tag="host:x"),
        Instruction(PipelineOp.FREE, buffer="x"),
        Instruction(PipelineOp.RUN, stage="f", inputs=("x",),
                    outputs=("y",)),
        Instruction(PipelineOp.FREE, buffer="y"),
    )}
    with pytest.raises(ValueError, match="dead"):
        validate_schedules(bad, feeds=["host:x"], collects=[])


def test_validator_catches_leak():
    bad = {"s": (Instruction(PipelineOp.RECV, buffer="x", tag="host:x"),)}
    with pytest.raises(ValueError, match="leak"):
        validate_schedules(bad, feeds=["host:x"], collects=[])


def test_validator_catches_unfed_recv():
    bad = {"s": (
        Instruction(PipelineOp.RECV, buffer="x", tag="nobody:sends"),
        Instruction(PipelineOp.FREE, buffer="x"),
    )}
    with pytest.raises(ValueError, match="never fed"):
        validate_schedules(bad, feeds=["host:x"], collects=[])


def test_validator_catches_unconsumed_send():
    bad = {"s": (
        Instruction(PipelineOp.RECV, buffer="x", tag="host:x"),
        Instruction(PipelineOp.SEND, buffer="x", tag="pipe:orphan"),
        Instruction(PipelineOp.FREE, buffer="x"),
    )}
    with pytest.raises(ValueError, match="never consumed"):
        validate_schedules(bad, feeds=["host:x"], collects=[])


def test_host_microbatches_match_fused_slicing():
    batch = _batch(b=8, seed=5)
    micros = host_microbatches(batch, 4)
    assert len(micros) == 4
    joined = np.concatenate([np.asarray(m.obs_tokens) for m in micros])
    assert np.array_equal(joined, np.asarray(batch.obs_tokens))


# ---------------------------------------------------------------------------
# executor parity — >=2 micro-batches AND 2 concurrent stages
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [2, 4])
def test_executor_parity_two_stages(k):
    """Pipelined round == fused step at fixed seed, with the WM stage
    running concurrently on the second stream."""
    rl = RLConfig(grad_accum=k, fused_loss=True, lr_policy=1e-4,
                  lr_value=1e-3)
    prog = build_train_step_program(CFG, rl)
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    batch = _batch(b=2 * k, seed=3)

    s_ref, m_ref = prog.fused(donate=False)(state, batch)

    wm_calls = []

    def wm_stage(b):
        wm_calls.append(threading.current_thread().name)
        return {"seen": len(b)}

    feed_batches = iter([[{"x": 1}, {"x": 2}], [{"x": 3}]])
    ex = PipelineExecutor(prog, SubmeshLayout.split(jax.devices()))
    ex.set_wm_stage(wm_stage, lambda: next(feed_batches, None), wm_micro=2)
    try:
        s_pipe, m_pipe, wm_out = ex.run_round(state, batch)
    finally:
        ex.close()

    assert _max_diff(s_ref.params, s_pipe.params) < 1e-6
    assert abs(float(m_ref["loss"]) - float(m_pipe["loss"])) < 1e-6
    assert _max_diff(s_ref.opt.mu, s_pipe.opt.mu) < 1e-6
    assert int(s_pipe.version) == 1
    # the second stage really ran, on the wm stream's thread
    assert len(wm_calls) == 2 and all("wm" in t for t in wm_calls)
    assert wm_out == {"seen": 1}


def test_executor_multiple_rounds_match_fused_sequence():
    rl = RLConfig(grad_accum=2, fused_loss=True, lr_policy=1e-4,
                  lr_value=1e-3)
    prog = build_train_step_program(CFG, rl)
    state_a = state_b = init_train_state(CFG, jax.random.PRNGKey(4))
    fused = prog.fused(donate=False)
    ex = PipelineExecutor(prog, SubmeshLayout.split(jax.devices()))
    try:
        for r in range(3):
            batch = _batch(b=4, seed=100 + r)
            state_a, _ = fused(state_a, batch)
            state_b, _, _ = ex.run_round(state_b, batch)
    finally:
        ex.close()
    assert _max_diff(state_a.params, state_b.params) < 1e-6
    assert int(state_b.version) == 3
    assert ex.rounds == 3


def test_free_bounds_live_grads():
    """peak live gradient bytes == ONE micro-batch's grad tree no matter
    how deep the accumulation window is (GPipe/1F1B claim)."""
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    peaks = {}
    for k in (2, 4):
        rl = RLConfig(grad_accum=k, fused_loss=True)
        prog = build_train_step_program(CFG, rl)
        ex = PipelineExecutor(prog, SubmeshLayout.split(jax.devices()))
        try:
            ex.run_round(state, _batch(b=8, seed=1))
        finally:
            ex.close()
        peaks[k] = ex.peak_grad_bytes
    grad_tree_bytes = sum(
        l.nbytes for l in jax.tree.leaves(state.params))
    assert peaks[2] == peaks[4] == grad_tree_bytes


def test_bubble_histogram_recorded():
    rl = RLConfig(grad_accum=2, fused_loss=True)
    prog = build_train_step_program(CFG, rl)
    metrics = MetricsRegistry("t")
    ex = PipelineExecutor(prog, SubmeshLayout.split(jax.devices()),
                          metrics=metrics)
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    try:
        ex.run_round(state, _batch())
        ex.run_round(state, _batch())
    finally:
        ex.close()
    assert set(ex.last_bubble) == {"policy"}    # no WM stage attached
    assert 0.0 <= ex.last_bubble["policy"] <= 1.0
    h = metrics.hist("pipeline_bubble_frac")
    assert h is not None and h["count"] == 2


# ---------------------------------------------------------------------------
# TrainerWorker wiring
# ---------------------------------------------------------------------------

class _ListSource:
    def pop_batch(self, n, timeout=None):
        return []


def _worker(rt, seed=0):
    from repro.runtime.trainer import TrainerWorker
    from repro.runtime.weight_store import VersionedWeightStore
    rl = RLConfig(grad_accum=2, fused_loss=True, lr_policy=1e-4,
                  lr_value=1e-3)
    return TrainerWorker(CFG, rl, rt, _ListSource(),
                         VersionedWeightStore(), batch_episodes=4,
                         seed=seed)


def test_trainer_worker_pipeline_parity():
    """rt.pipeline routes train_on_batch through the executor and the
    resulting state matches the default single-mesh worker exactly."""
    ref = _worker(RuntimeConfig())
    pipe = _worker(RuntimeConfig(pipeline=True))
    assert ref.pipeline is None and pipe.pipeline is not None
    assert [s.name for s in pipe.program.stages] == \
        [s.name for s in ref.program.stages]
    try:
        ref.begin_inline()
        pipe.begin_inline()
        for r in range(2):
            batch = _batch(b=4, seed=50 + r)
            m_ref = ref.train_on_batch(batch)
            m_pipe = pipe.train_on_batch(batch)
            assert abs(m_ref["loss"] - m_pipe["loss"]) < 1e-6
        assert _max_diff(ref.state.params, pipe.state.params) < 1e-6
        assert pipe.steps_done == 2
        assert pipe.pipeline.rounds == 2
        # publishes flowed through the store on both paths
        assert pipe.store.version() == ref.store.version() == 2
        h = pipe.metrics.hist("pipeline_bubble_frac")
        assert h is not None and h["count"] >= 2
    finally:
        ref.stop()
        pipe.stop()


def test_trainer_worker_set_wm_stage_guard():
    ref = _worker(RuntimeConfig())
    try:
        with pytest.raises(RuntimeError, match="rt.pipeline"):
            ref.set_wm_stage(lambda b: None, lambda: None)
    finally:
        ref.stop()


# ---------------------------------------------------------------------------
# disjoint submeshes (forced 2-device CPU backend, own process)
# ---------------------------------------------------------------------------

_DISJOINT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.configs.base import RLConfig
from repro.core.train_step import init_train_state
from repro.data.trajectory import dummy_batch
from repro.runtime.pipeline_exec import PipelineExecutor, SubmeshLayout
from repro.runtime.step_program import build_train_step_program

cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
rl = RLConfig(grad_accum=2, fused_loss=True, lr_policy=1e-4, lr_value=1e-3)
layout = SubmeshLayout.split(jax.devices())
assert layout.disjoint and layout.policy.devices != layout.wm.devices
prog = build_train_step_program(cfg, rl)
state = init_train_state(cfg, jax.random.PRNGKey(0))
batch = dummy_batch(4, 4, 12, cfg.action_dim, cfg.vocab_size,
                    cfg.action_vocab_size, seed=3)
s_ref, m_ref = prog.fused(donate=False)(state, batch)

devices_seen = []
def wm_stage(b):
    arr = jnp.asarray([1.0, 2.0]) + 1
    arr.block_until_ready()
    devices_seen.append(next(iter(arr.devices())))
    return {"ok": 1}

feeds = iter([[{"x": 1}]])
ex = PipelineExecutor(prog, layout)
ex.set_wm_stage(wm_stage, lambda: next(feeds, None), wm_micro=1)
s_pipe, m_pipe, wm_out = ex.run_round(state, batch)
ex.close()

d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
    a.astype(jnp.float32) - b.astype(jnp.float32)))),
    s_ref.params, s_pipe.params)
mx = max(jax.tree.leaves(d))
assert mx < 1e-6, mx
assert abs(float(m_ref["loss"]) - float(m_pipe["loss"])) < 1e-6
# the policy state came back from the POLICY submesh (cross-mesh reshard
# happened), and the WM stage computed on the WM submesh's device
out_dev = next(iter(jax.tree.leaves(s_pipe.params)[0].devices()))
assert out_dev == layout.policy.device, (out_dev, layout.policy.device)
assert devices_seen == [layout.wm.device], devices_seen
print("OK", mx)
"""


def test_disjoint_submesh_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _DISJOINT_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         env=env)
    assert out.returncode == 0, out.stderr
    assert out.stdout.startswith("OK")
