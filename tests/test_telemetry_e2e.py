"""Observability end-to-end acceptance: running the --remote-rollout demo
with --trace-out produces a Chrome-trace-event JSON whose span chains
cross the process boundary — the SAME trace id appears on the child's
rollout-side put, the parent server's apply, and the parent trainer's
pop/collate — and whose policy-lag flow ties a weight publish to the
first action computed with that version.

Spawns a jax-initializing process tree — slow by nature; CI runs it in
the dedicated telemetry-smoke job under a hard SIGKILL timeout.
"""
import json
import os
import pathlib
import subprocess
import sys

import pytest

SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")


def _collect(events, name):
    """trace id -> {pids} over every phase of ``name``."""
    out = {}
    for e in events:
        if e.get("name") == name and e.get("ph") in ("X", "i"):
            t = e.get("args", {}).get("trace")
            if t is not None:
                out.setdefault(t, set()).add(e["pid"])
    return out


@pytest.mark.slow
def test_remote_rollout_trace_joins_across_processes(tmp_path):
    trace_path = tmp_path / "trace.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("REPRO_TRACE", None)       # --trace-out must arm it itself
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--remote-rollout", "1", "--steps", "8",
         "--trace-out", str(trace_path)],
        env=env, capture_output=True, text=True, timeout=280)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "trace:" in proc.stdout

    doc = json.loads(trace_path.read_text())
    # Chrome trace-event container format (loads in Perfetto)
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    events = doc["traceEvents"]
    for e in events:
        assert {"name", "ph", "pid"} <= set(e)
        if e["ph"] != "M":
            assert isinstance(e["ts"], int)
        if e["ph"] == "X":
            assert e["dur"] >= 1

    pids = {e["pid"] for e in events}
    assert len(pids) >= 2, "expected parent + child events in one dump"

    puts = _collect(events, "rollout.put")
    applies = _collect(events, "server.apply")
    collates = _collect(events, "trainer.collate")
    pops = _collect(events, "replay.pop")

    # child put -> parent apply: same trace id, different pids
    cross = [t for t in puts
             if t in applies and puts[t] != applies[t]]
    assert cross, "no put->apply chain crossed a process boundary"

    # the full acceptance chain: put (child) -> apply (parent) ->
    # trainer-side pop/collate (parent) on ONE trace id
    full = [t for t in cross if t in collates or t in pops]
    assert full, "no cross-process trace reached the trainer side"

    # policy-lag flow: publish -> acquire -> first action per version id
    pub = _collect(events, "weights.publish")
    acq = _collect(events, "weights.acquire")
    first = _collect(events, "infer.first_action")
    assert set(pub) & set(acq) & set(first), \
        "no weight version traced publish -> acquire -> first action"
