"""Resilient control plane (ISSUE 6): the TransportServer journal.

The property-style core: ANY prefix of the journal (including a torn
final record, and including a snapshot + log-suffix chain after a
mid-sequence compaction) recovers a state whose channel contents, stream
watermarks, and store version match a reference that never crashed.
Around it: JournaledChannel atomicity semantics, resume/torn-tail
truncation, the stale-SHM sweep, FaultPlan parsing/triggers plus the
import-gated inertness guarantee, and the elastic-supervision state
machine (scale-up, cooldown, drain-then-retire scale-down)."""
import os
import pathlib
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import RLConfig, RuntimeConfig
from repro.runtime.experience import FifoChannel, RingChannel
from repro.runtime.transport import (PutStream, RemoteWorkerSpec,
                                     RestartPolicy, Supervisor,
                                     TransportJournal, TransportServer,
                                     WireClient, recover, sweep_stale_shm)
from repro.runtime.transport.codec import encode_pytree
from repro.runtime.transport.resilience import (JOURNAL_MAGIC,
                                                JournaledChannel,
                                                read_records, shm_name)
from repro.runtime.transport.supervision import (ElasticPolicy,
                                                 SupervisedWorker,
                                                 WorkerEndpoint)
from repro.runtime.weight_store import VersionedWeightStore


def _item(i):
    return {"i": np.int32(i)}


def _ids(items):
    return [int(x["i"]) for x in items]


def _record_offsets(path):
    """Byte offsets of every record boundary in a journal file (the
    positions a crash could truncate to and still leave a valid file)."""
    data = path.read_bytes()
    offsets = [len(JOURNAL_MAGIC)]
    records, torn, valid = read_records(path)
    assert not torn
    off = len(JOURNAL_MAGIC)
    import struct
    while off < valid:
        plen, = struct.unpack_from("<I", data, off)
        off += 8 + plen
        offsets.append(off)
    assert off == valid
    return offsets


# ---------------------------------------------------------------------------
# the property: any committed prefix recovers the reference state
# ---------------------------------------------------------------------------

def _drive_reference(journal, chan, rng, n_ops, state, expected):
    """Apply ``n_ops`` seeded ops through the journaled channel (plus
    direct stream/publish appends), mirroring each op on the plain-python
    reference ``state`` and snapshotting it after every appended record."""
    for _ in range(n_ops):
        op = rng.choice(["put", "put", "put", "pop", "stream", "publish"])
        if op == "put":
            k = rng.randint(1, 4)
            items = [_item(state["next"] + j) for j in range(k)]
            state["next"] += k
            assert chan.put_many(items) == [True] * k
            state["items"].extend(_ids(items))
            del state["items"][:max(0, len(state["items"]) - chan.capacity)]
        elif op == "pop":
            n = rng.randint(1, 3)
            got = chan.pop_batch(n, timeout=0)
            if got is None:
                continue               # nothing journaled, no snapshot
            assert _ids(got) == state["items"][:len(got)]
            del state["items"][:len(got)]
        elif op == "stream":
            state["seq"] += 1
            journal.append("stream", {"chan": "exp", "stream": "s0",
                                      "seq": state["seq"],
                                      "verdicts": [True], "window": 8,
                                      "ack_every": 1})
        else:
            state["version"] += 1
            journal.note_publish({"w": np.float32(state["version"])},
                                 state["version"])
        expected.append({"items": list(state["items"]),
                         "seq": state["seq"],
                         "version": state["version"]})


def _assert_matches(got, want):
    assert _ids(got.channel_items("exp")) == want["items"]
    if want["seq"] >= 0:
        assert got.streams[("exp", "s0")]["last_seq"] == want["seq"]
    else:
        assert ("exp", "s0") not in got.streams
    if want["version"] > 0:
        assert got.store[0] == want["version"]
    else:
        assert got.store is None


def test_any_journal_prefix_recovers_reference_state(tmp_path):
    import random
    rng = random.Random(7)
    d = tmp_path / "j"
    journal = TransportJournal(d, compact_bytes=1 << 30)
    chan = journal.wrap("exp", FifoChannel(8, policy="drop_oldest"))
    state = {"items": [], "next": 0, "seq": -1, "version": 0}
    # expected[k] = reference state after the (k+1)-th NON-META record;
    # the chan_meta record wrap() appended is prefix offset 1
    expected = [{"items": [], "seq": -1, "version": 0}]
    _drive_reference(journal, chan, rng, 60, state, expected)
    journal.close()

    log = d / "log-00000000.bin"
    offsets = _record_offsets(log)
    assert len(offsets) == len(expected) + 1   # +1: the chan_meta record
    raw = log.read_bytes()
    pdir = tmp_path / "prefix"
    pdir.mkdir()
    plog = pdir / "log-00000000.bin"
    for k in range(1, len(offsets)):           # every committed prefix
        plog.write_bytes(raw[:offsets[k]])
        _assert_matches(recover(pdir), expected[k - 1])
    # the full journal equals the live channel the reference never lost
    full = recover(d)
    assert _ids(full.channel_items("exp")) == _ids(chan.peek_all())
    assert not full.torn_tail

    # torn final record: every proper truncation INSIDE the last record
    # recovers exactly the previous committed state, flagged torn
    for cut in (1, 7, offsets[-1] - offsets[-2] - 1):
        plog.write_bytes(raw[:offsets[-2] + cut])
        got = recover(pdir)
        assert got.torn_tail
        _assert_matches(got, expected[-2])


def test_snapshot_plus_log_suffix_prefixes_recover(tmp_path):
    """The same property across a mid-sequence compaction: snapshot +
    any prefix of the post-rotation log recovers the reference."""
    import random
    rng = random.Random(11)
    d = tmp_path / "j"
    journal = TransportJournal(d, compact_bytes=1 << 30)
    chan = journal.wrap("exp", FifoChannel(8, policy="drop_oldest"))
    state = {"items": [], "next": 0, "seq": -1, "version": 0}
    expected = [{"items": [], "seq": -1, "version": 0}]
    _drive_reference(journal, chan, rng, 30, state, expected)
    gen = journal.compact(lambda: [
        ("stream_snap", {"chan": "exp", "stream": "s0",
                         "seq": state["seq"], "acks": {}, "window": 8,
                         "ack_every": 1}, b"")])
    base = dict(expected[-1])                  # state the snapshot holds
    expected = [base]
    _drive_reference(journal, chan, rng, 30, state, expected)
    journal.close()

    assert not (d / "log-00000000.bin").exists()   # old chain deleted
    log = d / f"log-{gen:08d}.bin"
    offsets = _record_offsets(log)
    raw = log.read_bytes()
    pdir = tmp_path / "prefix"
    pdir.mkdir()
    (pdir / f"snap-{gen:08d}.bin").write_bytes(
        (d / f"snap-{gen:08d}.bin").read_bytes())
    plog = pdir / f"log-{gen:08d}.bin"
    for k in range(len(offsets)):
        plog.write_bytes(raw[:offsets[k]])
        got = recover(pdir)
        assert got.base_gen == gen
        _assert_matches(got, expected[min(k, len(expected) - 1)])


def test_interrupted_snapshot_is_skipped(tmp_path):
    """A marker-less (or torn) snapshot is an interrupted compaction:
    recovery must fall back to the previous chain, which compaction only
    deletes AFTER the snapshot rename."""
    d = tmp_path / "j"
    journal = TransportJournal(d)
    chan = journal.wrap("exp", FifoChannel(16))
    chan.put_many([_item(i) for i in range(5)])
    journal.close()
    # forge an interrupted snapshot at a newer generation: valid records
    # but no snap_end marker (and a torn variant)
    from repro.runtime.transport.resilience import _record_bytes
    bogus = JOURNAL_MAGIC + _record_bytes(
        "put", {"chan": "exp", "count": 1}, encode_pytree([_item(99)]))
    (d / "snap-00000007.bin").write_bytes(bogus)
    got = recover(d)
    assert got.base_gen == 0
    assert _ids(got.channel_items("exp")) == list(range(5))
    (d / "snap-00000008.bin").write_bytes(bogus[:len(bogus) - 3])  # torn
    got = recover(d)
    assert _ids(got.channel_items("exp")) == list(range(5))


# ---------------------------------------------------------------------------
# journal lifecycle: resume, torn-tail truncation, compaction hygiene
# ---------------------------------------------------------------------------

def test_fresh_journal_refuses_nonempty_dir(tmp_path):
    d = tmp_path / "j"
    TransportJournal(d).close()
    with pytest.raises(ValueError, match="resume"):
        TransportJournal(d)
    TransportJournal(d, resume=True).close()   # the sanctioned path


def test_resume_truncates_torn_tail_then_continues(tmp_path):
    d = tmp_path / "j"
    journal = TransportJournal(d)
    chan = journal.wrap("exp", FifoChannel(64))
    chan.put_many([_item(i) for i in range(4)])
    journal.close()
    log = d / "log-00000000.bin"
    with log.open("ab") as f:                  # a half-written record
        f.write(b"\x40\x00\x00\x00\xde\xad")
    j2 = TransportJournal(d, resume=True)
    assert j2.torn_truncated == 1
    chan2 = j2.wrap("exp", FifoChannel(64))
    chan2.put_many([_item(i) for i in range(4, 7)])
    j2.close()
    got = recover(d)
    assert _ids(got.channel_items("exp")) == list(range(7))
    assert not got.torn_tail                   # the tear was healed


def test_compaction_bounds_the_chain_and_keeps_newest_publish(tmp_path):
    d = tmp_path / "j"
    journal = TransportJournal(d, compact_bytes=256)
    chan = journal.wrap("exp", FifoChannel(8))
    journal.note_publish({"w": np.arange(4, dtype=np.float32)}, 1)
    journal.note_publish({"w": np.arange(4, dtype=np.float32) * 2}, 2)
    for i in range(20):
        chan.put_many([_item(i)])
        if journal.should_compact():
            journal.compact()
    journal.close()
    files = sorted(p.name for p in d.iterdir())
    gens = {int(n.split("-")[1].split(".")[0]) for n in files}
    assert len(gens) <= 2, f"old generations must be deleted: {files}"
    got = recover(d)
    assert _ids(got.channel_items("exp")) == list(range(12, 20))
    params, version = got.store_params()
    assert version == 2
    np.testing.assert_array_equal(params["w"],
                                  np.arange(4, dtype=np.float32) * 2)


# ---------------------------------------------------------------------------
# JournaledChannel semantics
# ---------------------------------------------------------------------------

def test_journaled_channel_rejects_block_policy(tmp_path):
    journal = TransportJournal(tmp_path / "j")
    with pytest.raises(ValueError, match="block"):
        journal.wrap("exp", FifoChannel(4, policy="block"))
    with pytest.raises(TypeError, match="peek_all"):
        journal.wrap("ring", RingChannel(4))   # no non-destructive capture
    journal.close()


def test_journaled_channel_journals_only_accepted_items(tmp_path):
    """drop_newest rejections never enter the journal, even when the
    caller hands over a pre-encoded blob containing them."""
    d = tmp_path / "j"
    journal = TransportJournal(d)
    chan = journal.wrap("exp", FifoChannel(2, policy="drop_newest"))
    items = [_item(i) for i in range(4)]
    verdicts = chan.put_many(items, encoded=encode_pytree(items))
    assert verdicts == [True, True, False, False]
    journal.close()
    assert _ids(recover(d).channel_items("exp")) == [0, 1]


def test_journaled_channel_reuses_wire_encoding_when_all_accepted(tmp_path):
    """The streaming hot path never re-encodes: when every item is
    accepted the caller's blob is journaled VERBATIM (observable by
    handing over a marker blob and recovering it)."""
    d = tmp_path / "j"
    journal = TransportJournal(d)
    chan = journal.wrap("exp", FifoChannel(64))
    marker = encode_pytree([_item(999)])
    assert chan.put_many_encoded([_item(0)], marker) == [True]
    journal.close()
    assert _ids(recover(d).channel_items("exp")) == [999]


def test_journaled_channel_pops_and_drain_are_journaled(tmp_path):
    d = tmp_path / "j"
    journal = TransportJournal(d)
    chan = journal.wrap("exp", FifoChannel(64))
    chan.put_many([_item(i) for i in range(6)])
    assert _ids(chan.pop_batch(2, timeout=0)) == [0, 1]
    assert _ids(chan.pop_many(3, timeout=0)) == [2, 3, 4]
    journal.close()
    assert _ids(recover(d).channel_items("exp")) == [5]
    assert len(chan) == 1
    assert chan.stats()["journaled"] == 1.0


def test_journaled_channel_blocking_pop_wakes_on_put(tmp_path):
    journal = TransportJournal(tmp_path / "j")
    chan = journal.wrap("exp", FifoChannel(64))
    t0 = time.monotonic()
    assert chan.pop_batch(1, timeout=0.05) is None
    assert time.monotonic() - t0 >= 0.04
    got = []
    t = threading.Thread(
        target=lambda: got.append(chan.pop_batch(1, timeout=5.0)))
    t.start()
    time.sleep(0.05)
    chan.put(_item(42))
    t.join(timeout=5.0)
    assert got and _ids(got[0]) == [42]
    journal.close()


def test_restore_refills_without_journaling(tmp_path):
    d = tmp_path / "j"
    journal = TransportJournal(d)
    chan = journal.wrap("exp", FifoChannel(64))
    assert chan.restore([_item(i) for i in range(3)]) == 3
    assert len(chan) == 3
    journal.close()
    # the items came FROM the chain: replay must not double-count them
    assert recover(d).channel_items("exp") == []


# ---------------------------------------------------------------------------
# server integration: resume_from_journal over the real wire
# ---------------------------------------------------------------------------

def _journaled_server(d, resume=False):
    journal = TransportJournal(d, resume=resume)
    store = VersionedWeightStore()
    journal.attach_store(store)
    chan = journal.wrap("exp", FifoChannel(4096))
    srv = TransportServer(journal=journal)
    srv.add_channel("exp", chan)
    srv.set_store(store)
    return srv, chan, store


def test_server_resume_restores_channel_streams_and_store(tmp_path):
    d = tmp_path / "j"
    srv, chan, store = _journaled_server(d)
    srv.start()
    s = PutStream(srv.address, "exp", window=4, stream_id="t1")
    for base in range(0, 20, 4):
        s.put_many([_item(base + j) for j in range(4)])
    assert s.flush(10.0)
    s.close()
    store.publish({"w": np.arange(6, dtype=np.float32)}, 3)
    srv.stop()
    srv.join()

    srv2, chan2, store2 = _journaled_server(d, resume=True)
    state = srv2.resume_from_journal()
    assert len(chan2) == 20
    assert _ids(chan2.peek_all()) == list(range(20))
    assert store2.version() == 3
    got = store2.acquire(newer_than=-1, timeout=1.0)
    np.testing.assert_array_equal(got[0]["w"],
                                  np.arange(6, dtype=np.float32))
    assert state.streams[("exp", "t1")]["last_seq"] == 4  # seqs 0..4
    srv2.start()
    # the replacement re-acks a replayed frame WITHOUT re-applying it
    c = WireClient(srv2.address)
    resp, _ = c.request({"m": "stream.open", "chan": "exp",
                         "stream": "t1", "window": 4})
    assert resp["last_seq"] == 4
    resp, _ = c.request({"m": "chan.put_stream", "chan": "exp",
                         "stream": "t1", "seq": 4},
                        encode_pytree([_item(16 + j) for j in range(4)]))
    assert resp.get("dup") is True
    assert len(chan2) == 20                    # nothing re-applied
    assert srv2.metrics.counter("stream_dup_frames") >= 1
    resp, _ = c.request({"m": "server.stats"})
    assert resp["stats"]["journal_recovered_items"] == 20.0
    assert resp["stats"]["journal_recovered_streams"] == 1.0
    c.close()
    srv2.stop()
    srv2.join()


# ---------------------------------------------------------------------------
# SHM hygiene: names carry the creator pid, the sweep only touches the dead
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not pathlib.Path("/dev/shm").is_dir(),
                    reason="needs a /dev/shm tmpfs")
def test_sweep_stale_shm_unlinks_only_dead_creators(tmp_path):
    base = pathlib.Path("/dev/shm")
    live = base / shm_name()                   # our (live) pid
    assert live.name.startswith(f"acrl{os.getpid():x}x")
    proc = subprocess.run([sys.executable, "-c", "import os;print(os.getpid())"],
                          capture_output=True, text=True, check=True)
    dead_pid = int(proc.stdout)
    dead = base / f"acrl{dead_pid:x}xdeadbeef"
    mangled = base / "acrlnotapid"             # unparsable: never touched
    for p in (live, dead, mangled):
        p.write_bytes(b"x")
    try:
        assert sweep_stale_shm() >= 1
        assert not dead.exists(), "dead creator's segment must be swept"
        assert live.exists(), "live creator's segment must survive"
        assert mangled.exists(), "unparsable names must be left alone"
    finally:
        for p in (live, dead, mangled):
            if p.exists():
                p.unlink()


# ---------------------------------------------------------------------------
# FaultPlan: grammar, triggers, determinism, and import-gated inertness
# ---------------------------------------------------------------------------

def test_fault_plan_grammar_and_triggers():
    from repro.runtime.transport import faults
    plan = faults.FaultPlan.from_spec(
        "reset@server.frame:nth=3;delay@x:every=2,ms=1")
    for hit in range(1, 3):
        plan.hit("server.frame")               # hits 1-2: no fire
    with pytest.raises(faults.InjectedReset):
        plan.hit("server.frame")               # hit 3 fires, exactly once
    plan.hit("server.frame")
    t0 = time.monotonic()
    for _ in range(4):
        plan.hit("x")                          # every=2: fires twice
    assert time.monotonic() - t0 >= 0.002
    snap = plan.snapshot()
    assert snap["server.frame"] == {"hits": 4, "fired": 1}
    assert snap["x"] == {"hits": 4, "fired": 2}
    assert isinstance(faults.InjectedReset(""), ConnectionResetError)
    from repro.runtime.transport.ring import RingError
    assert isinstance(faults.InjectedTorn(""), RingError)
    for bad in ("boom@p", "reset", "reset@p:nth"):
        with pytest.raises(ValueError):
            faults.FaultPlan.from_spec(bad)


def test_fault_plan_prob_is_deterministic_per_seed():
    from repro.runtime.transport import faults

    def decisions(seed):
        plan = faults.FaultPlan.from_spec(f"delay@p:prob=0.5,ms=0,seed={seed}")
        rule = plan._rules["p"][0]
        return [rule.should_fire(h) for h in range(1, 33)]

    assert decisions(1) == decisions(1)        # same spec, same run
    assert decisions(1) != decisions(2)        # the stream is per-seed
    assert any(decisions(1)) and not all(decisions(1))


def test_fault_injection_drives_the_client_redial(monkeypatch, tmp_path):
    """Arm a reset at the server's frame point (via the module seam the
    env gate normally populates): the connection dies mid-run and the
    client's reconnect budget absorbs it — no duplicate applies, because
    the reset fires BEFORE dispatch."""
    from repro.runtime.transport import faults
    from repro.runtime.transport import server as server_mod
    monkeypatch.setenv(faults.ENV_VAR, "reset@server.frame:nth=3")
    faults.reset_plan()
    monkeypatch.setattr(server_mod, "_fault", faults.fault_point)
    try:
        srv = TransportServer()
        local = FifoChannel(256)
        srv.add_channel("exp", local)
        srv.start()
        from repro.runtime.transport import SocketChannel
        chan = SocketChannel(srv.address, "exp", reconnect_attempts=10,
                             reconnect_backoff_s=0.01)
        for i in range(6):
            assert chan.put(_item(i))
        assert _ids(local.drain()) == list(range(6))
        assert chan._client.reconnects >= 1
        chan.close()
        srv.stop()
        srv.join()
    finally:
        faults.reset_plan()


def test_faults_module_inert_unless_env_gated():
    """The acceptance invariant: with REPRO_FAULTS unset the faults
    module is NEVER imported by the hot paths; with it set, it is."""
    prog = ("import sys;"
            "import repro.runtime.transport.server;"
            "import repro.runtime.transport.channel;"
            "import repro.runtime.transport.ring;"
            "mod='repro.runtime.transport.faults';"
            "assert (mod in sys.modules) == (%r), sys.modules.keys()")
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    for gated in (False, True):
        env = {k: v for k, v in os.environ.items() if k != "REPRO_FAULTS"}
        env["PYTHONPATH"] = src
        if gated:
            env["REPRO_FAULTS"] = "delay@never:nth=999999"
        proc = subprocess.run([sys.executable, "-c", prog % gated],
                              env=env, capture_output=True, text=True)
        assert proc.returncode == 0, proc.stderr


# ---------------------------------------------------------------------------
# elastic supervision: scale-up / cooldown / drain-then-retire scale-down
# ---------------------------------------------------------------------------

class StubServer:
    def __init__(self):
        self.sinks = {}
        self.hello = None

    def register_worker_sink(self, name, host):
        self.sinks[name] = host

    def set_hello_handler(self, fn):
        self.hello = fn


class FakeEndpoint(WorkerEndpoint):
    mode = "spawn"

    def __init__(self):
        self._failure = None
        self.launches = 0

    def launch(self, spec):
        self.launches += 1
        self._failure = None

    def failure(self):
        return self._failure

    def die(self, reason="exited"):
        self._failure = reason


def _spec(name):
    return RemoteWorkerSpec(name=name,
                            cfg=reduced(get_config("deepseek-7b")),
                            rl=RLConfig(), rt=RuntimeConfig(),
                            address=("127.0.0.1", 1))


class ElasticSupervisor(Supervisor):
    """Supervisor with the endpoint seam faked (no real processes)."""

    def _elastic_add(self, spec):
        slot = SupervisedWorker(spec, FakeEndpoint(), self.server)
        slot.start()
        self.slots.append(slot)
        return slot


def test_elastic_policy_validation():
    ElasticPolicy(min_workers=0, max_workers=0)    # empty fleet is legal
    with pytest.raises(ValueError):
        ElasticPolicy(min_workers=3, max_workers=1)
    with pytest.raises(ValueError):
        ElasticPolicy(scale_up_depth=0.9, scale_down_depth=0.5)


def test_elastic_scale_up_cooldown_and_cap():
    signals = {"depth_frac": 0.0}
    registered = []
    sup = ElasticSupervisor(StubServer(), RestartPolicy())
    sup.enable_elastic(ElasticPolicy(min_workers=0, max_workers=2,
                                     interval_s=1.0),
                       lambda seq: _spec(f"elastic-{seq}"),
                       lambda: signals, register=registered.append)
    now = 100.0
    sup._elastic_step(now)
    assert len(sup.slots) == 1 and sup.slots[0].elastic
    assert sup.slots[0].phase == "up"
    assert registered == [sup.slots[0]]
    sup._elastic_step(now + 0.5)               # inside the cooldown
    assert len(sup.slots) == 1
    sup._elastic_step(now + 1.5)
    assert len(sup.slots) == 2
    sup._elastic_step(now + 3.0)               # at max_workers: hold
    assert len(sup.slots) == 2
    assert sup.metrics.counter("scale_ups") == 2


def test_elastic_scale_down_drains_newest_then_retires():
    signals = {"depth_frac": 0.0}
    sup = ElasticSupervisor(StubServer(), RestartPolicy())
    sup.enable_elastic(ElasticPolicy(min_workers=0, max_workers=2,
                                     interval_s=1.0, drain_timeout_s=30.0),
                       lambda seq: _spec(f"elastic-{seq}"),
                       lambda: signals)
    now = 100.0
    sup._elastic_step(now)
    sup._elastic_step(now + 2.0)
    first, second = sup.slots
    signals["depth_frac"] = 1.0                # trainer saturated
    sup._elastic_step(now + 4.0)
    assert second.phase == "draining" and second._stop_remote  # LIFO
    assert first.phase == "up"
    sup._elastic_step(now + 6.0)               # one transition at a time
    assert first.phase == "up"
    sup._drain_step(second, now + 7.0)         # still flushing: keep it
    assert second.phase == "draining"
    second.endpoint.die()                      # worker exited after close()
    sup._drain_step(second, now + 8.0)
    assert second.phase == "done"
    assert second.error is None, "a drained slot is NOT a failure"
    assert sup.metrics.counter("drains_completed") == 1
    sup._elastic_step(now + 10.0)              # now the next one may drain
    assert first.phase == "draining"


def test_elastic_staleness_cap_gates_scale_up():
    signals = {"depth_frac": 0.0, "staleness": 5.0}
    sup = ElasticSupervisor(StubServer(), RestartPolicy())
    sup.enable_elastic(ElasticPolicy(min_workers=0, max_workers=4,
                                     interval_s=0.0, staleness_cap=2.0),
                       lambda seq: _spec(f"elastic-{seq}"),
                       lambda: signals)
    sup._elastic_step(100.0)
    assert sup.slots == [], "off-policy lag past the cap must gate scale-up"
    signals["staleness"] = 1.0
    sup._elastic_step(101.0)
    assert len(sup.slots) == 1


def test_elastic_flaky_signal_source_never_kills_supervision():
    sup = ElasticSupervisor(StubServer(), RestartPolicy())
    sup.enable_elastic(ElasticPolicy(min_workers=0, max_workers=2,
                                     interval_s=0.0),
                       lambda seq: _spec(f"elastic-{seq}"),
                       lambda: 1 / 0)
    sup._elastic_step(100.0)                   # swallows, scales nothing
    assert sup.slots == []
    with pytest.raises(ValueError):
        sup.enable_elastic(ElasticPolicy(), lambda s: None, lambda: {},
                           mode="teleport")


def test_connected_liveness_window_is_configurable():
    sup = Supervisor(StubServer(), RestartPolicy())
    spec = _spec("w0")
    assert spec.heartbeat_s == 0.25
    slot = sup.add_connected(spec, liveness_heartbeats=4.0,
                             liveness_floor_s=0.5)
    assert slot.endpoint.liveness_timeout_s == pytest.approx(1.0)
    slot = sup.add_connected(spec, liveness_heartbeats=1.0,
                             liveness_floor_s=2.0)
    assert slot.endpoint.liveness_timeout_s == pytest.approx(2.0)  # floored
    slot = sup.add_connected(spec, liveness_timeout_s=7.5)
    assert slot.endpoint.liveness_timeout_s == pytest.approx(7.5)  # explicit
