"""End-to-end behaviour tests for the paper's system: the env suite, the
train-step machinery on trajectory batches, roofline parsing, and the
value-recomputation equivalence (App. C.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import RLConfig
from repro.core.train_step import (_score_batch, init_train_state,
                                   make_train_step)
from repro.data.trajectory import dummy_batch
from repro.envs.toy_manipulation import SUITES, ManipulationEnv


# ---------------------------------------------------------------------------
# environment suite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("suite", SUITES)
def test_env_oracle_solves(suite):
    """The scripted expert must solve every suite (imitation source)."""
    succ = 0
    for task in range(5):
        env = ManipulationEnv(suite=suite, task_id=task,
                              max_steps=40 if suite == "long" else 25,
                              seed=task)
        obs, done = env.reset(task), False
        while not done:
            obs, r, done, info = env.step(env.oracle_action())
        succ += int(info["success"])
    assert succ >= 4, f"{suite}: oracle solved only {succ}/5"


def test_env_observation_contract():
    env = ManipulationEnv(suite="spatial")
    obs = env.reset(0)
    assert obs["tokens"].shape == (12,)
    assert obs["frame"].shape == (192,)
    assert 0.0 <= obs["frame"].min() and obs["frame"].max() <= 1.0


def test_env_truncation_vs_termination():
    env = ManipulationEnv(suite="spatial", max_steps=3)
    env.reset(0)
    done, info = False, {}
    while not done:
        _, _, done, info = env.step(np.zeros(7, np.int32))
    assert info["truncated"] and not info["success"]


def test_env_latency_injection():
    import time
    env = ManipulationEnv(suite="spatial", latency=lambda: 0.01)
    env.reset(0)
    t0 = time.monotonic()
    env.step(np.zeros(7, np.int32))
    assert time.monotonic() - t0 >= 0.01


# ---------------------------------------------------------------------------
# trainer machinery on trajectory batches
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    return reduced(get_config("internlm2-1.8b"), layers=2, d_model=64)


def test_value_recompute_equals_forced_reinference(tiny):
    """App. C.1 equivalence: within a frozen-parameter window, fused GAE on
    training-forward values == GAE on a separate re-inference pass."""
    from repro.core import gae
    cfg = tiny
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = dummy_batch(4, 3, 8, cfg.action_dim, cfg.vocab_size,
                        cfg.action_vocab_size)
    rl = RLConfig()
    _, v1, _ = _score_batch(cfg, state.params, batch, remat=False)
    _, v2, _ = _score_batch(cfg, state.params, batch, remat=False)
    a1, _ = gae.jit_gae_from_forward(v1, batch.rewards, batch.dones,
                                     rl.discount, rl.gae_lambda)
    a2, _ = gae.jit_gae_from_forward(v2, batch.rewards, batch.dones,
                                     rl.discount, rl.gae_lambda)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_ppo_and_gipo_modes_run(tiny):
    cfg = tiny
    for algo in ("gipo", "ppo"):
        rl = RLConfig(algo=algo, grad_accum=1)
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        step = make_train_step(cfg, rl, donate=False)
        batch = dummy_batch(2, 3, 8, cfg.action_dim, cfg.vocab_size,
                            cfg.action_vocab_size)
        _, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))


def test_adv_norm_state_advances(tiny):
    cfg = tiny
    rl = RLConfig(grad_accum=1)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = make_train_step(cfg, rl, donate=False)
    batch = dummy_batch(2, 3, 8, cfg.action_dim, cfg.vocab_size,
                        cfg.action_vocab_size)
    s1, _ = step(state, batch)
    s2, _ = step(s1, batch)
    assert float(s2.adv_norm.count) > float(s1.adv_norm.count) > 0
    assert int(s2.version) == 2


def test_value_recompute_off_uses_stale_values(tiny):
    """The Fig.-7 ablation switch actually changes the advantages."""
    cfg = tiny
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = dummy_batch(2, 3, 8, cfg.action_dim, cfg.vocab_size,
                        cfg.action_vocab_size)
    outs = {}
    for flag in (True, False):
        rl = RLConfig(grad_accum=1, value_recompute=flag)
        step = make_train_step(cfg, rl, donate=False)
        _, metrics = step(state, batch)
        outs[flag] = float(metrics["pg_loss"])
    assert outs[True] != outs[False]


# ---------------------------------------------------------------------------
# roofline machinery
# ---------------------------------------------------------------------------

def test_collective_parser():
    from repro.roofline.analysis import collective_bytes
    hlo = """
  %ag = bf16[16,512]{1,0} all-gather(bf16[1,512]{1,0} %x), dims={0}
  %ar.1 = f32[3]{0} all-reduce(f32[3]{0} %y), to_apply=%add
  %start = (f32[8]{0}, f32[8]{0}) all-reduce-start(f32[8]{0} %z)
  %done = f32[8]{0} all-reduce-done((f32[8]{0}) %start)
  %a2a = f32[4,4]{1,0} all-to-all(f32[4,4]{1,0} %w), dimensions={0}
  %cp = u32[2]{0} collective-permute(u32[2]{0} %v)
"""
    got = collective_bytes(hlo)
    counts = got.pop("_counts")
    assert got["all-gather"] == 16 * 512 * 2
    assert got["all-reduce"] == 3 * 4 + 2 * 8 * 4      # plain + start tuple
    assert got["all-to-all"] == 16 * 4
    assert got["collective-permute"] == 2 * 4
    assert counts["all-reduce"] == 2                   # done NOT re-counted


def test_model_flops_formulas():
    from repro.configs.base import ShapeConfig
    from repro.roofline.analysis import model_flops
    dense = get_config("deepseek-7b")
    moe = get_config("dbrx-132b")
    train = ShapeConfig("train_4k", 4096, 256, "train")
    decode = ShapeConfig("decode_32k", 32768, 128, "decode")
    assert model_flops(dense, train) == pytest.approx(
        6.0 * dense.param_count() * 256 * 4096, rel=1e-6)
    # MoE counts ACTIVE params only
    assert model_flops(moe, train) < 6.0 * moe.param_count() * 256 * 4096
    assert model_flops(dense, decode) == pytest.approx(
        2.0 * dense.param_count() * 128, rel=1e-6)


def test_layer_delta_combiner():
    from repro.roofline.analysis import combine_layer_delta
    t1 = {"flops": 100.0, "bytes": 10.0,
          "coll": {"all-reduce": 4.0}, "counts": {"all-reduce": 2}}
    t2 = {"flops": 160.0, "bytes": 14.0,
          "coll": {"all-reduce": 6.0}, "counts": {"all-reduce": 3}}
    out = combine_layer_delta(t1, t2, 10)
    assert out["flops"] == pytest.approx(100 + 9 * 60)
    assert out["coll"]["all-reduce"] == pytest.approx(4 + 9 * 2)
    assert out["counts"]["all-reduce"] == 11


def test_param_count_sanity():
    """Analytic parameter counts land near the nominal sizes the names
    promise. starcoder2/granite use 2-matrix GELU MLPs upstream; this
    framework unifies every dense family on SwiGLU (3 matrices), so those
    two run ~40% heavier than their names — sanity bound is 2x."""
    expect = {"deepseek-7b": 7e9, "internlm2-1.8b": 1.8e9,
              "starcoder2-15b": 15e9, "dbrx-132b": 132e9,
              "mamba2-2.7b": 2.7e9, "granite-20b": 20e9,
              "zamba2-1.2b": 1.2e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.5 * n < got < 2.0 * n, f"{arch}: {got:.2e} vs {n:.2e}"
