"""End-to-end remote-rollout acceptance (ISSUE 3): an AcceRLSystem with a
rollout worker in a REAL spawned subprocess (SocketChannel segments +
WeightStoreTransport weights) trains to its step budget, emits the same
metric schema as the in-process run with the remote worker's snapshot
under ``metrics()["services"]``, and a SIGKILLed worker is contained as a
failed service instead of a hang.

These spawn jax-initializing subprocesses — slow by nature; CI runs them
in a dedicated multiprocess smoke job with a hard timeout."""
import os
import signal
import threading
import time

import pytest

from repro.configs import get_config, reduced
from repro.configs.base import RLConfig, RuntimeConfig, TransportConfig


def _system(*, remote_workers=1, local_workers=1, kind="socket", seed=0,
            put_window=0):
    from repro.runtime import AcceRLSystem
    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    rl = RLConfig(grad_accum=1, lr_policy=1e-4, lr_value=1e-3)
    rt = RuntimeConfig(
        num_rollout_workers=local_workers, inference_batch=4,
        transport=TransportConfig(remote_rollout_workers=remote_workers,
                                  kind=kind, put_window=put_window))
    return AcceRLSystem(cfg, rl, rt, suite="spatial", segment_horizon=4,
                        max_episode_steps=8, batch_episodes=4, seed=seed)


@pytest.mark.slow
def test_remote_rollout_e2e_schema_and_snapshot():
    """Acceptance: train N steps with a spawned rollout worker; the metric
    schema equals the in-process run's and the remote snapshot rides along."""
    m_local = _system(remote_workers=0, seed=1).run_async(
        train_steps=2, wall_timeout_s=240.0)
    # remote-only rollout: the trainer can reach its budget ONLY through
    # the wire, so remote contribution is guaranteed rather than racing
    # the child's startup against a local worker on a slow machine
    sys_ = _system(remote_workers=1, local_workers=0, seed=0)
    m = sys_.run_async(train_steps=2, wall_timeout_s=240.0)

    assert m["train_steps"] >= 2 and m["env_steps"] > 0
    # same top-level schema as the in-process run — topology is invisible
    assert set(m) == set(m_local)
    # the remote worker's snapshot is part of the parent's service report
    assert "remote-rollout-0" in m["services"]
    remote = m["services"]["remote-rollout-0"]
    assert remote["counters"].get("env_steps", 0) > 0
    assert remote["counters"].get("segments", 0) > 0
    assert remote["counters"].get("weight_swaps", 0) > 0  # pulled weights
    # ... and contributes to the aggregates like a local worker would
    host = sys_.remote_hosts[0]
    assert host.env_steps > 0 and host.reports_seen > 0
    assert m["env_steps"] >= host.env_steps
    assert {"inference", "rollout-0"} <= set(host.remote_services)
    # clean cooperative shutdown: everything stopped, nothing failed
    health = sys_.health()
    assert all(h["state"] == "stopped" for h in health.values()), health
    # the child process is really gone
    assert not host.process.is_alive()


@pytest.mark.slow
def test_remote_worker_kill_is_contained():
    """Acceptance: SIGKILL the worker mid-run — the run returns (no hang)
    and the host surfaces as a failed service with the exit code."""
    sys_ = _system(remote_workers=1, local_workers=1, seed=2)
    host = sys_.remote_hosts[0]

    def killer():
        deadline = time.monotonic() + 120.0
        while time.monotonic() < deadline:
            # wait until the child demonstrably produced data, then murder it
            if host.metrics.counter("env_steps") > 0:
                os.kill(host.process.pid, signal.SIGKILL)
                return
            time.sleep(0.05)

    t = threading.Thread(target=killer, daemon=True)
    t.start()
    t0 = time.monotonic()
    m = sys_.run_async(train_steps=1_000_000, wall_timeout_s=180.0)
    wall = time.monotonic() - t0
    t.join(timeout=5.0)

    assert wall < 150.0, "kill was not contained — run hit the wall timeout"
    health = sys_.health()
    assert health["remote-rollout-0"]["state"] == "failed"
    assert "died" in health["remote-rollout-0"]["error"]
    # the rest of the system was stopped in an orderly way, and the
    # metric schema survived the crash
    assert health["trainer"]["state"] == "stopped"
    assert "services" in m and "remote-rollout-0" in m["services"]


@pytest.mark.slow
def test_remote_rollout_e2e_shm_kind():
    """The SHM data plane drives the same e2e loop (weights above the
    threshold travel via shared memory)."""
    from repro.runtime.transport.channel import shared_memory
    if shared_memory is None:
        pytest.skip("multiprocessing.shared_memory unavailable")
    sys_ = _system(remote_workers=1, local_workers=0, kind="shm", seed=3)
    m = sys_.run_async(train_steps=1, wall_timeout_s=240.0)
    assert m["train_steps"] >= 1
    remote = m["services"]["remote-rollout-0"]
    assert remote["counters"].get("env_steps", 0) > 0
    assert all(h["state"] == "stopped" for h in sys_.health().values())


@pytest.mark.slow
def test_remote_rollout_e2e_streaming_ring_kind():
    """Streaming smoke (ISSUE 5): the full async system trains with the
    remote worker flushing through the pipelined put stream into
    persistent SHM rings — zero per-message segment churn on the server,
    stream frames actually carried the segments, and shutdown leaves
    nothing failed."""
    from repro.runtime.transport.channel import shared_memory
    if shared_memory is None:
        pytest.skip("multiprocessing.shared_memory unavailable")
    sys_ = _system(remote_workers=1, local_workers=0, kind="ring", seed=4,
                   put_window=16)
    m = sys_.run_async(train_steps=2, wall_timeout_s=240.0)
    assert m["train_steps"] >= 2
    remote = m["services"]["remote-rollout-0"]
    assert remote["counters"].get("env_steps", 0) > 0
    assert remote["counters"].get("segments", 0) > 0
    server = sys_.transport_server.metrics
    # the segments crossed through the STREAM + RING data plane ...
    assert server.counter("stream_items") > 0
    assert server.counter("ring_records_in") > 0
    # ... with no per-message segment churn on the experience path (the
    # weight wire may legitimately create reply segments)
    assert server.counter("shm_segments_attached") == 0
    assert all(h["state"] == "stopped" for h in sys_.health().values())
