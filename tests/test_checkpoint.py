"""Checkpoint subsystem: save/restore round-trip, atomicity, pruning,
latest-step resolution, and dtype-preserving restore into templates."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.train_step import init_train_state
from repro.data import checkpoint


@pytest.fixture()
def state():
    cfg = reduced(get_config("internlm2-1.8b"), layers=2, d_model=64)
    return init_train_state(cfg, jax.random.PRNGKey(0))


def test_roundtrip(tmp_path, state):
    checkpoint.save(tmp_path, 7, state)
    restored = checkpoint.restore(tmp_path, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_latest_and_prune(tmp_path, state):
    for step in (1, 5, 3, 9, 12):
        checkpoint.save(tmp_path, step, state, keep=3)
    assert checkpoint.latest_step(tmp_path) == 12
    kept = sorted(pathlib.Path(tmp_path).glob("ckpt_*.npz"))
    assert len(kept) == 3
    restored = checkpoint.restore(tmp_path, state, step=9)
    assert int(restored.opt.step) == int(state.opt.step)


def test_restore_into_struct_template(tmp_path, state):
    """Restore works against a ShapeDtypeStruct template (cold start)."""
    checkpoint.save(tmp_path, 1, state)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = checkpoint.restore(tmp_path, template)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_missing_raises(tmp_path, state):
    with pytest.raises(FileNotFoundError):
        checkpoint.restore(tmp_path, state)
