"""Supervision end-to-end acceptance (ISSUE 4): a Supervisor-hosted
rollout worker is SIGKILLed mid-run and the system keeps training —
respawned (spawn mode) or re-accepted on redial (connect mode) within its
restart budget, with `metrics()["services"]` showing a single healthy
worker entry whose counters stay monotonic across the restart; exhausting
the budget surfaces FAILED exactly as PR 3's containment did.

These spawn jax-initializing subprocesses — slow by nature; CI runs them
in the dedicated supervision-smoke job under a hard SIGKILL timeout."""
import multiprocessing
import os
import signal
import threading
import time

import pytest

from repro.configs import get_config, reduced
from repro.configs.base import (RLConfig, RuntimeConfig, SupervisionConfig,
                                TransportConfig)


def _system(*, spawn_workers=0, connect_workers=0, local_workers=0,
            restart="on_failure", max_restarts=2, seed=0,
            liveness_timeout_s=1.0):
    from repro.runtime import AcceRLSystem
    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    rl = RLConfig(grad_accum=1, lr_policy=1e-4, lr_value=1e-3)
    rt = RuntimeConfig(
        num_rollout_workers=local_workers, inference_batch=4,
        transport=TransportConfig(
            remote_rollout_workers=spawn_workers,
            connect_rollout_workers=connect_workers,
            heartbeat_s=0.1, token="e2e-token",
            reconnect_attempts=3,
            supervision=SupervisionConfig(
                restart=restart, max_restarts=max_restarts,
                backoff_initial_s=0.05, backoff_max_s=0.5,
                liveness_timeout_s=liveness_timeout_s)))
    return AcceRLSystem(cfg, rl, rt, suite="spatial", segment_horizon=4,
                        max_episode_steps=8, batch_episodes=4, seed=seed)


@pytest.mark.slow
def test_spawned_worker_sigkill_is_respawned_within_budget():
    """Acceptance (spawn mode): SIGKILL the only rollout worker mid-run;
    the Supervisor respawns it, training reaches its budget, and the
    service report shows ONE healthy worker entry with monotonic
    counters."""
    sys_ = _system(spawn_workers=1, restart="on_failure", seed=0)
    slot = sys_.remote_hosts[0]
    steps_at_kill = [0]

    def killer():
        deadline = time.monotonic() + 120.0
        while time.monotonic() < deadline:
            if slot.env_steps > 0 and slot.process is not None:
                steps_at_kill[0] = slot.env_steps
                os.kill(slot.process.pid, signal.SIGKILL)
                return
            time.sleep(0.05)

    t = threading.Thread(target=killer, daemon=True)
    t.start()
    m = sys_.run_async(train_steps=2, wall_timeout_s=240.0)
    t.join(timeout=5.0)

    assert steps_at_kill[0] > 0, "killer never fired"
    assert m["train_steps"] >= 2
    assert slot.restarts >= 1
    # single coherent worker entry, not one per incarnation
    names = [n for n in m["services"] if n.startswith("remote-rollout")]
    assert names == ["remote-rollout-0"]
    entry = m["services"]["remote-rollout-0"]
    assert entry["counters"]["restarts"] >= 1
    # monotonic across the restart: the final total includes the dead
    # incarnation's work (the killed process had made progress)
    assert entry["counters"]["env_steps"] >= steps_at_kill[0]
    # clean end state: the slot was healthy post-restart and stopped
    health = sys_.health()
    assert health["remote-rollout-0"]["state"] == "stopped", health
    assert health["remote-rollout-0"]["error"] is None
    assert health["supervisor"]["state"] == "stopped"
    assert not slot.process.is_alive()


@pytest.mark.slow
def test_budget_zero_surfaces_failed_like_pr3():
    """Acceptance (budget exhaustion): with a zero restart budget the
    first SIGKILL exhausts it — the slot surfaces FAILED and the run
    returns promptly, exactly PR 3's containment."""
    sys_ = _system(spawn_workers=1, local_workers=1, restart="on_failure",
                   max_restarts=0, seed=1)
    slot = sys_.remote_hosts[0]

    def killer():
        deadline = time.monotonic() + 120.0
        while time.monotonic() < deadline:
            if slot.env_steps > 0 and slot.process is not None:
                os.kill(slot.process.pid, signal.SIGKILL)
                return
            time.sleep(0.05)

    t = threading.Thread(target=killer, daemon=True)
    t.start()
    t0 = time.monotonic()
    m = sys_.run_async(train_steps=1_000_000, wall_timeout_s=180.0)
    wall = time.monotonic() - t0
    t.join(timeout=5.0)

    assert wall < 150.0, "exhaustion was not contained — hit wall timeout"
    health = sys_.health()
    assert health["remote-rollout-0"]["state"] == "failed"
    assert "restart budget exhausted" in health["remote-rollout-0"]["error"]
    assert health["trainer"]["state"] == "stopped"
    assert "services" in m and "remote-rollout-0" in m["services"]


def _connect_worker(address, token):
    """Child body for a connect-mode worker process (module-level so the
    spawn start method can pickle it)."""
    import sys
    from repro.launch.worker import run
    sys.exit(run(f"{address[0]}:{address[1]}", token=token,
                 hello_timeout_s=180.0, retry_s=0.2))


@pytest.mark.slow
def test_connect_worker_kill_and_redial_is_reaccepted():
    """Acceptance (connect mode): a dialed-in worker is SIGKILLed; a NEW
    worker process redials and is re-accepted into the same slot within
    the restart budget; the trainer reaches its budget and the slot ends
    healthy with monotonic counters."""
    ctx = multiprocessing.get_context("spawn")
    sys_ = _system(connect_workers=1, restart="on_failure", max_restarts=3,
                   seed=2, liveness_timeout_s=1.0)
    slot = sys_.remote_hosts[0]
    address = sys_.transport_server.address
    procs = []

    def controller():
        deadline = time.monotonic() + 200.0
        w1 = ctx.Process(target=_connect_worker,
                         args=(address, "e2e-token"), daemon=True)
        w1.start()
        procs.append(w1)
        while time.monotonic() < deadline:       # let it produce, then kill
            if slot.env_steps > 0:
                break
            time.sleep(0.05)
        steps_at_kill = slot.env_steps
        os.kill(w1.pid, signal.SIGKILL)
        w2 = ctx.Process(target=_connect_worker,
                         args=(address, "e2e-token"), daemon=True)
        w2.start()                               # redials until re-accepted
        procs.append(w2)
        return steps_at_kill

    result = {}
    t = threading.Thread(
        target=lambda: result.update(steps=controller()), daemon=True)
    t.start()
    m = sys_.run_async(train_steps=2, wall_timeout_s=240.0)
    t.join(timeout=10.0)

    assert m["train_steps"] >= 2
    assert result.get("steps", 0) > 0, "first worker never produced"
    assert slot.restarts >= 1, "kill was never detected as a restart"
    assert slot.incarnation >= 2, "redial was not re-accepted"
    names = [n for n in m["services"] if n.startswith("connect-rollout")]
    assert names == ["connect-rollout-0"]
    entry = m["services"]["connect-rollout-0"]
    assert entry["counters"]["env_steps"] >= result["steps"]
    health = sys_.health()
    assert health["connect-rollout-0"]["state"] == "stopped", health
    assert health["connect-rollout-0"]["error"] is None
    # the replacement worker saw the stop flag (or the server vanish) and
    # exited on its own; the first one died by our SIGKILL
    for p in procs:
        p.join(timeout=30.0)
        if p.is_alive():                      # never leak a worker process
            p.kill()
            p.join(timeout=5.0)
    assert procs[0].exitcode == -signal.SIGKILL
    assert not procs[1].is_alive()
