"""Distribution-layer tests: partition rules must produce divisible,
duplicate-free specs for EVERY assigned architecture on both production
meshes — cheap structural checks (AbstractMesh, no devices)."""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch import steps
from repro.optim import zero
from repro.sharding import rules

MESHES = {
    "16x16": AbstractMesh((("data", 16), ("model", 16))),
    "2x16x16": AbstractMesh((("pod", 2), ("data", 16), ("model", 16))),
}


def _axis_size(mesh, axis):
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _check_spec_tree(shapes, specs, mesh):
    leaves_sh = jax.tree.leaves(shapes)
    leaves_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_sh) == len(leaves_sp)
    for sh, sp in zip(leaves_sh, leaves_sp):
        used = []
        for i, axis in enumerate(sp):
            if axis is None:
                continue
            names = axis if isinstance(axis, tuple) else (axis,)
            for nm in names:
                assert nm not in used, f"dup axis {nm} in {sp} for {sh.shape}"
                used.append(nm)
            assert sh.shape[i] % _axis_size(mesh, axis) == 0, \
                f"{sh.shape}[{i}] not divisible by {axis} under {sp}"


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_divisible(arch, mesh_name):
    cfg = get_config(arch)
    mesh = MESHES[mesh_name]
    shapes = steps.param_structs(cfg)
    for fsdp in (False, True):
        specs = rules.param_specs(cfg, shapes, mesh, fsdp=fsdp)
        _check_spec_tree(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_zero_moments_specs(arch):
    cfg = get_config(arch)
    mesh = MESHES["16x16"]
    shapes = steps.param_structs(cfg)
    pspec = rules.param_specs(cfg, shapes, mesh, fsdp=True)
    mspec = zero.shard_moments_spec(shapes, pspec, data_axis="data",
                                    data_size=16)
    _check_spec_tree(shapes, mspec, mesh)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_big_tensors_are_sharded(arch):
    """No parameter tensor above 64 MiB (bf16) may stay fully replicated
    on the single-pod mesh — the memory-fit precondition."""
    cfg = get_config(arch)
    mesh = MESHES["16x16"]
    shapes = steps.param_structs(cfg)
    fsdp = cfg.param_count() > rules.FSDP_PARAM_THRESHOLD
    specs = rules.param_specs(cfg, shapes, mesh, fsdp=fsdp)

    def check(path, sh, sp):
        nbytes = int(np.prod(sh.shape)) * 2
        if nbytes > 64 * 2**20:
            assert any(a is not None for a in sp), \
                f"{path}: {sh.shape} ({nbytes/2**20:.0f} MiB) replicated"
    jax.tree_util.tree_map_with_path(
        lambda p, sh, sp: check(p, sh, sp), shapes, specs,
        is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("shape", INPUT_SHAPES, ids=lambda s: s.name)
def test_data_specs(shape):
    mesh = MESHES["16x16"]
    spec = rules.data_spec(mesh, shape.global_batch, 2, seq_axis=1,
                           seq_len=shape.seq_len)
    if shape.global_batch >= 16:
        assert spec[0] is not None          # batch sharded on data
    else:
        assert spec[0] is None              # long_500k: context parallelism
        assert spec[1] == "data"


@pytest.mark.parametrize("arch", ["granite-20b", "zamba2-1.2b",
                                  "mamba2-2.7b", "dbrx-132b"])
def test_cache_specs_cover_decode(arch):
    import jax.numpy as jnp
    from repro.models import transformer
    cfg = get_config(arch)
    mesh = MESHES["16x16"]
    cache = jax.eval_shape(
        lambda: transformer.init_decode_cache(cfg, 128, 4096))
    specs = rules.cache_specs(cfg, cache, mesh, 128, 4096)
    _check_spec_tree(cache, specs, mesh)
    # the KV/state payload must be batch-sharded
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert any(any(a is not None for a in sp) for sp in flat)


def test_choose_accum_monotone():
    from repro.configs.base import ShapeConfig
    mesh = MESHES["16x16"]
    small = get_config("internlm2-1.8b")
    big = get_config("granite-20b")
    shp = ShapeConfig("train_4k", 4096, 256, "train")
    assert steps.choose_accum(big, shp, mesh) >= \
        steps.choose_accum(small, shp, mesh)
