"""Streaming data-plane tests (ISSUE 5): pipelined windowed-ack puts
(PutStream), exactly-once replay across mid-stream reconnects, the
persistent-ring channel (ShmRingChannel) with its churn accounting, and
trainer-side pop coalescing (pop_many) from the buffer all the way
through the wire, the mixed source, and the prefetcher."""
import socket
import threading
import time

import numpy as np
import pytest

from repro.data.prefetch import Prefetcher
from repro.data.replay import FIFOReplayBuffer
from repro.runtime.experience import (FifoChannel, MixedExperienceSource,
                                      RingChannel)
from repro.runtime.transport import (PutStream, ShmChannel, ShmRingChannel,
                                     SocketChannel, TransportServer,
                                     WeightStoreTransport)
from repro.runtime.transport.channel import release_lease, shared_memory
from repro.runtime.transport.ring import RingError
from repro.runtime.weight_store import VersionedWeightStore


@pytest.fixture()
def server():
    srv = TransportServer()
    srv.start()
    yield srv
    srv.stop()
    srv.join()


def _host(server, capacity=4096, policy="drop_oldest", name=None):
    name = name or f"chan-{len(server._channels)}"
    local = FifoChannel(capacity, policy=policy, block_timeout=0.2)
    server.add_channel(name, local)
    return name, local


def _drop_server_side(server):
    with server._conn_lock:
        conns = list(server._conns)
    for c in conns:
        try:
            c.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass


def _item(i, n=32):
    return {"i": np.int32(i), "x": np.full(n, float(i), np.float32)}


# ---------------------------------------------------------------------------
# PutStream: pipelined puts with windowed async acks
# ---------------------------------------------------------------------------

def test_put_stream_delivers_and_acks(server):
    name, local = _host(server)
    s = PutStream(server.address, name, window=4)
    for i in range(12):
        assert s.put_many([_item(3 * i + j) for j in range(3)]) == [True] * 3
    assert s.flush(10.0), s.stats()
    st = s.stats()
    assert st["items_acked"] == 36 and st["items_accepted"] == 36
    assert st["frames_sent"] == 12 and st["frames_unacked"] == 0
    s.close()
    got = local.pop_batch(36, timeout=1.0)
    assert [int(g["i"]) for g in got] == list(range(36))  # in order


def test_put_stream_verdicts_land_in_stats(server):
    """Backpressure rejections come back asynchronously: the provisional
    return is optimistic, the authoritative counts are in stats()."""
    name, local = _host(server, capacity=4, policy="drop_newest")
    s = PutStream(server.address, name, window=8)
    assert s.put_many([_item(i) for i in range(10)]) == [True] * 10
    assert s.flush(10.0)
    st = s.stats()
    assert st["items_accepted"] == 4 and st["items_rejected"] == 6
    assert len(local) == 4
    s.close()


def test_put_stream_unknown_channel_fails_loudly(server):
    from repro.runtime.transport import TransportError
    with pytest.raises(TransportError):
        PutStream(server.address, "nope")


def test_put_stream_window_backpressure(server):
    """A stalled server-side channel (block policy, full) slows acks; the
    producer blocks only once `window` frames are in flight."""
    local = FifoChannel(1, policy="block", block_timeout=30.0)
    server.add_channel("blk", local)
    s = PutStream(server.address, "blk", window=2)
    t0 = time.monotonic()
    s.put_many([_item(0)])                 # accepted instantly
    s.put_many([_item(1)])                 # parks in the server-side put
    s.put_many([_item(2)])                 # window has room for one more
    # window full now: this one must wait for an ack slot
    done = []
    t = threading.Thread(
        target=lambda: done.append(s.put_many([_item(3)])))
    t.start()
    time.sleep(0.3)
    assert not done, "4th flush should be window-blocked"
    local.pop_batch(1, timeout=1.0)        # consumer frees a slot
    local.pop_batch(1, timeout=2.0)
    t.join(timeout=10.0)
    assert done == [[True]]
    assert time.monotonic() - t0 < 20.0
    s.close()


def test_put_stream_close_flushes(server):
    name, local = _host(server)
    s = PutStream(server.address, name, window=64)
    for i in range(50):
        s.put_many([_item(i)])
    s.close()                              # drains the window first
    assert len(local) == 50
    assert s.put_many([_item(99)]) == [False]   # closed: no-op, no storm


# ---------------------------------------------------------------------------
# exactly-once replay across mid-stream reconnects (the acceptance test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ring", [False, True])
def test_put_stream_reconnect_replay_exactly_once(server, ring):
    """Drop every server-side connection repeatedly while a stream is in
    flight: the unacked window is replayed after each redial, the server
    dedups by put sequence, and every item lands EXACTLY once.

    Drops are injected synchronously from the put loop (every quarter of
    the run), not from a timer thread — a fast machine could stream every
    item before a timer's first tick fired, making the reconnect
    assertion below flaky."""
    if ring and shared_memory is None:
        pytest.skip("multiprocessing.shared_memory unavailable")
    name, local = _host(server, capacity=100_000)
    s = PutStream(server.address, name, window=8,
                  ring_bytes=(1 << 20) if ring else 0,
                  reconnect_attempts=20, reconnect_backoff_s=0.01)
    total = 400
    flush = 4
    flushes = total // flush
    for k, base in enumerate(range(0, total, flush)):
        if k and k % (flushes // 4) == 0:      # mid-stream, frames in flight
            _drop_server_side(server)
        s.put_many([_item(base + j) for j in range(flush)])
    assert s.flush(30.0), s.stats()
    st = s.stats()
    s.close()

    got = local.pop_batch(len(local), timeout=1.0) or []
    ids = sorted(int(g["i"]) for g in got)
    assert ids == list(range(total)), (
        f"exactly-once violated: {len(ids)} items, "
        f"dups={len(ids) - len(set(ids))}, stats={st}")
    assert st["items_acked"] == total
    assert s.reconnects >= 1, "the test never actually reconnected"
    # the server really saw duplicate frames and deduped them
    if st["replayed_frames"]:
        assert server.metrics.counter("stream_dup_frames") >= 0


def test_put_stream_no_budget_fails_fast(server):
    name, _ = _host(server)
    s = PutStream(server.address, name, window=4)    # reconnect_attempts=0
    s.put_many([_item(0)])
    assert s.flush(5.0)
    _drop_server_side(server)
    deadline = time.monotonic() + 10.0
    while s.failed is None and time.monotonic() < deadline:
        s.put_many([_item(1)])
        time.sleep(0.02)
    assert s.failed is not None
    assert s.put_many([_item(2)]) == [False]
    s.close()


# ---------------------------------------------------------------------------
# streaming through the channel surface (SocketChannel put_window)
# ---------------------------------------------------------------------------

def test_socket_channel_streams_when_windowed(server):
    name, local = _host(server)
    chan = SocketChannel(server.address, name, put_window=8)
    before = server.metrics.counter("requests")
    for i in range(10):
        assert chan.put_many([_item(10 * i + j) for j in range(10)]) \
            == [True] * 10
    assert chan._put_stream().flush(10.0)
    assert len(local) == 100
    st = chan.stream_stats()
    assert st is not None and st["items_accepted"] == 100
    # the stream's frames are NOT request/response RPCs on the main client
    assert server.metrics.counter("requests") - before >= 10  # acks counted
    chan.close()
    assert chan.put_many([_item(0)]) == [False]


# ---------------------------------------------------------------------------
# ShmRingChannel: persistent rings end to end + churn accounting
# ---------------------------------------------------------------------------

@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_ring_channel_zero_segment_churn(server):
    """Large payloads through the ring channel: zero per-message segment
    create/attach/unlink on the server, ring counters carry the traffic —
    the churn fix is observable in metrics(), not just benchmarked."""
    name, local = _host(server)
    chan = ShmRingChannel(server.address, name, ring_bytes=1 << 22,
                          put_window=8)
    big = [{"w": np.arange(20_000, dtype=np.float32) + i} for i in range(4)]
    for _ in range(5):
        chan.put_many(big)
    assert chan._put_stream().flush(10.0)
    got = chan.pop_many(100, timeout=5.0)
    assert got is not None and len(got) == 20
    np.testing.assert_array_equal(
        got[3]["w"], np.arange(20_000, dtype=np.float32) + 3)
    counters = server.metrics.snapshot()["counters"]
    assert counters.get("shm_segments_created", 0) == 0
    assert counters.get("shm_segments_attached", 0) == 0
    assert counters["ring_records_in"] == 5
    assert counters["ring_records_out"] >= 1
    assert counters["ring_bytes_in"] > 0 and counters["ring_bytes_out"] > 0
    chan.close()


@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_shm_channel_counts_segment_churn(server):
    """The per-message data plane now exposes its churn: one attach per
    big request, one create+unlink per big reply."""
    name, local = _host(server)
    chan = ShmChannel(server.address, name, shm_threshold=256)
    big = [{"w": np.arange(20_000, dtype=np.float32)} for _ in range(3)]
    assert chan.put_many(big) == [True] * 3
    assert chan.pop_batch(3, timeout=5.0) is not None
    chan.put({"tiny": np.int32(1)})        # next frame acks the reply shm
    counters = server.metrics.snapshot()["counters"]
    assert counters["shm_segments_attached"] >= 1
    assert counters["shm_segments_created"] >= 1
    assert counters["shm_segments_unlinked"] >= 1
    chan.close()


@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_ring_channel_pop_survives_reconnect(server):
    """The pop-reply ring is per-connection state: after a server-side
    drop the client redials, re-opens a FRESH ring via the reconnect
    hook, and pops keep flowing through it."""
    name, local = _host(server)
    chan = ShmRingChannel(server.address, name, ring_bytes=1 << 20,
                          reconnect_attempts=10,
                          reconnect_backoff_s=0.02)
    local.put_many([_item(i, n=30_000) for i in range(4)])
    assert len(chan.pop_many(2, timeout=5.0)) == 2
    old_ring = chan._s2c.name
    _drop_server_side(server)
    got = chan.pop_many(2, timeout=10.0)
    assert got is not None and len(got) == 2
    assert chan._client.reconnects >= 1
    assert chan._s2c.name != old_ring, "reconnect must re-open a fresh ring"
    chan.close()


@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_ring_channel_oversized_flush_is_loud(server):
    name, _ = _host(server)
    chan = ShmRingChannel(server.address, name, ring_bytes=1 << 12)
    with pytest.raises(RingError):
        chan.put_many([{"w": np.zeros(100_000, np.float32)}])
    chan.close()


# ---------------------------------------------------------------------------
# pop coalescing: buffer → channel → wire → mixed source → prefetcher
# ---------------------------------------------------------------------------

def test_fifo_buffer_pop_upto():
    buf = FIFOReplayBuffer(64)
    assert buf.pop_upto(4, timeout=0.05) is None
    for i in range(6):
        buf.push(i)
    assert buf.pop_upto(4, timeout=0.1) == [0, 1, 2, 3]   # capped at max
    assert buf.pop_upto(4, timeout=0.1) == [4, 5]         # partial, no wait
    assert buf.pop_upto(0, timeout=0.1) is None


def test_fifo_channel_pop_many_blocks_only_for_first():
    chan = FifoChannel(64)
    t0 = time.monotonic()
    threading.Timer(0.15, lambda: chan.put({"i": 0})).start()
    got = chan.pop_many(8, timeout=2.0)
    assert len(got) == 1 and time.monotonic() - t0 < 1.5


def test_pop_many_one_rpc_over_the_wire(server):
    name, local = _host(server)
    remote = SocketChannel(server.address, name)
    local.put_many([_item(i) for i in range(5)])
    before = server.metrics.counter("requests")
    got = remote.pop_many(32, timeout=1.0)
    assert [int(g["i"]) for g in got] == list(range(5))
    assert server.metrics.counter("requests") == before + 1
    assert remote.pop_many(32, timeout=0.1) is None       # empty: timeout
    remote.close()


def test_ring_replay_channel_pop_many_is_an_error(server):
    """A sampling RingChannel (B_wm) has no FIFO pop path: the endpoint
    surfaces the error instead of inventing semantics."""
    from repro.runtime.transport import TransportError
    ring = RingChannel(8, seed=0)
    server.add_channel("bwm", ring)
    remote = SocketChannel(server.address, "bwm")
    remote.put(_item(0))
    with pytest.raises(TransportError):
        remote.pop_many(4, timeout=0.1)
    remote.close()


def test_mixed_source_pop_many_partial_and_pins():
    real, imagined = FifoChannel(64), FifoChannel(64)
    # hard pin 0.0: never touches real even when imagined is empty
    src = MixedExperienceSource(real, imagined, real_fraction=0.0)
    real.put_many([{"r": i} for i in range(4)])
    assert src.pop_many(4, timeout=0.05) is None
    imagined.put_many([{"im": i} for i in range(2)])
    got = src.pop_many(8, timeout=1.0)
    assert len(got) == 2 and all("im" in g for g in got)
    # intermediate fraction: partial drains still mix by availability
    src2 = MixedExperienceSource(real, imagined, real_fraction=0.5)
    imagined.put_many([{"im": i} for i in range(2)])
    got = src2.pop_many(4, timeout=1.0)
    assert 1 <= len(got) <= 4
    assert src2.real_consumed + src2.imagined_consumed == len(got)


# ---------------------------------------------------------------------------
# adaptive streaming (ISSUE 9): RTT-tuned effective window / ack cadence
# ---------------------------------------------------------------------------

def test_adaptive_tune_controller(server):
    """The adaptive controller, stepped deterministically: steady RTT
    never shrinks the effective window below the configured bound,
    verdict pressure halves it (bounded below), the server's ack cadence
    follows via stream.tune, and recovery restores the full window."""
    name, _ = _host(server)
    s = PutStream(server.address, name, window=8, adaptive=True)
    try:
        with s._lock:
            s._tune(0.01, 0)                     # primes the EWMA
            assert s.window_effective == 8 and s.window_backoffs == 0
            for _ in range(6):                   # sustained rejections
                s._tune(0.01, 3)
            assert s.window_effective == s._win_min == 1
            assert s.window_backoffs == 3        # 8 -> 4 -> 2 -> 1
            assert s.ack_every_effective == 1    # cadence tracked the window
            assert s._rtt_ewma > 0.0
        # the retune really reached the server (async accept loop)
        deadline = time.monotonic() + 5.0
        while (server.metrics.counter("stream_tunes") < 1
               and time.monotonic() < deadline):
            time.sleep(0.01)
        assert server.metrics.counter("stream_tunes") >= 1
        with s._lock:
            for _ in range(8):                   # settled RTT: recovery
                s._tune(0.01, 0)
            assert s.window_effective == 8       # back at the static bound
            assert s.ack_every_effective == s.ack_every
    finally:
        s.close()


def test_adaptive_stream_backs_off_under_pressure(server):
    """End to end: a shedding channel (tiny, drop_newest) produces reject
    verdicts; the adaptive stream halves its effective window at least
    once, still acks every frame, and reports the RTT EWMA."""
    name, local = _host(server, capacity=4, policy="drop_newest")
    s = PutStream(server.address, name, window=16, adaptive=True)
    for base in range(0, 100, 4):
        s.put_many([_item(base + j) for j in range(4)])
    assert s.flush(10.0), s.stats()
    st = s.stats()
    s.close()
    assert st["items_acked"] == 100
    assert st["items_accepted"] == 4 and st["items_rejected"] == 96
    assert st["window_backoffs"] >= 1
    assert st["window_effective"] >= 2            # bounded below (16 // 8)
    assert st["rtt_ewma_s"] > 0.0
    assert len(local) == 4


def test_adaptive_stream_steady_delivery(server):
    """A healthy channel under an adaptive stream: every item delivered,
    effective window still within the configured bounds."""
    name, local = _host(server, capacity=100_000)
    s = PutStream(server.address, name, window=8, adaptive=True)
    for base in range(0, 120, 4):
        s.put_many([_item(base + j) for j in range(4)])
    assert s.flush(10.0), s.stats()
    st = s.stats()
    s.close()
    assert st["items_acked"] == 120 and st["items_accepted"] == 120
    assert s._win_min <= st["window_effective"] <= st["window"]
    assert len(local) == 120


# ---------------------------------------------------------------------------
# weight broadcast lane (ISSUE 9): positional reads, torn-read fallback
# ---------------------------------------------------------------------------

@pytest.fixture()
def lane_server():
    srv = TransportServer(weight_lane_bytes=1 << 20)
    store = VersionedWeightStore()
    srv.set_store(store)
    srv.start()
    srv.local_store = store
    yield srv
    srv.stop()
    srv.join()


@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_weight_lane_acquires_positionally(lane_server):
    params = {"w": np.arange(512, dtype=np.float32), "b": np.float32(2.0)}
    lane_server.local_store.publish(params, 1)
    remote = WeightStoreTransport(lane_server.address, use_lane=True,
                                  state_ttl=0.0)
    try:
        got, version = remote.acquire(newer_than=0, timeout=5.0)
        assert version == 1
        np.testing.assert_array_equal(got["w"], params["w"])
        assert remote.lane_hits == 1 and remote.lane_fallbacks == 0
        lane_server.local_store.publish(
            {"w": params["w"] * 2, "b": np.float32(3.0)}, 2)
        got2, v2 = remote.acquire(newer_than=1, timeout=5.0)
        assert v2 == 2
        np.testing.assert_array_equal(got2["w"], params["w"] * 2)
        assert remote.lane_hits == 2
        counters = lane_server.metrics.snapshot()["counters"]
        assert counters["weight_lane_publishes"] == 2
        assert counters["weight_lane_serves"] == 2
    finally:
        remote.close()


@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_weight_lane_torn_read_falls_back_in_band(lane_server):
    """A failed positional read (stale attachment / torn under a newer
    publish) degrades to ONE in-band re-acquire — same params, counted."""
    lane_server.local_store.publish({"w": np.full(64, 7.0, np.float32)}, 3)
    remote = WeightStoreTransport(lane_server.address, use_lane=True,
                                  state_ttl=0.0)
    try:
        remote._lane_read = lambda resp: None    # every lane read "torn"
        got, version = remote.acquire(newer_than=-1, timeout=5.0)
        assert version == 3
        np.testing.assert_array_equal(got["w"], np.full(64, 7.0, np.float32))
        assert remote.lane_fallbacks == 1 and remote.lane_hits == 0
    finally:
        remote.close()


# ---------------------------------------------------------------------------
# zero-copy pops through the ring channel (ISSUE 9)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_ring_channel_zero_copy_pop_leases(server):
    """zero_copy_pop=True: decoded items view the pop-reply ring in place
    and carry one shared lease; releasing every item frees the ring."""
    name, local = _host(server)
    chan = ShmRingChannel(server.address, name, ring_bytes=1 << 22,
                          zero_copy_pop=True)
    local.put_many([_item(i, n=20_000) for i in range(6)])
    got = chan.pop_many(6, timeout=5.0)
    assert got is not None and len(got) == 6
    assert all(g.get("_lease") is not None for g in got)
    # the data is readable (and correct) while the lease is live
    np.testing.assert_array_equal(got[2]["x"],
                                  np.full(20_000, 2.0, np.float32))
    rs = chan.ring_stats()
    assert rs["views_served"] >= 1 and rs["views_live"] >= 1
    assert rs["bytes_copied"] == 0               # nothing memcpy'd out yet
    for g in got:
        release_lease(g)
    assert all("_lease" not in g for g in got)   # release_lease strips it
    assert chan.ring_stats()["views_live"] == 0
    # the ring keeps serving after the lease cycle
    local.put_many([_item(9, n=20_000)])
    more = chan.pop_many(2, timeout=5.0)
    assert more is not None and len(more) == 1
    np.testing.assert_array_equal(more[0]["x"],
                                  np.full(20_000, 9.0, np.float32))
    for g in more:
        release_lease(g)
    chan.close()


# ---------------------------------------------------------------------------
# prefetcher (ISSUE 9): idle backoff, lease release, staging pool
# ---------------------------------------------------------------------------

def test_prefetcher_idle_backoff_grows_and_resets():
    """An empty source sees exponentially longer drain timeouts (capped),
    and the first successful drain resets the cadence."""
    class RecordingSource:
        def __init__(self):
            self.timeouts = []
            self.feed = []
            self.fed_at = None       # index of the drain that got items

        def pop_many(self, n, timeout=None):
            self.timeouts.append(timeout)
            if self.feed:
                out, self.feed = self.feed, []
                self.fed_at = len(self.timeouts) - 1
                return out
            time.sleep(0.002)
            return None

    src = RecordingSource()
    p = Prefetcher(src, 4, collate=lambda segs: list(segs), depth=1,
                   drain_timeout_s=0.01, idle_timeout_max_s=0.08)
    p.start()
    try:
        deadline = time.monotonic() + 5.0
        while len(src.timeouts) < 8 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert src.timeouts[0] == pytest.approx(0.01)
        assert max(src.timeouts[:8]) <= 0.08 + 1e-9     # capped
        assert any(t > 0.01 for t in src.timeouts[:8])  # it actually grew
        # a successful drain resets the timeout to the configured floor
        src.feed = [{"i": i} for i in range(4)]
        assert p.get(timeout=5.0) is not None
        deadline = time.monotonic() + 5.0
        while (src.fed_at is None
               or len(src.timeouts) <= src.fed_at + 1) \
                and time.monotonic() < deadline:
            time.sleep(0.005)
        assert src.timeouts[src.fed_at + 1] == pytest.approx(0.01)
        assert p.metrics()["idle_backoffs"] >= 1
    finally:
        p.stop()


def test_prefetcher_releases_ring_leases():
    class FakeLease:
        def __init__(self):
            self.released = 0

        def release(self):
            self.released += 1

    chan = FifoChannel(64)
    leases = [FakeLease() for _ in range(8)]
    chan.put_many([{"i": np.int32(i), "_lease": leases[i]}
                   for i in range(8)])
    p = Prefetcher(chan, 8, collate=lambda segs: list(segs), depth=1)
    p.start()
    try:
        batch = p.get(timeout=5.0)
        assert batch is not None and len(batch) == 8
        assert all(l.released == 1 for l in leases)      # exactly once
        assert all("_lease" not in b for b in batch)     # stripped
        assert p.metrics()["views_served"] == 8
    finally:
        p.stop()


def test_prefetcher_staging_pool_reuses_slabs():
    """Shape-stable dict batches are carved into pooled page-aligned
    slabs: after warmup every batch reuses a slab (zero batch-sized
    allocations in steady state), and the copied bytes are counted."""
    chan = FifoChannel(256)
    collate = lambda segs: {"x": np.stack([s["x"] for s in segs]),
                            "i": np.stack([s["i"] for s in segs])}
    p = Prefetcher(chan, 4, collate=collate, depth=1, stage_batches=True,
                   staging_slabs=2)
    p.start()
    try:
        batches = 0
        for round_ in range(4):
            chan.put_many([_item(4 * round_ + j, n=64) for j in range(4)])
            batch = p.get(timeout=5.0)
            assert batch is not None
            np.testing.assert_array_equal(
                batch["x"][1], np.full(64, 4.0 * round_ + 1, np.float32))
            # staged leaves are aligned views into the slab, not copies
            assert batch["x"].ctypes.data % 64 == 0
            batches += 1
        m = p.metrics()
        assert m["batches_built"] >= batches
        assert m["bytes_copied"] > 0
        assert m["staging_reuse"] >= 1           # the pool actually recycled
        assert m["staging_slabs"] <= 2           # bounded allocations
    finally:
        p.stop()


def test_prefetcher_accumulates_partial_drains():
    """The prefetcher rides pop_many: items trickling in smaller than the
    super-batch still assemble into exactly-sized batches."""
    chan = FifoChannel(256)
    built = Prefetcher(chan, 8, collate=lambda segs: list(segs), depth=2)
    built.start()
    try:
        for base in (0, 3, 6):
            chan.put_many([{"i": base + j} for j in range(3)])
            time.sleep(0.05)
        batch = built.get(timeout=5.0)
        assert batch is not None and len(batch) == 8
        assert [b["i"] for b in batch] == list(range(8))
    finally:
        built.stop()
