"""Streaming data-plane tests (ISSUE 5): pipelined windowed-ack puts
(PutStream), exactly-once replay across mid-stream reconnects, the
persistent-ring channel (ShmRingChannel) with its churn accounting, and
trainer-side pop coalescing (pop_many) from the buffer all the way
through the wire, the mixed source, and the prefetcher."""
import socket
import threading
import time

import numpy as np
import pytest

from repro.data.prefetch import Prefetcher
from repro.data.replay import FIFOReplayBuffer
from repro.runtime.experience import (FifoChannel, MixedExperienceSource,
                                      RingChannel)
from repro.runtime.transport import (PutStream, ShmChannel, ShmRingChannel,
                                     SocketChannel, TransportServer)
from repro.runtime.transport.channel import shared_memory
from repro.runtime.transport.ring import RingError


@pytest.fixture()
def server():
    srv = TransportServer()
    srv.start()
    yield srv
    srv.stop()
    srv.join()


def _host(server, capacity=4096, policy="drop_oldest", name=None):
    name = name or f"chan-{len(server._channels)}"
    local = FifoChannel(capacity, policy=policy, block_timeout=0.2)
    server.add_channel(name, local)
    return name, local


def _drop_server_side(server):
    with server._conn_lock:
        conns = list(server._conns)
    for c in conns:
        try:
            c.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass


def _item(i, n=32):
    return {"i": np.int32(i), "x": np.full(n, float(i), np.float32)}


# ---------------------------------------------------------------------------
# PutStream: pipelined puts with windowed async acks
# ---------------------------------------------------------------------------

def test_put_stream_delivers_and_acks(server):
    name, local = _host(server)
    s = PutStream(server.address, name, window=4)
    for i in range(12):
        assert s.put_many([_item(3 * i + j) for j in range(3)]) == [True] * 3
    assert s.flush(10.0), s.stats()
    st = s.stats()
    assert st["items_acked"] == 36 and st["items_accepted"] == 36
    assert st["frames_sent"] == 12 and st["frames_unacked"] == 0
    s.close()
    got = local.pop_batch(36, timeout=1.0)
    assert [int(g["i"]) for g in got] == list(range(36))  # in order


def test_put_stream_verdicts_land_in_stats(server):
    """Backpressure rejections come back asynchronously: the provisional
    return is optimistic, the authoritative counts are in stats()."""
    name, local = _host(server, capacity=4, policy="drop_newest")
    s = PutStream(server.address, name, window=8)
    assert s.put_many([_item(i) for i in range(10)]) == [True] * 10
    assert s.flush(10.0)
    st = s.stats()
    assert st["items_accepted"] == 4 and st["items_rejected"] == 6
    assert len(local) == 4
    s.close()


def test_put_stream_unknown_channel_fails_loudly(server):
    from repro.runtime.transport import TransportError
    with pytest.raises(TransportError):
        PutStream(server.address, "nope")


def test_put_stream_window_backpressure(server):
    """A stalled server-side channel (block policy, full) slows acks; the
    producer blocks only once `window` frames are in flight."""
    local = FifoChannel(1, policy="block", block_timeout=30.0)
    server.add_channel("blk", local)
    s = PutStream(server.address, "blk", window=2)
    t0 = time.monotonic()
    s.put_many([_item(0)])                 # accepted instantly
    s.put_many([_item(1)])                 # parks in the server-side put
    s.put_many([_item(2)])                 # window has room for one more
    # window full now: this one must wait for an ack slot
    done = []
    t = threading.Thread(
        target=lambda: done.append(s.put_many([_item(3)])))
    t.start()
    time.sleep(0.3)
    assert not done, "4th flush should be window-blocked"
    local.pop_batch(1, timeout=1.0)        # consumer frees a slot
    local.pop_batch(1, timeout=2.0)
    t.join(timeout=10.0)
    assert done == [[True]]
    assert time.monotonic() - t0 < 20.0
    s.close()


def test_put_stream_close_flushes(server):
    name, local = _host(server)
    s = PutStream(server.address, name, window=64)
    for i in range(50):
        s.put_many([_item(i)])
    s.close()                              # drains the window first
    assert len(local) == 50
    assert s.put_many([_item(99)]) == [False]   # closed: no-op, no storm


# ---------------------------------------------------------------------------
# exactly-once replay across mid-stream reconnects (the acceptance test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ring", [False, True])
def test_put_stream_reconnect_replay_exactly_once(server, ring):
    """Drop every server-side connection repeatedly while a stream is in
    flight: the unacked window is replayed after each redial, the server
    dedups by put sequence, and every item lands EXACTLY once.

    Drops are injected synchronously from the put loop (every quarter of
    the run), not from a timer thread — a fast machine could stream every
    item before a timer's first tick fired, making the reconnect
    assertion below flaky."""
    if ring and shared_memory is None:
        pytest.skip("multiprocessing.shared_memory unavailable")
    name, local = _host(server, capacity=100_000)
    s = PutStream(server.address, name, window=8,
                  ring_bytes=(1 << 20) if ring else 0,
                  reconnect_attempts=20, reconnect_backoff_s=0.01)
    total = 400
    flush = 4
    flushes = total // flush
    for k, base in enumerate(range(0, total, flush)):
        if k and k % (flushes // 4) == 0:      # mid-stream, frames in flight
            _drop_server_side(server)
        s.put_many([_item(base + j) for j in range(flush)])
    assert s.flush(30.0), s.stats()
    st = s.stats()
    s.close()

    got = local.pop_batch(len(local), timeout=1.0) or []
    ids = sorted(int(g["i"]) for g in got)
    assert ids == list(range(total)), (
        f"exactly-once violated: {len(ids)} items, "
        f"dups={len(ids) - len(set(ids))}, stats={st}")
    assert st["items_acked"] == total
    assert s.reconnects >= 1, "the test never actually reconnected"
    # the server really saw duplicate frames and deduped them
    if st["replayed_frames"]:
        assert server.metrics.counter("stream_dup_frames") >= 0


def test_put_stream_no_budget_fails_fast(server):
    name, _ = _host(server)
    s = PutStream(server.address, name, window=4)    # reconnect_attempts=0
    s.put_many([_item(0)])
    assert s.flush(5.0)
    _drop_server_side(server)
    deadline = time.monotonic() + 10.0
    while s.failed is None and time.monotonic() < deadline:
        s.put_many([_item(1)])
        time.sleep(0.02)
    assert s.failed is not None
    assert s.put_many([_item(2)]) == [False]
    s.close()


# ---------------------------------------------------------------------------
# streaming through the channel surface (SocketChannel put_window)
# ---------------------------------------------------------------------------

def test_socket_channel_streams_when_windowed(server):
    name, local = _host(server)
    chan = SocketChannel(server.address, name, put_window=8)
    before = server.metrics.counter("requests")
    for i in range(10):
        assert chan.put_many([_item(10 * i + j) for j in range(10)]) \
            == [True] * 10
    assert chan._put_stream().flush(10.0)
    assert len(local) == 100
    st = chan.stream_stats()
    assert st is not None and st["items_accepted"] == 100
    # the stream's frames are NOT request/response RPCs on the main client
    assert server.metrics.counter("requests") - before >= 10  # acks counted
    chan.close()
    assert chan.put_many([_item(0)]) == [False]


# ---------------------------------------------------------------------------
# ShmRingChannel: persistent rings end to end + churn accounting
# ---------------------------------------------------------------------------

@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_ring_channel_zero_segment_churn(server):
    """Large payloads through the ring channel: zero per-message segment
    create/attach/unlink on the server, ring counters carry the traffic —
    the churn fix is observable in metrics(), not just benchmarked."""
    name, local = _host(server)
    chan = ShmRingChannel(server.address, name, ring_bytes=1 << 22,
                          put_window=8)
    big = [{"w": np.arange(20_000, dtype=np.float32) + i} for i in range(4)]
    for _ in range(5):
        chan.put_many(big)
    assert chan._put_stream().flush(10.0)
    got = chan.pop_many(100, timeout=5.0)
    assert got is not None and len(got) == 20
    np.testing.assert_array_equal(
        got[3]["w"], np.arange(20_000, dtype=np.float32) + 3)
    counters = server.metrics.snapshot()["counters"]
    assert counters.get("shm_segments_created", 0) == 0
    assert counters.get("shm_segments_attached", 0) == 0
    assert counters["ring_records_in"] == 5
    assert counters["ring_records_out"] >= 1
    assert counters["ring_bytes_in"] > 0 and counters["ring_bytes_out"] > 0
    chan.close()


@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_shm_channel_counts_segment_churn(server):
    """The per-message data plane now exposes its churn: one attach per
    big request, one create+unlink per big reply."""
    name, local = _host(server)
    chan = ShmChannel(server.address, name, shm_threshold=256)
    big = [{"w": np.arange(20_000, dtype=np.float32)} for _ in range(3)]
    assert chan.put_many(big) == [True] * 3
    assert chan.pop_batch(3, timeout=5.0) is not None
    chan.put({"tiny": np.int32(1)})        # next frame acks the reply shm
    counters = server.metrics.snapshot()["counters"]
    assert counters["shm_segments_attached"] >= 1
    assert counters["shm_segments_created"] >= 1
    assert counters["shm_segments_unlinked"] >= 1
    chan.close()


@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_ring_channel_pop_survives_reconnect(server):
    """The pop-reply ring is per-connection state: after a server-side
    drop the client redials, re-opens a FRESH ring via the reconnect
    hook, and pops keep flowing through it."""
    name, local = _host(server)
    chan = ShmRingChannel(server.address, name, ring_bytes=1 << 20,
                          reconnect_attempts=10,
                          reconnect_backoff_s=0.02)
    local.put_many([_item(i, n=30_000) for i in range(4)])
    assert len(chan.pop_many(2, timeout=5.0)) == 2
    old_ring = chan._s2c.name
    _drop_server_side(server)
    got = chan.pop_many(2, timeout=10.0)
    assert got is not None and len(got) == 2
    assert chan._client.reconnects >= 1
    assert chan._s2c.name != old_ring, "reconnect must re-open a fresh ring"
    chan.close()


@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_ring_channel_oversized_flush_is_loud(server):
    name, _ = _host(server)
    chan = ShmRingChannel(server.address, name, ring_bytes=1 << 12)
    with pytest.raises(RingError):
        chan.put_many([{"w": np.zeros(100_000, np.float32)}])
    chan.close()


# ---------------------------------------------------------------------------
# pop coalescing: buffer → channel → wire → mixed source → prefetcher
# ---------------------------------------------------------------------------

def test_fifo_buffer_pop_upto():
    buf = FIFOReplayBuffer(64)
    assert buf.pop_upto(4, timeout=0.05) is None
    for i in range(6):
        buf.push(i)
    assert buf.pop_upto(4, timeout=0.1) == [0, 1, 2, 3]   # capped at max
    assert buf.pop_upto(4, timeout=0.1) == [4, 5]         # partial, no wait
    assert buf.pop_upto(0, timeout=0.1) is None


def test_fifo_channel_pop_many_blocks_only_for_first():
    chan = FifoChannel(64)
    t0 = time.monotonic()
    threading.Timer(0.15, lambda: chan.put({"i": 0})).start()
    got = chan.pop_many(8, timeout=2.0)
    assert len(got) == 1 and time.monotonic() - t0 < 1.5


def test_pop_many_one_rpc_over_the_wire(server):
    name, local = _host(server)
    remote = SocketChannel(server.address, name)
    local.put_many([_item(i) for i in range(5)])
    before = server.metrics.counter("requests")
    got = remote.pop_many(32, timeout=1.0)
    assert [int(g["i"]) for g in got] == list(range(5))
    assert server.metrics.counter("requests") == before + 1
    assert remote.pop_many(32, timeout=0.1) is None       # empty: timeout
    remote.close()


def test_ring_replay_channel_pop_many_is_an_error(server):
    """A sampling RingChannel (B_wm) has no FIFO pop path: the endpoint
    surfaces the error instead of inventing semantics."""
    from repro.runtime.transport import TransportError
    ring = RingChannel(8, seed=0)
    server.add_channel("bwm", ring)
    remote = SocketChannel(server.address, "bwm")
    remote.put(_item(0))
    with pytest.raises(TransportError):
        remote.pop_many(4, timeout=0.1)
    remote.close()


def test_mixed_source_pop_many_partial_and_pins():
    real, imagined = FifoChannel(64), FifoChannel(64)
    # hard pin 0.0: never touches real even when imagined is empty
    src = MixedExperienceSource(real, imagined, real_fraction=0.0)
    real.put_many([{"r": i} for i in range(4)])
    assert src.pop_many(4, timeout=0.05) is None
    imagined.put_many([{"im": i} for i in range(2)])
    got = src.pop_many(8, timeout=1.0)
    assert len(got) == 2 and all("im" in g for g in got)
    # intermediate fraction: partial drains still mix by availability
    src2 = MixedExperienceSource(real, imagined, real_fraction=0.5)
    imagined.put_many([{"im": i} for i in range(2)])
    got = src2.pop_many(4, timeout=1.0)
    assert 1 <= len(got) <= 4
    assert src2.real_consumed + src2.imagined_consumed == len(got)


def test_prefetcher_accumulates_partial_drains():
    """The prefetcher rides pop_many: items trickling in smaller than the
    super-batch still assemble into exactly-sized batches."""
    chan = FifoChannel(256)
    built = Prefetcher(chan, 8, collate=lambda segs: list(segs), depth=2)
    built.start()
    try:
        for base in (0, 3, 6):
            chan.put_many([{"i": base + j} for j in range(3)])
            time.sleep(0.05)
        batch = built.get(timeout=5.0)
        assert batch is not None and len(batch) == 8
        assert [b["i"] for b in batch] == list(range(8))
    finally:
        built.stop()
