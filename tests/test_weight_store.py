"""Weight-store transport tests (paper App. D.6 / G.3, Table 8): every
transport must deliver the SAME tree under the drain protocol, versions
must be monotone under concurrent publish/acquire, and payloads must never
tear (a consumer always sees the tree matching the version it acquired)."""
import threading

import numpy as np
import pytest

from repro.runtime import (DirectTransport, DiskTransport,
                           SerializedTransport, VersionedWeightStore)

TRANSPORTS = {
    "nccl_direct": DirectTransport,
    "host_serialized": SerializedTransport,
    "shared_storage": DiskTransport,
}


def _params(version: int):
    """A version-stamped tree so payload/version tears are detectable."""
    base = np.float32(version)
    return {"w": np.full((4, 3), base),
            "nested": {"b": np.arange(6, dtype=np.float32) + base,
                       "v": np.array([version], np.int32)}}


def _assert_tree_matches(got, version: int):
    np.testing.assert_array_equal(np.asarray(got["nested"]["v"]), [version])
    np.testing.assert_allclose(np.asarray(got["w"]),
                               np.full((4, 3), np.float32(version)))
    np.testing.assert_allclose(np.asarray(got["nested"]["b"]),
                               np.arange(6, dtype=np.float32) + version)


# ---------------------------------------------------------------------------
# drain-protocol parity across transports
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TRANSPORTS))
def test_transport_parity_under_drain(name):
    """begin_publish → draining; publish clears the flag atomically with the
    swap; the acquired tree is identical regardless of transport."""
    store = VersionedWeightStore(transport=TRANSPORTS[name]())
    for v in range(3):
        store.begin_publish()
        assert store.draining, "drain signal must precede the swap"
        store.publish(_params(v), v)
        assert not store.draining, "publish must clear drain atomically"
        got, version = store.acquire(newer_than=v - 1, timeout=5.0)
        assert version == v
        _assert_tree_matches(got, v)
    # stale acquire: nothing newer than the last version
    assert store.acquire(newer_than=2, timeout=0.1) is None


@pytest.mark.parametrize("name", sorted(TRANSPORTS))
def test_transport_delivers_fresh_copy_or_reference(name):
    """Serialized/disk transports must deliver a COPY (mutating the
    producer's tree after publish must not corrupt the consumer's view)."""
    store = VersionedWeightStore(transport=TRANSPORTS[name]())
    params = _params(7)
    store.publish(params, 7)
    params["w"][:] = -1.0          # producer mutates after publish
    got, _ = store.acquire()
    if name == "nccl_direct":      # reference semantics by design
        np.testing.assert_allclose(np.asarray(got["w"]), -1.0)
    else:
        _assert_tree_matches(got, 7)


# ---------------------------------------------------------------------------
# concurrent publish/acquire stress
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TRANSPORTS))
def test_concurrent_publish_acquire_stress(name):
    """One publisher racing several drain-respecting consumers: every
    consumer must observe strictly increasing versions, never a torn
    payload, and must reach the final version."""
    n_versions, n_consumers = 25, 4
    store = VersionedWeightStore(transport=TRANSPORTS[name]())
    errors = []
    done = threading.Event()

    def publisher():
        try:
            for v in range(n_versions):
                store.begin_publish()
                store.publish(_params(v), v)
        except Exception as e:       # noqa: BLE001
            errors.append(("publisher", e))
        finally:
            done.set()

    def consumer(idx):
        last = -1
        try:
            while last < n_versions - 1:
                got = store.acquire(newer_than=last, timeout=5.0)
                if got is None:
                    if done.is_set() and store.version() == last:
                        break
                    continue
                tree, version = got
                assert version > last, (idx, version, last)
                _assert_tree_matches(tree, version)
                last = version
            assert last == n_versions - 1, (idx, last)
        except Exception as e:       # noqa: BLE001
            errors.append((f"consumer-{idx}", e))

    threads = [threading.Thread(target=consumer, args=(i,))
               for i in range(n_consumers)]
    threads.append(threading.Thread(target=publisher))
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert not any(t.is_alive() for t in threads), "stress test deadlocked"
    assert not errors, errors
