"""Unit + property tests for the paper's core math: GAE value
recomputation, GIPO, lagged advantage normalization, DWR."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (test extra)")
from hypothesis import given, settings, strategies as st

from repro.core import advnorm, gae, gipo
from repro.core.resampler import DynamicWeightedResampler

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# GAE
# ---------------------------------------------------------------------------

@given(b=st.integers(1, 4), t=st.integers(1, 12),
       discount=st.floats(0.5, 0.999), lam=st.floats(0.0, 1.0),
       seed=st.integers(0, 1000))
def test_gae_matches_reference(b, t, discount, lam, seed):
    rng = np.random.default_rng(seed)
    values = rng.standard_normal((b, t + 1)).astype(np.float32)
    rewards = rng.standard_normal((b, t)).astype(np.float32)
    dones = (rng.random((b, t)) < 0.2).astype(np.float32)
    adv, ret = gae.gae(jnp.asarray(values), jnp.asarray(rewards),
                       jnp.asarray(dones), discount, lam)
    adv_ref, ret_ref = gae.gae_reference(values, rewards, dones, discount,
                                         lam)
    np.testing.assert_allclose(np.asarray(adv), adv_ref, rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(ret), ret_ref, rtol=2e-4,
                               atol=2e-4)


def test_gae_blocks_value_flow_across_done():
    """No bootstrap across natural termination."""
    values = jnp.array([[0.0, 100.0, 0.0]])      # huge value after done
    rewards = jnp.array([[1.0, 0.0]])
    dones = jnp.array([[1.0, 0.0]])
    adv, _ = gae.gae(values, rewards, dones, 0.99, 0.95)
    # step 0 advantage must not see the 100 (done masks the bootstrap)
    assert abs(float(adv[0, 0]) - 1.0) < 1e-6


def test_jit_gae_detaches_bootstrap():
    def loss(values):
        adv, ret = gae.jit_gae_from_forward(
            values, jnp.ones((1, 2)), jnp.zeros((1, 2)), 0.9, 0.9)
        return jnp.sum(adv)
    g = jax.grad(loss)(jnp.ones((1, 3)))
    assert np.allclose(np.asarray(g), 0.0)       # fully detached


# ---------------------------------------------------------------------------
# GIPO (eqs. 5–6)
# ---------------------------------------------------------------------------

@given(lr=st.floats(-3, 3), sigma=st.floats(0.05, 2.0))
def test_trust_weight_bounds(lr, sigma):
    w = float(gipo.gaussian_trust_weight(jnp.asarray(lr), sigma))
    assert 0.0 <= w <= 1.0
    assert w == pytest.approx(np.exp(-0.5 * (lr / sigma) ** 2), rel=1e-5)


def test_gipo_equals_pg_when_on_policy():
    """ρ = 1 ⇒ ω = 1 and GIPO reduces to the vanilla PG surrogate."""
    rng = np.random.default_rng(0)
    logp = jnp.asarray(rng.standard_normal((2, 5, 3)), jnp.float32)
    adv = jnp.asarray(rng.standard_normal((2, 5)), jnp.float32)
    mask = jnp.ones((2, 5))
    loss, metrics = gipo.gipo_loss(logp, logp, adv, mask, sigma=0.2)
    expected = -float(jnp.mean(adv[..., None] * jnp.ones_like(logp)))
    assert float(loss) == pytest.approx(expected, rel=1e-5)
    assert float(metrics["omega_mean"]) == pytest.approx(1.0, rel=1e-6)


def test_gipo_keeps_gradient_where_ppo_clips():
    """The central algorithmic claim: for stale data (|log ρ| large), PPO's
    clip zeroes the gradient while GIPO's smooth weight keeps signal."""
    logp_old = jnp.full((1, 1, 1), -4.0)
    adv = jnp.ones((1, 1))
    mask = jnp.ones((1, 1))

    def g(fn, lp):
        return float(jax.grad(
            lambda x: fn(x, logp_old, adv, mask)[0])(lp)[0, 0, 0])

    lp_new = jnp.full((1, 1, 1), -3.0)    # log ratio = +1 (very stale)
    ppo_grad = g(lambda *a: gipo.ppo_loss(*a, clip_eps=0.2), lp_new)
    gipo_grad = g(lambda *a: gipo.gipo_loss(*a, sigma=0.5), lp_new)
    assert ppo_grad == 0.0
    assert gipo_grad != 0.0


@given(sigma=st.floats(0.1, 1.0), drift=st.floats(0.0, 2.0))
def test_gipo_loss_magnitude_bounded(sigma, drift):
    """ω·ρ = exp(−½(x/σ)² + x) is bounded ⇒ no divergence however stale."""
    x = np.linspace(-drift, drift, 50)
    vals = np.exp(-0.5 * (x / sigma) ** 2 + x)
    assert np.all(vals <= np.exp(0.5 * sigma ** 2) + 1e-6)


# ---------------------------------------------------------------------------
# Lagged global advantage normalization (eq. 8, App. C.2)
# ---------------------------------------------------------------------------

@given(n=st.integers(2, 6), seed=st.integers(0, 100))
def test_welford_matches_two_pass(n, seed):
    rng = np.random.default_rng(seed)
    batches = [rng.standard_normal(rng.integers(2, 50)).astype(np.float32)
               for _ in range(n)]
    state = advnorm.init_adv_state()
    for b in batches:
        stats = advnorm.local_stats(jnp.asarray(b), jnp.ones_like(
            jnp.asarray(b)))
        state = advnorm.welford_update(state, stats)
    allv = np.concatenate(batches)
    assert float(state.mean) == pytest.approx(float(allv.mean()), abs=1e-4)
    assert float(state.std) == pytest.approx(float(allv.std()), abs=1e-3)


def test_lagged_norm_uses_previous_stats():
    state = advnorm.init_adv_state()
    adv1 = jnp.asarray(np.random.default_rng(0).standard_normal(100) * 5 + 3,
                       jnp.float32)
    # first batch: no stats yet -> passthrough
    out1 = advnorm.normalize_lagged(adv1, state)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(adv1), rtol=1e-5)
    state = advnorm.welford_update(
        state, advnorm.local_stats(adv1, jnp.ones_like(adv1)))
    # second batch: normalized with batch-1 stats (eq. 8)
    adv2 = jnp.ones(10)
    out2 = advnorm.normalize_lagged(adv2, state)
    expected = (1.0 - float(state.mean)) / (float(state.std) + 1e-8)
    assert np.allclose(np.asarray(out2), expected, rtol=1e-4)


def test_packed_stats_single_collective_shape():
    stats = advnorm.local_stats(jnp.ones((4, 7)), jnp.ones((4, 7)))
    assert stats.shape == (3,)      # ONE packed (sum, sum², count) vector


# ---------------------------------------------------------------------------
# Dynamic Weighted Resampling (App. D.4)
# ---------------------------------------------------------------------------

def test_dwr_weights_failures():
    r = DynamicWeightedResampler(num_tasks=3, window_size=10, eps=1.0)
    for _ in range(10):
        r.update_history(0, 1.0)    # task 0 always succeeds
    for _ in range(10):
        r.update_history(1, 0.0)    # task 1 always fails
    p = r.probabilities()
    assert p[1] > p[2]              # failing task oversampled
    assert p[2] == pytest.approx(p[0])   # untouched == all-success (ones init)
    assert p.min() > 0              # eps keeps every task alive
    assert p.sum() == pytest.approx(1.0)


@given(st.integers(2, 8))
def test_dwr_uniform_at_init(num_tasks):
    r = DynamicWeightedResampler(num_tasks=num_tasks)
    p = r.probabilities()
    np.testing.assert_allclose(p, 1.0 / num_tasks, rtol=1e-6)


# ---------------------------------------------------------------------------
# Fused-loss path (kernels/dispatch.py): property parity with the reference
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _fused_parity_fixture():
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.core.train_step import init_train_state
    cfg = reduced(get_config("deepseek-7b"), layers=1, d_model=32)
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    return cfg, state


@given(b=st.integers(1, 3), t=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_fused_loss_property_parity(b, t, seed):
    """rl.fused_loss=True matches the reference path to fp32 tolerance on
    the loss AND every parameter gradient, for arbitrary batch shapes
    (including token counts ragged vs the kernel block size)."""
    import dataclasses
    from repro.configs.base import RLConfig
    from repro.core.train_step import loss_fn
    from repro.data.trajectory import dummy_batch

    cfg, state = _fused_parity_fixture()
    batch = dummy_batch(b, t, 5, cfg.action_dim, cfg.vocab_size,
                        cfg.action_vocab_size, seed=seed)
    rl_ref = RLConfig(grad_accum=1, entropy_coef=0.01)
    rl_fused = dataclasses.replace(rl_ref, fused_loss=True)

    (l_ref, _), g_ref = jax.value_and_grad(
        lambda p: loss_fn(p, batch, state.adv_norm, cfg, rl_ref),
        has_aux=True)(state.params)
    (l_f, _), g_f = jax.value_and_grad(
        lambda p: loss_fn(p, batch, state.adv_norm, cfg, rl_fused),
        has_aux=True)(state.params)

    assert float(l_f) == pytest.approx(float(l_ref), rel=1e-5, abs=1e-6)
    for (path, a), (_, b_) in zip(
            jax.tree_util.tree_leaves_with_path(g_ref),
            jax.tree_util.tree_leaves_with_path(g_f)):
        scale = float(jnp.max(jnp.abs(a))) + 1e-8
        diff = float(jnp.max(jnp.abs(a - b_)))
        assert diff <= 1e-5 + 1e-4 * scale, (path, diff, scale)
