"""Chaos e2e (ISSUE 6 acceptance): SIGKILL the TransportServer process
mid-PutStream, bring up a replacement on the same port with the journal
resumed, and prove the recovery invariants end to end:

  * exactly-once experience delivery — no lost AND no duplicated items
    across the server death (the in-flight window replays, the recovered
    watermark dedups);
  * the producer redials the replacement transparently (within its
    reconnect budget) and keeps streaming;
  * weight consumers re-acquire the correct latest published version
    from the recovered store, and publishes continue past it;
  * ``server.stats`` on the replacement shows the recovery happened
    (recovered item/stream counts, a compacted journal generation).

The kill is DETERMINISTIC, not wall-clock timed: the server child runs
under ``REPRO_FAULTS=kill@server.stream_applied:nth=K``, so it SIGKILLs
itself immediately after applying+journaling the K-th stream frame but
BEFORE acking it — the exact crash window the journal's apply-then-append
ordering defends (see resilience.py). The replacement child starts with
the env gate off, proving the fault layer is also scoped per-process.

Runs real subprocesses; CI executes this file in the dedicated
``chaos-smoke`` job under a hard SIGKILL timeout, not in tier 1.
"""
import os
import pathlib
import subprocess
import sys
import threading
import time

import numpy as np

from repro.runtime.transport import (PutStream, SocketChannel,
                                     WeightStoreTransport, WireClient)

_SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")

# child body: a journaled TransportServer hosting one channel + the
# weight store; prints READY <port> once serving, runs until killed (or
# until stdin closes, so a failing parent never leaks it)
_SERVER_PROG = """
import sys
from repro.runtime.experience import FifoChannel
from repro.runtime.transport.resilience import TransportJournal
from repro.runtime.transport.server import TransportServer
from repro.runtime.weight_store import VersionedWeightStore

jdir, port, resume = sys.argv[1], int(sys.argv[2]), sys.argv[3] == "resume"
journal = TransportJournal(jdir, compact_bytes=1 << 30, resume=resume)
store = VersionedWeightStore()
journal.attach_store(store)
chan = journal.wrap("exp", FifoChannel(1 << 17))
srv = TransportServer(port=port, journal=journal)
srv.add_channel("exp", chan)
srv.set_store(store)
if resume:
    srv.resume_from_journal()
srv.start()
print("READY", srv.address[1], flush=True)
sys.stdin.read()
srv.stop()
srv.join()
"""


def _spawn_server(jdir, port, resume, faults=None):
    env = {k: v for k, v in os.environ.items() if k != "REPRO_FAULTS"}
    env["PYTHONPATH"] = _SRC
    if faults:
        env["REPRO_FAULTS"] = faults
    proc = subprocess.Popen(
        [sys.executable, "-c", _SERVER_PROG, str(jdir), str(port),
         "resume" if resume else "fresh"],
        env=env, stdin=subprocess.PIPE, stdout=subprocess.PIPE,
        stderr=subprocess.PIPE, text=True)
    line = proc.stdout.readline()
    if not line.startswith("READY"):
        proc.kill()
        raise AssertionError(
            f"server child never came up: {line!r} / {proc.stderr.read()}")
    return proc, int(line.split()[1])


def _item(i):
    return {"i": np.int32(i), "x": np.full(64, float(i), np.float32)}


def _reap(proc):
    if proc.poll() is None:
        proc.kill()
    proc.wait(timeout=10.0)


def test_server_sigkill_midstream_exactly_once_recovery(tmp_path):
    jdir = tmp_path / "journal"
    total, flush = 200, 4
    kill_at = 13                       # SIGKILL after frame 13 is applied
                                       # + journal-buffered but NOT yet
                                       # group-committed or acked
    server_a, port = _spawn_server(
        jdir, 0, resume=False,
        faults=f"kill@server.stream_applied:nth={kill_at}")
    addr = ("127.0.0.1", port)
    replacement = []

    def replace_when_dead():
        server_a.wait()
        replacement.append(_spawn_server(jdir, port, resume=True)[0])

    watcher = threading.Thread(target=replace_when_dead, daemon=True)
    watcher.start()
    stream = SocketChannel = None      # for finally-cleanup clarity
    try:
        weights = WeightStoreTransport(addr, reconnect_attempts=400,
                                       reconnect_backoff_s=0.05)
        weights.publish({"w": np.arange(8, dtype=np.float32)}, 1)
        got = weights.acquire(newer_than=-1, timeout=5.0)
        assert got is not None and got[1] == 1

        stream = PutStream(addr, "exp", window=4, stream_id="chaos",
                           reconnect_attempts=400,
                           reconnect_backoff_s=0.05)
        for base in range(0, total, flush):
            stream.put_many([_item(base + j) for j in range(flush)])
        assert stream.flush(120.0), stream.stats()
        st = stream.stats()
        assert st["items_acked"] == total
        assert stream.reconnects >= 1, \
            "the producer never had to redial — the server did not die?"
        watcher.join(timeout=30.0)
        assert replacement, "no replacement server came up"
        assert server_a.returncode == -9, \
            f"server A should die by SIGKILL, got {server_a.returncode}"

        # -- zero experience loss, zero duplication --------------------------
        from repro.runtime.transport import SocketChannel as _SC
        pop = _SC(addr, "exp")
        ids = []
        deadline = time.monotonic() + 60.0
        while len(ids) < total and time.monotonic() < deadline:
            got = pop.pop_many(total, timeout=1.0)
            if got:
                ids.extend(int(g["i"]) for g in got)
        assert sorted(ids) == list(range(total)), (
            f"exactly-once violated across server death: {len(ids)} items, "
            f"{len(ids) - len(set(ids))} dup(s)")

        # -- recovery + monotone accounting on the replacement ---------------
        ctl = WireClient(addr)
        resp, _ = ctl.request({"m": "server.stats"})
        stats = resp["stats"]
        # server A group-commits each frame's journal record with its ack
        # reply (window=4 -> ack_every=1). The kill fires after frame
        # `kill_at` was applied and BUFFERED but before its ack flushed
        # it, so exactly the first kill_at-1 frames are in the committed
        # journal — frame kill_at itself is the crash window the data
        # path heals: never acked, so the producer replayed it to the
        # replacement, which applied it fresh (no dup, no loss, as the
        # pop sweep above proved). Compaction bumped the generation.
        committed = kill_at - 1
        assert stats["journal_recovered_items"] == float(committed * flush)
        assert stats["journal_recovered_streams"] == 1.0
        assert stats["journal_gen"] >= 1.0
        assert stats["stream_items"] == float(total - committed * flush)
        ctl.close()

        # -- weight consumers re-acquire the recovered latest version --------
        got = weights.acquire(newer_than=-1, timeout=10.0)
        assert got is not None and got[1] == 1, \
            "replacement must serve the recovered publish"
        np.testing.assert_array_equal(got[0]["w"],
                                      np.arange(8, dtype=np.float32))
        weights.publish({"w": np.arange(8, dtype=np.float32) * 3}, 2)
        got = weights.acquire(newer_than=1, timeout=10.0)
        assert got is not None and got[1] == 2
        pop.close()
        weights.close()
        if stream is not None:
            stream.close()
    finally:
        _reap(server_a)
        for proc in replacement:
            _reap(proc)


def test_replacement_without_resume_flag_refuses_loudly(tmp_path):
    """Operator-error guard, end to end: pointing a FRESH server at a
    journal directory that already holds recoverable state must fail the
    process with the actionable error, not silently shadow the state."""
    jdir = tmp_path / "journal"
    server_a, port = _spawn_server(jdir, 0, resume=False)
    _reap(server_a)
    env = {k: v for k, v in os.environ.items() if k != "REPRO_FAULTS"}
    env["PYTHONPATH"] = _SRC
    proc = subprocess.run(
        [sys.executable, "-c", _SERVER_PROG, str(jdir), str(port), "fresh"],
        env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode != 0
    assert "resume" in proc.stderr, proc.stderr
