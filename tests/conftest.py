import os

# Smoke tests and benches see the single real CPU device; ONLY the dry-run
# driver (repro.launch.dryrun) forces 512 placeholder devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
