"""Transport-layer contract tests (no subprocesses — real sockets/SHM,
both ends in-process): the ExperienceChannel semantics across the wire
(backpressure verdicts, batched put_many, blocking pops,
close-while-blocked), WireClient reconnect after a server-side drop, the
WeightStoreTransport parity with the local store (drain protocol
included), the SHM orphan sweep, and the worker-report metrics bridge."""
import multiprocessing
import os
import socket
import threading
import time

import numpy as np
import pytest

from repro.runtime.experience import FifoChannel, RingChannel
from repro.runtime.service import MetricsRegistry
from repro.runtime.transport import (RemoteWorkerSpec, RestartPolicy,
                                     ShmChannel, SocketChannel, Supervisor,
                                     TransportError, TransportServer,
                                     WeightStoreTransport)
from repro.runtime.transport.channel import shared_memory
from repro.runtime.weight_store import VersionedWeightStore


@pytest.fixture()
def server():
    srv = TransportServer()
    store = VersionedWeightStore()
    srv.set_store(store)
    srv.start()
    srv.local_store = store
    yield srv
    srv.stop()
    srv.join()


def _channel(server, cls=SocketChannel, capacity=8, policy="drop_oldest",
             name=None, **kw):
    name = name or f"chan-{len(server._channels)}"
    local = FifoChannel(capacity, policy=policy, block_timeout=0.2)
    server.add_channel(name, local)
    remote = cls(server.address, name, **kw)
    return local, remote


# ---------------------------------------------------------------------------
# SocketChannel: the ExperienceChannel contract over the wire
# ---------------------------------------------------------------------------

def test_socket_channel_roundtrip(server):
    local, remote = _channel(server)
    item = {"x": np.arange(6, dtype=np.float32), "v": np.int32(3)}
    assert remote.put(item)
    assert len(remote) == 1 == len(local)
    got = remote.pop_batch(1, timeout=1.0)
    np.testing.assert_array_equal(got[0]["x"], item["x"])
    assert isinstance(got[0]["v"], np.int32)
    assert remote.stats()["pushed"] == 1.0


@pytest.mark.parametrize("policy,expect_ok", [("drop_oldest", True),
                                              ("drop_newest", False),
                                              ("block", False)])
def test_backpressure_verdict_crosses_the_wire(server, policy, expect_ok):
    """The server-side policy decides; the producer's boolean verdict is
    the same one the in-process channel would have returned."""
    local, remote = _channel(server, capacity=2, policy=policy)
    assert remote.put({"i": np.int32(0)})
    assert remote.put({"i": np.int32(1)})
    assert remote.put({"i": np.int32(2)}) is expect_ok   # channel is full
    assert local.total_dropped == 1


def test_block_policy_unblocks_on_remote_consumer(server):
    # the server-side channel blocks the remote producer until the LOCAL
    # consumer (the parent trainer, in the real topology) frees a slot
    local = FifoChannel(1, policy="block", block_timeout=2.0)
    server.add_channel("blk", local)
    r = SocketChannel(server.address, "blk")
    assert r.put({"i": np.int32(0)})
    t = threading.Thread(
        target=lambda: (time.sleep(0.1), local.pop_batch(1, timeout=1.0)))
    t.start()
    t0 = time.monotonic()
    assert r.put({"i": np.int32(1)})      # held until the pop frees a slot
    assert time.monotonic() - t0 >= 0.05
    t.join()
    r.close()


def test_pop_timeout_and_zero_timeout(server):
    _, remote = _channel(server)
    t0 = time.monotonic()
    assert remote.pop_batch(1, timeout=0.3) is None
    assert 0.25 <= time.monotonic() - t0 < 2.0
    assert remote.pop_batch(1, timeout=0) is None       # non-blocking probe
    remote.put({"i": np.int32(0)})
    assert remote.pop_batch(1, timeout=0) is not None


def test_close_unblocks_remote_pop(server):
    """Satellite acceptance: close() while a remote pop_batch is blocked
    returns None promptly (within one poll slice), it does not hang; the
    channel then degrades to no-op puts."""
    _, remote = _channel(server)
    out = []
    t = threading.Thread(
        target=lambda: out.append(remote.pop_batch(4, timeout=60.0)))
    t.start()
    time.sleep(0.2)
    t0 = time.monotonic()
    remote.close()
    t.join(timeout=5.0)
    assert not t.is_alive(), "close() left pop_batch hanging"
    assert time.monotonic() - t0 < 2.0
    assert out == [None]
    assert remote.put({"i": np.int32(0)}) is False      # no exception storm
    assert len(remote) == 0


def test_server_stop_unblocks_remote_pop(server):
    _, remote = _channel(server)
    out = []
    t = threading.Thread(
        target=lambda: out.append(remote.pop_batch(4, timeout=60.0)))
    t.start()
    time.sleep(0.2)
    server.stop()
    t.join(timeout=5.0)
    assert not t.is_alive(), "server shutdown left pop_batch hanging"
    assert out == [None]


def test_unknown_channel_is_a_transport_error(server):
    remote = SocketChannel(server.address, "nope")
    with pytest.raises(TransportError):
        remote.put({"i": np.int32(0)})
    with pytest.raises(TransportError):
        remote.stats()
    remote.close()


def test_put_many_one_roundtrip_with_per_item_verdicts(server):
    """A whole flush crosses the wire as ONE codec blob/RPC, and the
    server answers the same per-item verdict vector the in-process
    channel would have produced."""
    local, remote = _channel(server, capacity=4, policy="drop_newest")
    items = [{"i": np.int32(i), "x": np.full(8, float(i), np.float32)}
             for i in range(6)]
    before = server.metrics.counter("requests")
    verdicts = remote.put_many(items)
    assert server.metrics.counter("requests") == before + 1
    assert verdicts == [True] * 4 + [False] * 2  # capacity-4 drop_newest
    assert len(local) == 4
    got = remote.pop_batch(4, timeout=1.0)
    np.testing.assert_array_equal(got[2]["x"], items[2]["x"])
    assert remote.put_many([]) == []


def test_put_many_after_close_is_all_false(server):
    _, remote = _channel(server)
    remote.close()
    assert remote.put_many([{"i": np.int32(0)}] * 3) == [False] * 3


def test_ring_channel_over_the_wire(server):
    ring = RingChannel(8, seed=0)
    server.add_channel("ring", ring)
    remote = SocketChannel(server.address, "ring")
    for i in range(12):
        assert remote.put({"i": np.int32(i)})
    assert len(remote) == 8
    assert ring.sample(3) is not None
    remote.close()


# ---------------------------------------------------------------------------
# ShmChannel: same protocol, shared-memory data plane
# ---------------------------------------------------------------------------

@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_shm_channel_large_and_small_payloads(server):
    _, remote = _channel(server, cls=ShmChannel, capacity=8,
                         shm_threshold=256)
    small = {"x": np.ones(4, np.float32)}                # in-band
    big = {"w": np.arange(4096, dtype=np.float32)}       # out-of-band
    assert remote.put(small) and remote.put(big)
    got = remote.pop_batch(2, timeout=1.0)
    np.testing.assert_array_equal(got[0]["x"], small["x"])
    np.testing.assert_array_equal(got[1]["w"], big["w"])
    remote.close()


# ---------------------------------------------------------------------------
# WeightStoreTransport: remote publish/acquire with the drain protocol
# ---------------------------------------------------------------------------

def _params(v):
    return {"w": np.full((4, 3), np.float32(v)),
            "nested": {"b": np.arange(6, dtype=np.float32) + v}}


def test_weight_transport_acquire_parity(server):
    remote = WeightStoreTransport(server.address, state_ttl=0.0)
    assert remote.acquire(timeout=0.2) is None           # nothing published
    for v in range(3):
        server.local_store.begin_publish()
        assert remote.draining, "drain signal must be visible remotely"
        server.local_store.publish(_params(v), v)
        got, version = remote.acquire(newer_than=v - 1, timeout=5.0)
        assert version == v and not remote.draining
        np.testing.assert_array_equal(got["w"], _params(v)["w"])
        np.testing.assert_array_equal(got["nested"]["b"],
                                      _params(v)["nested"]["b"])
    assert remote.acquire(newer_than=2, timeout=0.1) is None
    assert remote.version() == 2
    remote.close()


def test_weight_transport_remote_publish(server):
    """A trainer across the wire: remote begin_publish/publish drive the
    parent store exactly like local calls."""
    remote = WeightStoreTransport(server.address, state_ttl=0.0)
    remote.begin_publish()
    assert server.local_store.draining
    remote.publish(_params(5), 5)
    assert not server.local_store.draining
    got, version = server.local_store.acquire(newer_than=4, timeout=1.0)
    assert version == 5
    np.testing.assert_array_equal(got["w"], _params(5)["w"])
    remote.close()


def test_weight_transport_close_unblocks_acquire(server):
    remote = WeightStoreTransport(server.address)
    out = []
    t = threading.Thread(
        target=lambda: out.append(remote.acquire(timeout=60.0)))
    t.start()
    time.sleep(0.2)
    remote.close()
    t.join(timeout=5.0)
    assert not t.is_alive() and out == [None]


def test_weights_encoded_once_per_version(server):
    """The server cache-serves one encoded blob per version — the
    broadcast cost is O(1) in the number of remote consumers."""
    server.local_store.publish(_params(1), 1)
    clients = [WeightStoreTransport(server.address) for _ in range(3)]
    for c in clients:
        got, v = c.acquire(timeout=5.0)
        assert v == 1
    assert server._weights_cache[0] == 1
    for c in clients:
        c.close()


# ---------------------------------------------------------------------------
# WireClient reconnect-with-backoff after a server-side connection drop
# ---------------------------------------------------------------------------

def _drop_server_side(server):
    """Kill every live connection from the SERVER side — the failure a
    reconnecting client must survive."""
    with server._conn_lock:
        conns = list(server._conns)
    for c in conns:
        try:
            c.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass


def test_socket_channel_resumes_after_server_side_drop(server):
    local, remote = _channel(server, reconnect_attempts=5,
                             reconnect_backoff_s=0.02)
    assert remote.put({"i": np.int32(0)})
    _drop_server_side(server)
    assert remote.put({"i": np.int32(1)})     # transparently redialed
    assert remote._client.reconnects >= 1
    assert not remote.closed
    assert len(local) == 2


def test_no_reconnect_budget_fails_fast(server):
    """PR 3 semantics are the default: no redial budget means a dropped
    connection degrades to no-op puts immediately."""
    _, remote = _channel(server)
    assert remote.put({"i": np.int32(0)})
    _drop_server_side(server)
    assert remote.put({"i": np.int32(1)}) is False
    assert remote.closed


def test_weight_transport_reacquires_version_after_drop(server):
    """A drop may hide publishes behind the state-cache TTL: the
    on_reconnect hook busts the cache so the newest version is re-acquired
    on the fresh connection."""
    remote = WeightStoreTransport(server.address, state_ttl=30.0,
                                  reconnect_attempts=5,
                                  reconnect_backoff_s=0.02)
    server.local_store.publish(_params(1), 1)
    assert remote.acquire(timeout=5.0)[1] == 1
    assert remote.version() == 1
    server.local_store.publish(_params(2), 2)
    assert remote.version() == 1              # within TTL: cached state
    _drop_server_side(server)
    got, version = remote.acquire(newer_than=1, timeout=5.0)
    assert version == 2
    np.testing.assert_array_equal(got["w"], _params(2)["w"])
    assert remote.version() == 2              # cache busted on reconnect
    assert remote._client.reconnects >= 1
    remote.close()


# ---------------------------------------------------------------------------
# SHM orphan sweep: a producer SIGKILLed between create and unlink
# ---------------------------------------------------------------------------

def _shm_exists(name: str) -> bool:
    from multiprocessing import resource_tracker, shared_memory as shm_mod
    try:
        seg = shm_mod.SharedMemory(name=name)
    except FileNotFoundError:
        return False
    seg.close()
    try:    # attaching registered the name on this process's tracker —
            # undo that so the probe itself doesn't log a leak at exit
        resource_tracker.unregister(seg._name, "shared_memory")
    except Exception:
        pass
    return True


def _orphan_producer(address, name_file):
    """Child body: create a request segment, get the server's reply, then
    die by SIGKILL *before* the creator-side unlink — the leak scenario."""
    import signal
    from multiprocessing import resource_tracker
    from repro.runtime.transport.channel import WireClient, shm_write
    from repro.runtime.transport.codec import encode_pytree

    client = WireClient(tuple(address))
    body = encode_pytree({"x": np.arange(1024, dtype=np.float32)})
    seg = shm_write(body)
    client.request({"m": "chan.put", "chan": "orphan", "shm": seg.name,
                    "shm_size": len(body)})
    # keep the shared tracker's books clean (it outlives this process, so
    # it would neither unlink the segment nor forget it on its own)
    try:
        resource_tracker.unregister(seg._name, "shared_memory")
    except Exception:
        pass
    with open(name_file, "w") as f:
        f.write(seg.name)
        f.flush()
        os.fsync(f.fileno())
    os.kill(os.getpid(), signal.SIGKILL)


@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_server_sweeps_orphaned_shm_of_sigkilled_producer(tmp_path):
    """Regression (ISSUE 4): a worker killed between SHM create and its
    post-ack unlink leaks the segment; TransportServer.close() sweeps it."""
    server = TransportServer()
    server.add_channel("orphan", FifoChannel(8))
    server.start()
    name_file = tmp_path / "segname"
    ctx = multiprocessing.get_context("spawn")
    proc = ctx.Process(target=_orphan_producer,
                       args=(server.address, str(name_file)))
    proc.start()
    proc.join(timeout=60.0)
    assert not proc.is_alive()
    assert proc.exitcode == -9, "producer must die by SIGKILL"
    name = name_file.read_text().strip()
    assert name and _shm_exists(name), "segment should be orphaned (leaked)"
    server.stop()
    server.join()
    assert not _shm_exists(name), "close() must sweep the orphan"
    assert server.metrics.counter("shm_orphans_swept") >= 1


# ---------------------------------------------------------------------------
# worker-report metrics bridge (no subprocess)
# ---------------------------------------------------------------------------

def _fake_report():
    return {
        "health": {"healthy": True, "state": "running", "error": None},
        "services": {"rollout-0": {"health": {"state": "running"},
                                   "metrics": {"counters": {}}}},
        "merged": {"counters": {"env_steps": 40.0, "episodes": 5.0,
                                "successes": 2.0},
                   "gauges": {"policy_version": 3.0},
                   "series": {"return": {"count": 5, "mean": 0.4,
                                         "last": 1.0}}},
    }


def _slot(server, name="remote-rollout-9"):
    from repro.configs import get_config, reduced
    from repro.configs.base import RLConfig, RuntimeConfig
    spec = RemoteWorkerSpec(name=name,
                            cfg=reduced(get_config("deepseek-7b")),
                            rl=RLConfig(), rt=RuntimeConfig(),
                            address=server.address)
    # never started: the slot is used as a pure report bridge
    return Supervisor(server, RestartPolicy()).add_spawned(spec)


def test_slot_mirrors_remote_report(server):
    host = _slot(server)
    host.apply_report(_fake_report())
    assert host.env_steps == 40 and host.episodes_done == 5
    assert host.successes == 2
    assert host.returns == [0.4] * 5            # count-weighted expansion
    snap = host.metrics.snapshot()
    assert snap["counters"]["env_steps"] == 40.0
    assert snap["gauges"]["policy_version"] == 3.0
    assert snap["series"]["return"] == {"count": 5, "mean": 0.4,
                                        "last": 1.0}
    assert host.metrics.series_mean("return") == 0.4
    assert "rollout-0" in host.remote_services


def test_slot_flags_unhealthy_report(server):
    host = _slot(server, name="remote-rollout-8")
    report = _fake_report()
    report["health"] = {"healthy": False, "state": "failed",
                        "error": "RuntimeError('boom')"}
    host.apply_report(report)
    assert host._remote_error is not None and "boom" in host._remote_error


def test_slot_drops_stale_incarnation_reports(server):
    """Idempotent bridging across restarts: a zombie incarnation's report
    neither lands in the registry nor bumps reports_seen — and its reply
    would carry the stop flag."""
    host = _slot(server, name="remote-rollout-7")
    host.apply_report(_fake_report(), incarnation=0)
    assert host.reports_seen == 1
    host.incarnation = 1                        # supervisor moved on
    host.metrics.begin_remote_incarnation()
    host.apply_report(_fake_report(), incarnation=0)     # zombie
    assert host.reports_seen == 1
    assert host.stop_for(0) and not host.stop_for(1)
    host.apply_report(_fake_report(), incarnation=1)     # replacement
    assert host.reports_seen == 2
    # the dead incarnation's 40 steps stay; the new one's 40 stack on top
    assert host.env_steps == 80


def test_metrics_registry_apply_remote_merges_local_series():
    m = MetricsRegistry("t")
    m.apply_remote({"counters": {"c": 5.0}, "gauges": {},
                    "series": {"remote_only": {"count": 2, "mean": 1.5,
                                               "last": 2.0}}})
    m.record("local_only", 4.0)
    snap = m.snapshot()
    assert snap["counters"]["c"] == 5.0
    assert snap["series"]["remote_only"]["mean"] == 1.5
    assert snap["series"]["local_only"]["mean"] == 4.0
    assert m.series_mean("remote_only") == 1.5
    assert m.series_mean("local_only") == 4.0
    assert m.series_mean("absent", default=-1.0) == -1.0
