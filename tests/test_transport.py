"""Transport-layer contract tests (no subprocesses — real sockets/SHM,
both ends in-process): the ExperienceChannel semantics across the wire
(backpressure verdicts, blocking pops, close-while-blocked), the
WeightStoreTransport parity with the local store (drain protocol
included), and the worker-report metrics bridge."""
import threading
import time

import numpy as np
import pytest

from repro.runtime.experience import FifoChannel, RingChannel
from repro.runtime.service import MetricsRegistry
from repro.runtime.transport import (RemoteRolloutHost, RemoteWorkerSpec,
                                     ShmChannel, SocketChannel,
                                     TransportError, TransportServer,
                                     WeightStoreTransport)
from repro.runtime.transport.channel import shared_memory
from repro.runtime.weight_store import VersionedWeightStore


@pytest.fixture()
def server():
    srv = TransportServer()
    store = VersionedWeightStore()
    srv.set_store(store)
    srv.start()
    srv.local_store = store
    yield srv
    srv.stop()
    srv.join()


def _channel(server, cls=SocketChannel, capacity=8, policy="drop_oldest",
             name=None, **kw):
    name = name or f"chan-{len(server._channels)}"
    local = FifoChannel(capacity, policy=policy, block_timeout=0.2)
    server.add_channel(name, local)
    remote = cls(server.address, name, **kw)
    return local, remote


# ---------------------------------------------------------------------------
# SocketChannel: the ExperienceChannel contract over the wire
# ---------------------------------------------------------------------------

def test_socket_channel_roundtrip(server):
    local, remote = _channel(server)
    item = {"x": np.arange(6, dtype=np.float32), "v": np.int32(3)}
    assert remote.put(item)
    assert len(remote) == 1 == len(local)
    got = remote.pop_batch(1, timeout=1.0)
    np.testing.assert_array_equal(got[0]["x"], item["x"])
    assert isinstance(got[0]["v"], np.int32)
    assert remote.stats()["pushed"] == 1.0


@pytest.mark.parametrize("policy,expect_ok", [("drop_oldest", True),
                                              ("drop_newest", False),
                                              ("block", False)])
def test_backpressure_verdict_crosses_the_wire(server, policy, expect_ok):
    """The server-side policy decides; the producer's boolean verdict is
    the same one the in-process channel would have returned."""
    local, remote = _channel(server, capacity=2, policy=policy)
    assert remote.put({"i": np.int32(0)})
    assert remote.put({"i": np.int32(1)})
    assert remote.put({"i": np.int32(2)}) is expect_ok   # channel is full
    assert local.total_dropped == 1


def test_block_policy_unblocks_on_remote_consumer(server):
    # the server-side channel blocks the remote producer until the LOCAL
    # consumer (the parent trainer, in the real topology) frees a slot
    local = FifoChannel(1, policy="block", block_timeout=2.0)
    server.add_channel("blk", local)
    r = SocketChannel(server.address, "blk")
    assert r.put({"i": np.int32(0)})
    t = threading.Thread(
        target=lambda: (time.sleep(0.1), local.pop_batch(1, timeout=1.0)))
    t.start()
    t0 = time.monotonic()
    assert r.put({"i": np.int32(1)})      # held until the pop frees a slot
    assert time.monotonic() - t0 >= 0.05
    t.join()
    r.close()


def test_pop_timeout_and_zero_timeout(server):
    _, remote = _channel(server)
    t0 = time.monotonic()
    assert remote.pop_batch(1, timeout=0.3) is None
    assert 0.25 <= time.monotonic() - t0 < 2.0
    assert remote.pop_batch(1, timeout=0) is None       # non-blocking probe
    remote.put({"i": np.int32(0)})
    assert remote.pop_batch(1, timeout=0) is not None


def test_close_unblocks_remote_pop(server):
    """Satellite acceptance: close() while a remote pop_batch is blocked
    returns None promptly (within one poll slice), it does not hang; the
    channel then degrades to no-op puts."""
    _, remote = _channel(server)
    out = []
    t = threading.Thread(
        target=lambda: out.append(remote.pop_batch(4, timeout=60.0)))
    t.start()
    time.sleep(0.2)
    t0 = time.monotonic()
    remote.close()
    t.join(timeout=5.0)
    assert not t.is_alive(), "close() left pop_batch hanging"
    assert time.monotonic() - t0 < 2.0
    assert out == [None]
    assert remote.put({"i": np.int32(0)}) is False      # no exception storm
    assert len(remote) == 0


def test_server_stop_unblocks_remote_pop(server):
    _, remote = _channel(server)
    out = []
    t = threading.Thread(
        target=lambda: out.append(remote.pop_batch(4, timeout=60.0)))
    t.start()
    time.sleep(0.2)
    server.stop()
    t.join(timeout=5.0)
    assert not t.is_alive(), "server shutdown left pop_batch hanging"
    assert out == [None]


def test_unknown_channel_is_a_transport_error(server):
    remote = SocketChannel(server.address, "nope")
    with pytest.raises(TransportError):
        remote.put({"i": np.int32(0)})
    with pytest.raises(TransportError):
        remote.stats()
    remote.close()


def test_ring_channel_over_the_wire(server):
    ring = RingChannel(8, seed=0)
    server.add_channel("ring", ring)
    remote = SocketChannel(server.address, "ring")
    for i in range(12):
        assert remote.put({"i": np.int32(i)})
    assert len(remote) == 8
    assert ring.sample(3) is not None
    remote.close()


# ---------------------------------------------------------------------------
# ShmChannel: same protocol, shared-memory data plane
# ---------------------------------------------------------------------------

@pytest.mark.skipif(shared_memory is None,
                    reason="multiprocessing.shared_memory unavailable")
def test_shm_channel_large_and_small_payloads(server):
    _, remote = _channel(server, cls=ShmChannel, capacity=8,
                         shm_threshold=256)
    small = {"x": np.ones(4, np.float32)}                # in-band
    big = {"w": np.arange(4096, dtype=np.float32)}       # out-of-band
    assert remote.put(small) and remote.put(big)
    got = remote.pop_batch(2, timeout=1.0)
    np.testing.assert_array_equal(got[0]["x"], small["x"])
    np.testing.assert_array_equal(got[1]["w"], big["w"])
    remote.close()


# ---------------------------------------------------------------------------
# WeightStoreTransport: remote publish/acquire with the drain protocol
# ---------------------------------------------------------------------------

def _params(v):
    return {"w": np.full((4, 3), np.float32(v)),
            "nested": {"b": np.arange(6, dtype=np.float32) + v}}


def test_weight_transport_acquire_parity(server):
    remote = WeightStoreTransport(server.address, state_ttl=0.0)
    assert remote.acquire(timeout=0.2) is None           # nothing published
    for v in range(3):
        server.local_store.begin_publish()
        assert remote.draining, "drain signal must be visible remotely"
        server.local_store.publish(_params(v), v)
        got, version = remote.acquire(newer_than=v - 1, timeout=5.0)
        assert version == v and not remote.draining
        np.testing.assert_array_equal(got["w"], _params(v)["w"])
        np.testing.assert_array_equal(got["nested"]["b"],
                                      _params(v)["nested"]["b"])
    assert remote.acquire(newer_than=2, timeout=0.1) is None
    assert remote.version() == 2
    remote.close()


def test_weight_transport_remote_publish(server):
    """A trainer across the wire: remote begin_publish/publish drive the
    parent store exactly like local calls."""
    remote = WeightStoreTransport(server.address, state_ttl=0.0)
    remote.begin_publish()
    assert server.local_store.draining
    remote.publish(_params(5), 5)
    assert not server.local_store.draining
    got, version = server.local_store.acquire(newer_than=4, timeout=1.0)
    assert version == 5
    np.testing.assert_array_equal(got["w"], _params(5)["w"])
    remote.close()


def test_weight_transport_close_unblocks_acquire(server):
    remote = WeightStoreTransport(server.address)
    out = []
    t = threading.Thread(
        target=lambda: out.append(remote.acquire(timeout=60.0)))
    t.start()
    time.sleep(0.2)
    remote.close()
    t.join(timeout=5.0)
    assert not t.is_alive() and out == [None]


def test_weights_encoded_once_per_version(server):
    """The server cache-serves one encoded blob per version — the
    broadcast cost is O(1) in the number of remote consumers."""
    server.local_store.publish(_params(1), 1)
    clients = [WeightStoreTransport(server.address) for _ in range(3)]
    for c in clients:
        got, v = c.acquire(timeout=5.0)
        assert v == 1
    assert server._weights_cache[0] == 1
    for c in clients:
        c.close()


# ---------------------------------------------------------------------------
# worker-report metrics bridge (no subprocess)
# ---------------------------------------------------------------------------

def _fake_report():
    return {
        "health": {"healthy": True, "state": "running", "error": None},
        "services": {"rollout-0": {"health": {"state": "running"},
                                   "metrics": {"counters": {}}}},
        "merged": {"counters": {"env_steps": 40.0, "episodes": 5.0,
                                "successes": 2.0},
                   "gauges": {"policy_version": 3.0},
                   "series": {"return": {"count": 5, "mean": 0.4,
                                         "last": 1.0}}},
    }


def test_host_mirrors_remote_report(server):
    from repro.configs import get_config, reduced
    from repro.configs.base import RLConfig, RuntimeConfig
    spec = RemoteWorkerSpec(name="remote-rollout-9",
                            cfg=reduced(get_config("deepseek-7b")),
                            rl=RLConfig(), rt=RuntimeConfig(),
                            address=server.address)
    host = RemoteRolloutHost(spec, server)      # never started: bridge only
    host.apply_report(_fake_report())
    assert host.env_steps == 40 and host.episodes_done == 5
    assert host.successes == 2
    assert host.returns == [0.4] * 5            # count-weighted expansion
    snap = host.metrics.snapshot()
    assert snap["counters"]["env_steps"] == 40.0
    assert snap["gauges"]["policy_version"] == 3.0
    assert snap["series"]["return"] == {"count": 5, "mean": 0.4,
                                        "last": 1.0}
    assert host.metrics.series_mean("return") == 0.4
    assert "rollout-0" in host.remote_services


def test_host_flags_unhealthy_report(server):
    from repro.configs import get_config, reduced
    from repro.configs.base import RLConfig, RuntimeConfig
    spec = RemoteWorkerSpec(name="remote-rollout-8",
                            cfg=reduced(get_config("deepseek-7b")),
                            rl=RLConfig(), rt=RuntimeConfig(),
                            address=server.address)
    host = RemoteRolloutHost(spec, server)
    report = _fake_report()
    report["health"] = {"healthy": False, "state": "failed",
                        "error": "RuntimeError('boom')"}
    host.apply_report(report)
    assert host._remote_error is not None and "boom" in host._remote_error


def test_metrics_registry_apply_remote_merges_local_series():
    m = MetricsRegistry("t")
    m.apply_remote({"counters": {"c": 5.0}, "gauges": {},
                    "series": {"remote_only": {"count": 2, "mean": 1.5,
                                               "last": 2.0}}})
    m.record("local_only", 4.0)
    snap = m.snapshot()
    assert snap["counters"]["c"] == 5.0
    assert snap["series"]["remote_only"]["mean"] == 1.5
    assert snap["series"]["local_only"]["mean"] == 4.0
    assert m.series_mean("remote_only") == 1.5
    assert m.series_mean("local_only") == 4.0
    assert m.series_mean("absent", default=-1.0) == -1.0
