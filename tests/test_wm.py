"""World-model tests: EDM denoiser training/sampling, the reward model,
potential-based imagined rewards (eq. 4), horizon capping (eq. 3), and the
imagination pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (test extra)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.configs.base import WMConfig
from repro.envs.toy_manipulation import FRAME_DIM
from repro.wm import denoiser as dn
from repro.wm import reward as rw
from repro.wm.imagination import imagine_rollout

settings.register_profile("wm", deadline=None, max_examples=15)
settings.load_profile("wm")

WM = WMConfig(imagine_horizon=3, history_frames=2, diffusion_steps=4)
KEY = jax.random.PRNGKey(0)


def _denoiser(frame_dim=16, action_dim=3, action_vocab=8):
    return dn.denoiser_init(KEY, frame_dim, action_dim, action_vocab, WM)


# ---------------------------------------------------------------------------
# EDM denoiser
# ---------------------------------------------------------------------------

def test_edm_preconditioning_identity_at_zero_noise():
    """As σ → 0: c_skip → 1, c_out → 0, so D(x; σ) → x."""
    p = _denoiser()
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16)),
                    jnp.float32)
    hist = jnp.zeros((2, 2, 16))
    acts = jnp.zeros((2, 3), jnp.int32)
    d = dn.denoiser_apply(p, x, jnp.full((2,), 1e-6), hist, acts,
                          sigma_data=0.5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(x), atol=1e-3)


@given(seed=st.integers(0, 50))
def test_edm_loss_finite_positive(seed):
    p = _denoiser()
    rng = np.random.default_rng(seed)
    frames = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    hist = jnp.asarray(rng.standard_normal((4, 2, 16)), jnp.float32)
    acts = jnp.asarray(rng.integers(0, 8, (4, 3)), jnp.int32)
    loss = dn.denoiser_loss(p, jax.random.PRNGKey(seed), frames, hist,
                            acts, WM)
    assert np.isfinite(float(loss)) and float(loss) >= 0.0


def test_karras_schedule_monotone():
    s = np.asarray(dn.karras_schedule(8))
    assert s[0] == pytest.approx(dn.SIGMA_MAX, rel=1e-4)
    assert s[-1] == 0.0
    assert np.all(np.diff(s) < 0)


def test_denoiser_training_reduces_loss():
    p = _denoiser()
    from repro.optim import adamw
    opt = adamw.init(p)
    step = dn.make_denoiser_train_step(WM, lr=1e-3)
    rng = np.random.default_rng(0)
    frames = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    hist = jnp.asarray(np.repeat(frames[:, None], 2, 1))
    acts = jnp.zeros((32, 3), jnp.int32)
    first = last = None
    key = jax.random.PRNGKey(1)
    for i in range(40):
        key, sub = jax.random.split(key)
        p, opt, loss = step(p, opt, sub, frames, hist, acts)
        first = first if first is not None else float(loss)
        last = float(loss)
    assert last < first


def test_sampler_shape_and_finite():
    p = _denoiser()
    hist = jnp.zeros((3, 2, 16))
    acts = jnp.zeros((3, 3), jnp.int32)
    out = dn.sample_next_frame(p, KEY, hist, acts, WM)
    assert out.shape == (3, 16)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# reward model
# ---------------------------------------------------------------------------

def test_reward_probability_range():
    p = rw.reward_init(KEY, 16)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((5, 16)) * 10,
                    jnp.float32)
    prob = rw.reward_apply(p, x)
    assert np.all((np.asarray(prob) > 0) & (np.asarray(prob) < 1))


def test_reward_learns_separable_labels():
    p = rw.reward_init(KEY, 8)
    from repro.optim import adamw
    opt = adamw.init(p)
    step = rw.make_reward_train_step(lr=5e-3)
    rng = np.random.default_rng(0)
    pos = rng.standard_normal((64, 8)).astype(np.float32) + 3
    neg = rng.standard_normal((64, 8)).astype(np.float32) - 3
    frames = np.concatenate([pos, neg])
    labels = np.concatenate([np.ones(64), np.zeros(64)]).astype(np.float32)
    for _ in range(60):
        p, opt, loss = step(p, opt, frames, labels)
    probs = np.asarray(rw.reward_apply(p, jnp.asarray(frames)))
    assert probs[:64].mean() > 0.8 and probs[64:].mean() < 0.2


# ---------------------------------------------------------------------------
# imagination (eqs. 3–4)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def imag_setup():
    import dataclasses
    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    cfg = dataclasses.replace(cfg, num_prefix_tokens=1)
    from repro.models.policy import init_policy_params
    policy = init_policy_params(cfg, KEY)
    obs_p = dn.denoiser_init(KEY, FRAME_DIM, cfg.action_dim,
                             cfg.action_vocab_size, WM)
    rew_p = rw.reward_init(KEY, FRAME_DIM)
    return cfg, policy, obs_p, rew_p


def test_imagination_shapes_and_horizon_cap(imag_setup):
    cfg, policy, obs_p, rew_p = imag_setup
    b = 2
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 12)), jnp.int32)
    frame0 = jnp.asarray(rng.random((b, FRAME_DIM)), jnp.float32)
    out = imagine_rollout(policy, obs_p, rew_p, KEY, tokens, frame0,
                          jnp.zeros((b,), jnp.int32), cfg=cfg, wm=WM)
    h = WM.imagine_horizon
    assert out["frames"].shape == (b, h + 1, FRAME_DIM)      # eq. 3: H+1
    assert out["rewards"].shape == (b, h)                    # strictly H
    assert out["actions"].shape == (b, h + 1, cfg.action_dim)
    assert np.isfinite(np.asarray(out["rewards"])).all()
    # seeded from the REAL frame: ô_t = o_t
    np.testing.assert_allclose(np.asarray(out["frames"][:, 0]),
                               np.asarray(frame0))


def test_potential_reward_telescopes(imag_setup):
    """Σ r̂ = scale·(M_r(ô_H) − M_r(ô_0)) — eq. 4 preserves policy
    invariance by telescoping."""
    cfg, policy, obs_p, rew_p = imag_setup
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
    frame0 = jnp.asarray(rng.random((1, FRAME_DIM)), jnp.float32)
    out = imagine_rollout(policy, obs_p, rew_p, KEY, tokens, frame0,
                          jnp.zeros((1,), jnp.int32), cfg=cfg, wm=WM)
    total = float(np.asarray(out["rewards"]).sum())
    p_first = float(rw.reward_apply(rew_p, out["frames"][:, 0])[0])
    p_last = float(rw.reward_apply(rew_p, out["frames"][:, -1])[0])
    assert total == pytest.approx(WM.reward_scale * (p_last - p_first),
                                  abs=1e-3)
