"""Dispatch-layer parity tests: the Pallas kernels (interpret mode) and
their streaming jnp twins must agree with the dense references on forward
values AND gradients, across dense/GQA shapes and ragged
``N % block_n != 0`` edges. Also covers mode resolution and the
fused-vs-reference trainer path (loss + parameter grads)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch, ref
from repro.kernels.gipo_loss import fused_policy_loss, gipo_head_loss

RNG = np.random.default_rng(11)
SIGMA = 0.2
TOL = dict(rtol=2e-4, atol=2e-5)


def _tok_data(n, v):
    return (jnp.asarray(RNG.integers(0, v, n), jnp.int32),
            jnp.asarray(RNG.standard_normal(n) * 0.3, jnp.float32),
            jnp.asarray(RNG.standard_normal(n), jnp.float32),
            jnp.asarray((RNG.random(n) > 0.15).astype(np.float32)))


def _combine(out):
    pg, ent, kl, _ = out
    return pg + 0.1 * kl - 0.01 * ent


def _close(a, b, **kw):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               **(kw or TOL))


# ---------------------------------------------------------------------------
# mode resolution
# ---------------------------------------------------------------------------

def test_mode_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    assert dispatch.resolve_mode() == "auto"
    assert dispatch.resolve_mode("jnp") == "jnp"
    with pytest.raises(ValueError):
        dispatch.resolve_mode("palas")      # config typo must not silently
    #                                         fall back to auto routing
    monkeypatch.setenv("REPRO_KERNELS", "pallas")
    assert dispatch.resolve_mode() == "pallas"
    assert dispatch.resolve_mode("jnp") == "pallas"       # env beats config
    with dispatch.forced("jnp"):                          # forced beats env
        assert dispatch.resolve_mode() == "jnp"
        assert not dispatch.use_pallas()
    assert dispatch.resolve_mode() == "pallas"
    monkeypatch.setenv("REPRO_KERNELS", "bogus")
    with pytest.raises(ValueError):
        dispatch.resolve_mode()
    with pytest.raises(ValueError):
        dispatch.set_mode("bogus")


def test_auto_mode_off_tpu_uses_jnp_twin(monkeypatch):
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    # conftest pins JAX_PLATFORMS=cpu, so auto must route to the twins
    assert not dispatch.use_pallas()
    assert dispatch.interpret_mode()


# ---------------------------------------------------------------------------
# fused GIPO loss: logits level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,v,block_n", [
    (64, 32, 32),            # exact multiple
    (300, 64, 128),          # ragged N % block_n
    (257, 48, 128),          # ragged by one
    (100, 256, 256),         # single partial block, full action vocab
])
@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_gipo_loss_parity(n, v, block_n, impl):
    logits = jnp.asarray(RNG.standard_normal((n, v)) * 2, jnp.float32)
    targets, logp_old, adv, mask = _tok_data(n, v)

    def fused(lg):
        if impl == "pallas":
            return gipo_head_loss(lg, targets, logp_old, adv, mask,
                                  SIGMA, block_n, True)
        return dispatch._jnp_gipo_loss(lg, targets, logp_old, adv, mask,
                                       SIGMA, block_n)

    def reference(lg):
        # identity head weight makes the hidden-level oracle a logits oracle
        return ref.reference_policy_loss(
            lg, jnp.eye(lg.shape[1], dtype=jnp.float32), targets, logp_old,
            adv, mask, SIGMA)

    got, exp = fused(logits), reference(logits)
    for g, e in zip(got[:3], exp[:3]):
        _close(g, e)
    for k in exp[3]:
        _close(got[3][k], exp[3][k])
    g_f = jax.grad(lambda lg: _combine(fused(lg)))(logits)
    g_r = jax.grad(lambda lg: _combine(reference(lg)))(logits)
    _close(g_f, g_r, rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# fused policy loss: hidden level (action head inside the kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,v,block_n", [
    (128, 32, 32, 64),
    (300, 64, 48, 128),      # ragged
    (65, 16, 256, 64),       # ragged by one, full action vocab
])
@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_policy_head_loss_parity(n, d, v, block_n, impl):
    hidden = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((d, v)) * 0.2, jnp.float32)
    targets, logp_old, adv, mask = _tok_data(n, v)

    def fused(h, w_):
        if impl == "pallas":
            return fused_policy_loss(h, w_, targets, logp_old, adv, mask,
                                     SIGMA, block_n, True)
        return dispatch._jnp_policy_loss(h, w_, targets, logp_old, adv,
                                         mask, SIGMA, block_n)

    def reference(h, w_):
        return ref.reference_policy_loss(h, w_, targets, logp_old, adv,
                                         mask, SIGMA)

    got, exp = fused(hidden, w), reference(hidden, w)
    for g, e in zip(got[:3], exp[:3]):
        _close(g, e)
    dh_f, dw_f = jax.grad(lambda h, w_: _combine(fused(h, w_)),
                          argnums=(0, 1))(hidden, w)
    dh_r, dw_r = jax.grad(lambda h, w_: _combine(reference(h, w_)),
                          argnums=(0, 1))(hidden, w)
    _close(dh_f, dh_r, rtol=5e-4, atol=5e-5)
    _close(dw_f, dw_r, rtol=5e-4, atol=5e-5)


def test_policy_head_loss_bf16_hidden():
    n, d, v = 256, 32, 64
    hidden = jnp.asarray(RNG.standard_normal((n, d)), jnp.bfloat16)
    w = jnp.asarray(RNG.standard_normal((d, v)) * 0.2, jnp.bfloat16)
    targets, logp_old, adv, mask = _tok_data(n, v)
    pg_p, *_ = fused_policy_loss(hidden, w, targets, logp_old, adv, mask,
                                 SIGMA, 128, True)
    pg_r, *_ = ref.reference_policy_loss(hidden, w, targets, logp_old, adv,
                                         mask, SIGMA)
    assert float(pg_p) == pytest.approx(float(pg_r), rel=5e-2, abs=5e-2)


# ---------------------------------------------------------------------------
# attention routing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,s,h,kv,d", [
    (1, 128, 128, 4, 4, 64),     # MHA square
    (2, 128, 128, 4, 1, 64),     # MQA
    (2, 64, 256, 8, 2, 64),      # GQA, cross lengths
    (1, 100, 100, 4, 2, 64),     # ragged vs block (padding path)
])
@pytest.mark.parametrize("window", [None, 64])
def test_attention_dispatch_parity(b, t, s, h, kv, d, window):
    q = jnp.asarray(RNG.standard_normal((b, t, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kv, d)), jnp.float32)
    with dispatch.forced("pallas"):
        out_p = dispatch.attention(q, k, v, window=window, block=64)
    with dispatch.forced("jnp"):
        out_j = dispatch.attention(q, k, v, window=window, block=64)
    exp = ref.reference_attention(q, k, v, window=window)
    _close(out_p, exp, rtol=2e-5, atol=2e-5)
    _close(out_j, exp, rtol=2e-5, atol=2e-5)

    def loss(mode):
        def f(q_, k_, v_):
            with dispatch.forced(mode):
                out = dispatch.attention(q_, k_, v_, window=window, block=64)
            return jnp.sum(out * out)
        return f
    g_p = jax.grad(loss("pallas"), argnums=(0, 1, 2))(q, k, v)
    g_j = jax.grad(loss("jnp"), argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_p, g_j):
        _close(a, b_, rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# real Pallas backward kernels (ISSUE 9): exact grad parity vs the jnp
# twins at head_dim 64 AND 128 (interpret mode; the dq and dk/dv kernels
# replay the saved LSE — any drift in the backward math shows up here)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [64, 128])
@pytest.mark.parametrize("window", [None, 32])
def test_flash_backward_kernel_grad_parity(d, window):
    b, t, s, h, kv = 1, 128, 128, 4, 2
    q = jnp.asarray(RNG.standard_normal((b, t, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kv, d)), jnp.float32)

    def loss(mode):
        def f(q_, k_, v_):
            with dispatch.forced(mode):
                out = dispatch.attention(q_, k_, v_, window=window,
                                         block=64)
            return jnp.sum(out * out)
        return f

    g_p = jax.grad(loss("pallas"), argnums=(0, 1, 2))(q, k, v)
    g_j = jax.grad(loss("jnp"), argnums=(0, 1, 2))(q, k, v)
    for got, exp in zip(g_p, g_j):
        _close(got, exp, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("d", [64, 128])
def test_flash_backward_ragged_rows_grad_parity(d):
    """Padded q rows (T % block != 0) must contribute exactly zero grad:
    the backward kernels pad the LSE with a sentinel so exp(s - LSE)
    vanishes on dead rows."""
    b, t, s, h, kv = 1, 50, 50, 4, 4
    q = jnp.asarray(RNG.standard_normal((b, t, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kv, d)), jnp.float32)

    def loss(mode):
        def f(q_, k_, v_):
            with dispatch.forced(mode):
                out = dispatch.attention(q_, k_, v_, block=64)
            return jnp.sum(out * out)
        return f

    g_p = jax.grad(loss("pallas"), argnums=(0, 1, 2))(q, k, v)
    g_j = jax.grad(loss("jnp"), argnums=(0, 1, 2))(q, k, v)
    for got, exp in zip(g_p, g_j):
        _close(got, exp, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("p", [64, 128])
def test_ssd_backward_kernel_grad_parity(p):
    """The reverse-chunk SSD kernel: grads for every input (x, dt, A, B,
    C) through BOTH outputs — a nonzero final-state cotangent seeds the
    reverse state sweep."""
    x, dt, a, bm, cm = _ssd_data(b=1, t=64, h=2, p=p, n=4, seed=9)

    def loss(mode):
        def f(x_, dt_, a_, b_, c_):
            with dispatch.forced(mode):
                y_, s_ = dispatch.ssd_scan(x_, dt_, a_, b_, c_, chunk=32)
            return jnp.sum(y_ * y_) + jnp.sum(jnp.sin(s_))
        return f

    g_p = jax.grad(loss("pallas"), argnums=(0, 1, 2, 3, 4))(x, dt, a, bm,
                                                            cm)
    g_j = jax.grad(loss("jnp"), argnums=(0, 1, 2, 3, 4))(x, dt, a, bm, cm)
    for got, exp in zip(g_p, g_j):
        _close(got, exp, rtol=2e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# decode-path routing: single-token decode + the dense small-T fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,kv,d", [
    (1, 128, 4, 4, 64),          # MHA, cache == block
    (2, 200, 8, 2, 64),          # GQA, ragged cache (kernel pads)
    (3, 33, 4, 1, 64),           # MQA, tiny cache
])
def test_decode_attention_dispatch_parity(b, s, h, kv, d):
    """The Pallas decode kernel matches the jnp twin bit-for-shape on
    data-dependent validity masks (ring gaps, short sequences)."""
    q = jnp.asarray(RNG.standard_normal((b, 1, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kv, d)), jnp.float32)
    # ring-shaped validity: random holes, but at least one live slot/row
    valid = jnp.asarray(RNG.random((b, s)) > 0.4)
    valid = valid.at[:, 0].set(True)
    with dispatch.forced("pallas"):
        out_p = dispatch.decode_attention(q, k, v, valid)
    with dispatch.forced("jnp"):
        out_j = dispatch.decode_attention(q, k, v, valid)
    _close(out_p, out_j, rtol=2e-5, atol=2e-5)


def test_decode_attention_masks_invalid_slots():
    """Fully-masked-but-one: the output must equal attending the single
    live slot exactly (masking is NEG_INF-additive, not a renormalize)."""
    b, s, h, d = 2, 64, 4, 64
    q = jnp.asarray(RNG.standard_normal((b, 1, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    valid = jnp.zeros((b, s), bool).at[:, 7].set(True)
    for mode in ("pallas", "jnp"):
        with dispatch.forced(mode):
            out = dispatch.decode_attention(q, k, v, valid)
        _close(out[:, 0], v[:, 7], rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("t,h,kv", [(16, 4, 4), (100, 8, 2)])
@pytest.mark.parametrize("window", [None, 8])
def test_dense_attention_dispatch_parity(t, h, kv, window):
    """Dense small-T fallback: both routes match the dense reference."""
    b, d = 2, 64
    q = jnp.asarray(RNG.standard_normal((b, t, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, t, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, t, kv, d)), jnp.float32)
    exp = ref.reference_attention(q, k, v, window=window)
    for mode in ("pallas", "jnp"):
        with dispatch.forced(mode):
            out = dispatch.dense_attention(q, k, v, window=window)
        _close(out, exp, rtol=2e-5, atol=2e-5)


def test_attention_decode_routes_through_dispatch():
    """models.attention.attention_decode answers identically whichever
    side dispatch routes to (the decode path is now dispatched)."""
    from repro.models.attention import (attention_decode, attention_init,
                                        attention_prefill)
    key = jax.random.PRNGKey(0)
    params = attention_init(key, 64, 4, 2, 64, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((2, 9, 64)), jnp.float32)
    outs = {}
    for mode in ("pallas", "jnp"):
        with dispatch.forced(mode):
            _, cache = attention_prefill(params, x, rope_theta=1e4,
                                         cache_len=16)
            step = jnp.ones((2, 1, 64), jnp.float32) * 0.1
            outs[mode], _ = attention_decode(params, step, cache,
                                             rope_theta=1e4)
    _close(outs["pallas"], outs["jnp"], rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd_scan routing (Mamba2): both sides match the stepwise oracle, fwd + bwd
# ---------------------------------------------------------------------------

def _ssd_data(b=2, t=64, h=3, p=8, n=4, seed=5):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((b, t, h, p)), jnp.float32),
            jnp.asarray(rng.uniform(0.01, 0.1, (b, t, h)), jnp.float32),
            -jnp.asarray(rng.uniform(0.5, 1.5, (h,)), jnp.float32),
            jnp.asarray(rng.standard_normal((b, t, n)), jnp.float32),
            jnp.asarray(rng.standard_normal((b, t, n)), jnp.float32))


@pytest.mark.parametrize("mode", ["pallas", "jnp"])
def test_ssd_scan_dispatch_parity(mode):
    x, dt, a, bm, cm = _ssd_data()
    with dispatch.forced(mode):
        y, s = dispatch.ssd_scan(x, dt, a, bm, cm, chunk=32)
    y_ref, s_ref = ref.reference_ssd(x, dt, a, bm, cm)
    _close(y, y_ref, rtol=2e-4, atol=2e-4)
    _close(s, s_ref, rtol=2e-4, atol=2e-4)

    def loss(m):
        def f(x_, dt_, b_, c_):
            with dispatch.forced(m):
                y_, s_ = dispatch.ssd_scan(x_, dt_, a, b_, c_, chunk=32)
            return jnp.sum(y_ * y_) + jnp.sum(s_)
        return f
    g_m = jax.grad(loss(mode), argnums=(0, 1, 2, 3))(x, dt, bm, cm)
    g_j = jax.grad(loss("jnp"), argnums=(0, 1, 2, 3))(x, dt, bm, cm)
    for got, exp in zip(g_m, g_j):
        _close(got, exp, rtol=2e-4, atol=2e-4)


def test_ssd_scan_ragged_length_falls_back_to_twin():
    """T not divisible by chunk is not kernel-eligible: the twin must serve
    it even when Pallas is forced (same eligibility idea as attention)."""
    x, dt, a, bm, cm = _ssd_data(t=24)
    with dispatch.forced("pallas"):
        y, s = dispatch.ssd_scan(x, dt, a, bm, cm, chunk=32)
    y_ref, s_ref = ref.reference_ssd(x, dt, a, bm, cm)
    _close(y, y_ref, rtol=2e-4, atol=2e-4)
    _close(s, s_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mode", ["pallas", "jnp"])
def test_ssm_forward_routes_through_dispatch(mode):
    """The model layer produces identical outputs on both dispatch sides."""
    from repro.configs.base import SSMConfig
    from repro.models.ssm import ssm_forward, ssm_init
    cfg = SSMConfig(state_dim=8, head_dim=4, expand=2, chunk=16)
    d_model = 16
    params = ssm_init(jax.random.PRNGKey(0), d_model, cfg, jnp.float32)
    u = jnp.asarray(RNG.standard_normal((2, 32, d_model)), jnp.float32)
    with dispatch.forced(mode):
        out = ssm_forward(params, u, d_model, cfg)
    with dispatch.forced("jnp"):
        exp = ssm_forward(params, u, d_model, cfg)
    _close(out, exp, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# seq-train path (launch/steps.py): fused loss vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["pallas", "jnp"])
def test_seq_fused_loss_matches_reference(mode):
    from repro.configs import get_config, reduced
    from repro.configs.base import RLConfig
    from repro.core.advnorm import init_adv_state
    from repro.launch.steps import seq_loss_fn
    from repro.models.policy import init_policy_params

    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    params = init_policy_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    rng = np.random.default_rng(3)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "behavior_logp": jnp.asarray(rng.standard_normal((b, s)) * 0.3,
                                     jnp.float32),
        "rewards": jnp.asarray(rng.standard_normal((b, s - 1)), jnp.float32),
        "dones": jnp.zeros((b, s - 1), jnp.float32),
        "mask": jnp.ones((b, s - 1), jnp.float32),
    }
    adv_state = init_adv_state()
    rl_ref = RLConfig(grad_accum=1)
    rl_fused = dataclasses.replace(rl_ref, fused_loss=True)

    l_ref, (m_ref, _) = seq_loss_fn(params, batch, adv_state, cfg, rl_ref,
                                    remat=False)
    g_ref = jax.grad(lambda p: seq_loss_fn(p, batch, adv_state, cfg,
                                           rl_ref, remat=False)[0])(params)
    with dispatch.forced(mode):
        l_f, (m_f, _) = seq_loss_fn(params, batch, adv_state, cfg,
                                    rl_fused, remat=False)
        g_f = jax.grad(lambda p: seq_loss_fn(p, batch, adv_state, cfg,
                                             rl_fused, remat=False)[0]
                       )(params)
    _close(l_f, l_ref, rtol=1e-5, atol=1e-6)
    for key in ("pg_loss", "value_loss", "kl"):
        _close(m_f[key], m_ref[key], rtol=1e-4, atol=1e-5)
    flat_ref = jax.tree_util.tree_leaves_with_path(g_ref)
    flat_f = dict(jax.tree_util.tree_leaves_with_path(g_f))
    assert len(flat_ref) == len(flat_f)
    for path, leaf in flat_ref:
        scale = float(jnp.max(jnp.abs(leaf))) + 1e-8
        diff = float(jnp.max(jnp.abs(leaf - flat_f[path])))
        assert diff <= 1e-5 + 1e-4 * scale, (path, diff, scale)


# ---------------------------------------------------------------------------
# trainer-path parity: fused loss vs reference (loss AND parameter grads)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["pallas", "jnp"])
def test_fused_train_loss_matches_reference(mode):
    from repro.configs import get_config, reduced
    from repro.configs.base import RLConfig
    from repro.core.train_step import init_train_state, loss_fn
    from repro.data.trajectory import dummy_batch

    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = dummy_batch(4, 3, 6, cfg.action_dim, cfg.vocab_size,
                        cfg.action_vocab_size)
    rl_ref = RLConfig(grad_accum=1, entropy_coef=0.01)
    rl_fused = dataclasses.replace(rl_ref, fused_loss=True)

    def total(p, rl):
        return loss_fn(p, batch, state.adv_norm, cfg, rl)

    l_ref, (m_ref, _) = total(state.params, rl_ref)
    g_ref = jax.grad(lambda p: total(p, rl_ref)[0])(state.params)
    with dispatch.forced(mode):
        l_f, (m_f, _) = total(state.params, rl_fused)
        g_f = jax.grad(lambda p: total(p, rl_fused)[0])(state.params)

    _close(l_f, l_ref, rtol=1e-5, atol=1e-6)
    for key in ("pg_loss", "value_loss", "kl", "entropy", "ratio_mean",
                "omega_mean", "stale_frac"):
        _close(m_f[key], m_ref[key], rtol=1e-4, atol=1e-5)
    flat_ref = jax.tree_util.tree_leaves_with_path(g_ref)
    flat_f = dict(jax.tree_util.tree_leaves_with_path(g_f))
    assert len(flat_ref) == len(flat_f)
    for path, leaf in flat_ref:
        scale = float(jnp.max(jnp.abs(leaf))) + 1e-8
        diff = float(jnp.max(jnp.abs(leaf - flat_f[path])))
        assert diff <= 1e-5 + 1e-4 * scale, (path, diff, scale)
