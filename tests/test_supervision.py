"""Supervision-layer unit tests (no jax subprocesses).

The Supervisor's state machine is exercised against a fake endpoint (so
failures are deterministic and instant), the connect-mode lifecycle
against a REAL TransportServer with the token handshake over actual
sockets, and the MetricsRegistry incarnation semantics (counters must
aggregate monotonically across a worker restart, gauges must reset) on
the registry directly."""
import threading
import time

import pytest

from repro.configs import get_config, reduced
from repro.configs.base import RLConfig, RuntimeConfig
from repro.runtime.service import MetricsRegistry, ServiceState
from repro.runtime.transport import (RemoteWorkerSpec, RestartPolicy,
                                     Supervisor, TransportError,
                                     TransportServer, WireClient)
from repro.runtime.transport.remote import spec_from_wire
from repro.runtime.transport.supervision import (SupervisedWorker,
                                                 WorkerEndpoint)


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------

class StubServer:
    """Just the sink/hello registration surface the supervisor needs."""

    def __init__(self):
        self.sinks = {}
        self.hello = None

    def register_worker_sink(self, name, host):
        self.sinks[name] = host

    def set_hello_handler(self, fn):
        self.hello = fn


class FakeEndpoint(WorkerEndpoint):
    """Deterministic 'process': dies exactly when the test says so."""

    mode = "spawn"

    def __init__(self):
        self.launches = 0
        self.specs = []
        self._failure = None

    def launch(self, spec):
        self.launches += 1
        self.specs.append(spec)
        self._failure = None

    def failure(self):
        return self._failure

    def die(self, reason="process died (exitcode=-9)"):
        self._failure = reason


def _spec(name="remote-rollout-0", **kw):
    return RemoteWorkerSpec(name=name,
                            cfg=reduced(get_config("deepseek-7b")),
                            rl=RLConfig(), rt=RuntimeConfig(),
                            address=("127.0.0.1", 1), **kw)


def _supervised(policy, n=1):
    sup = Supervisor(StubServer(), policy, poll_s=0.005)
    slots = []
    for i in range(n):
        slot = SupervisedWorker(_spec(f"remote-rollout-{i}"),
                                FakeEndpoint(), sup.server)
        slot.start()               # as the registry would (passive service)
        sup.slots.append(slot)
        slots.append(slot)
    return sup, slots


def _wait(cond, timeout=5.0, msg="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return
        time.sleep(0.002)
    raise AssertionError(f"timed out waiting for {msg}")


# ---------------------------------------------------------------------------
# RestartPolicy
# ---------------------------------------------------------------------------

def test_restart_policy_backoff_and_validation():
    p = RestartPolicy(mode="on_failure", backoff_initial_s=0.1,
                      backoff_factor=2.0, backoff_max_s=0.5)
    assert p.backoff_s(1) == pytest.approx(0.1)
    assert p.backoff_s(2) == pytest.approx(0.2)
    assert p.backoff_s(3) == pytest.approx(0.4)
    assert p.backoff_s(4) == pytest.approx(0.5)     # capped
    with pytest.raises(ValueError):
        RestartPolicy(mode="sometimes")


# ---------------------------------------------------------------------------
# supervisor state machine (fake endpoints)
# ---------------------------------------------------------------------------

def test_restart_within_budget_keeps_slot_healthy_and_counters_monotonic():
    policy = RestartPolicy(mode="on_failure", max_restarts=3,
                           backoff_initial_s=0.01, backoff_max_s=0.05)
    sup, (slot,) = _supervised(policy)
    endpoint = slot.endpoint
    sup.start()
    try:
        _wait(lambda: endpoint.launches == 1, msg="initial launch")
        assert slot.incarnation == 1
        slot.apply_report({"merged": {"counters": {"env_steps": 40.0},
                                      "gauges": {"weight_version": 7.0},
                                      "series": {}}}, incarnation=1)
        endpoint.die()
        _wait(lambda: endpoint.launches == 2, msg="respawn")
        assert slot.incarnation == 2 and slot.restarts == 1
        assert slot.error is None and slot.healthy
        # the replacement starts counting from zero — totals must not
        # rewind (monotonic) and the gauge must reset with the process
        assert slot.env_steps == 40
        assert slot.metrics.gauge("weight_version", -1.0) == -1.0
        slot.apply_report({"merged": {"counters": {"env_steps": 5.0},
                                      "gauges": {"weight_version": 9.0},
                                      "series": {}}}, incarnation=2)
        assert slot.env_steps == 45
        assert slot.metrics.gauge("weight_version") == 9.0
        # the spec handed to the new incarnation carries its id
        assert [s.incarnation for s in endpoint.specs] == [1, 2]
    finally:
        sup.stop()
        sup.join()


def test_budget_exhaustion_marks_slot_failed():
    policy = RestartPolicy(mode="on_failure", max_restarts=1,
                           backoff_initial_s=0.01, window_s=60.0)
    sup, (slot,) = _supervised(policy)
    endpoint = slot.endpoint
    sup.start()
    try:
        _wait(lambda: endpoint.launches == 1, msg="initial launch")
        endpoint.die()
        _wait(lambda: endpoint.launches == 2, msg="the one budgeted restart")
        endpoint.die()
        _wait(lambda: slot.error is not None, msg="budget exhaustion")
        assert slot.status == ServiceState.FAILED
        assert "restart budget exhausted" in repr(slot.error)
        assert endpoint.launches == 2               # no launch past budget
        assert sup.error is None                    # the supervisor lives
        # exhausted slot tells any lingering incarnation to stop
        assert slot.stop_for(slot.incarnation)
    finally:
        sup.stop()
        sup.join()


def test_never_mode_fails_on_first_death_like_pr3():
    sup, (slot,) = _supervised(RestartPolicy(mode="never"))
    endpoint = slot.endpoint
    sup.start()
    try:
        _wait(lambda: endpoint.launches == 1, msg="initial launch")
        endpoint.die("process died (exitcode=-9)")
        _wait(lambda: slot.error is not None, msg="containment")
        assert "died" in repr(slot.error)
        assert endpoint.launches == 1
    finally:
        sup.stop()
        sup.join()


def test_reported_unhealthy_service_is_a_failure_too():
    policy = RestartPolicy(mode="on_failure", max_restarts=2,
                           backoff_initial_s=0.01)
    sup, (slot,) = _supervised(policy)
    endpoint = slot.endpoint
    sup.start()
    try:
        _wait(lambda: endpoint.launches == 1, msg="initial launch")
        slot.apply_report(
            {"health": {"healthy": False, "state": "failed",
                        "error": "RuntimeError('boom')"},
             "merged": {}}, incarnation=1)
        _wait(lambda: endpoint.launches == 2, msg="restart on bad report")
        assert slot.restarts == 1 and slot.error is None
    finally:
        sup.stop()
        sup.join()


def test_stopping_during_backoff_never_relaunches():
    policy = RestartPolicy(mode="on_failure", max_restarts=5,
                           backoff_initial_s=10.0)   # park it in backoff
    sup, (slot,) = _supervised(policy)
    endpoint = slot.endpoint
    sup.start()
    try:
        _wait(lambda: endpoint.launches == 1, msg="initial launch")
        endpoint.die()
        _wait(lambda: slot.phase == "backoff", msg="backoff entry")
        slot.stop()
        _wait(lambda: slot.phase == "done", msg="stop short-circuit")
        assert endpoint.launches == 1
    finally:
        sup.stop()
        sup.join()
        slot.join()


# ---------------------------------------------------------------------------
# connect mode over a real server: token handshake, stall, redial re-accept
# ---------------------------------------------------------------------------

def _hello(address, token, worker=None):
    client = WireClient(address)
    try:
        header = {"m": "worker.hello", "token": token}
        if worker:
            header["worker"] = worker
        return client.request(header)[0]
    finally:
        client.close()


def test_connect_lifecycle_token_stall_and_redial():
    server = TransportServer(token="sekrit")
    policy = RestartPolicy(mode="on_failure", max_restarts=3,
                           backoff_initial_s=0.01, backoff_max_s=0.05)
    sup = Supervisor(server, policy, poll_s=0.005)
    spec = _spec("connect-rollout-0", heartbeat_s=0.05, token="sekrit")
    slot = sup.add_connected(spec, liveness_timeout_s=0.3)
    server.start()
    sup.start()
    control = None
    try:
        _wait(lambda: slot.phase == "waiting", msg="slot open")
        # -- token gate --------------------------------------------------
        with pytest.raises(TransportError, match="token"):
            _hello(server.address, "wrong")
        # -- handshake ships the spec ------------------------------------
        resp = _hello(server.address, "sekrit")
        assert resp["ok"] and resp["name"] == "connect-rollout-0"
        assert resp["incarnation"] == 1
        got = spec_from_wire(resp["spec"])
        assert got.name == spec.name and got.incarnation == 1
        assert got.cfg == spec.cfg
        # -- a live slot rejects a second dialer -------------------------
        with pytest.raises(TransportError, match="no open worker slot"):
            _hello(server.address, "sekrit")
        # -- heartbeats keep it alive; counters bridge -------------------
        control = WireClient(server.address)
        report = {"health": {"healthy": True},
                  "merged": {"counters": {"env_steps": 11.0},
                             "gauges": {}, "series": {}}}
        resp, _ = control.request({"m": "worker.report",
                                   "worker": "connect-rollout-0",
                                   "incarnation": 1, "report": report})
        assert resp["stop"] is False
        assert slot.env_steps == 11
        # -- stall: stop reporting; the slot re-opens under the budget ---
        _wait(lambda: slot.phase == "waiting", timeout=5.0,
              msg="stall detection + slot re-open")
        assert slot.restarts == 1 and slot.error is None
        # -- redial is re-accepted as a NEW incarnation ------------------
        resp = _hello(server.address, "sekrit")
        assert resp["ok"] and resp["incarnation"] == 2
        # zombie reports from incarnation 1 are dropped and told to stop
        resp, _ = control.request({"m": "worker.report",
                                   "worker": "connect-rollout-0",
                                   "incarnation": 1, "report": report})
        assert resp["stop"] is True
        assert slot.env_steps == 11               # not double-counted
        # the replacement's reports stack monotonically
        resp, _ = control.request({"m": "worker.report",
                                   "worker": "connect-rollout-0",
                                   "incarnation": 2, "report": report})
        assert resp["stop"] is False
        assert slot.env_steps == 22
    finally:
        if control is not None:
            control.close()
        sup.stop()
        sup.join()
        server.stop()
        server.join()


def test_stall_heal_during_backoff_cancels_relaunch():
    """A liveness 'failure' that was only a stall (GC pause, brief
    partition): if the worker's reports resume while the slot is still in
    backoff, the SAME incarnation goes back up — no relaunch, no strand."""
    sup = Supervisor(StubServer(),
                     RestartPolicy(mode="on_failure", max_restarts=2,
                                   backoff_initial_s=5.0),  # park in backoff
                     poll_s=0.005)
    slot = sup.add_connected(_spec("connect-rollout-0"),
                             liveness_timeout_s=0.2)
    slot.start()
    sup.start()
    try:
        _wait(lambda: slot.phase == "waiting", msg="slot open")
        assert sup.handle_hello({})["ok"]
        _wait(lambda: slot.phase == "backoff", msg="stall -> backoff")
        assert slot.restarts == 1
        slot.apply_report({"merged": {"counters": {"env_steps": 7.0},
                                      "gauges": {}, "series": {}}},
                          incarnation=1)
        _wait(lambda: slot.phase == "up", msg="heal in place")
        assert slot.incarnation == 1 and slot.error is None
        assert not slot.stop_for(1)
        assert slot.env_steps == 7
    finally:
        sup.stop()
        sup.join()
        slot.stop()
        slot.join()


def test_stalled_worker_readopts_slot_after_it_reopened():
    """Same stall, detected later: the slot already re-opened for a
    redial ('waiting') when the presumed-dead worker's reports resume —
    it re-adopts its incarnation instead of being told to stop while the
    attach window burns the rest of the budget."""
    sup = Supervisor(StubServer(),
                     RestartPolicy(mode="on_failure", max_restarts=3,
                                   backoff_initial_s=0.01),
                     poll_s=0.005)
    slot = sup.add_connected(_spec("connect-rollout-0"),
                             liveness_timeout_s=0.2)
    slot.start()
    sup.start()
    try:
        _wait(lambda: slot.phase == "waiting", msg="slot open")
        assert sup.handle_hello({})["ok"]
        _wait(lambda: slot.phase == "waiting" and slot.restarts == 1,
              msg="stall -> slot re-opened")
        slot.apply_report({"merged": {"counters": {"env_steps": 7.0},
                                      "gauges": {}, "series": {}}},
                          incarnation=1)
        assert slot.phase == "up"              # re-adopted synchronously
        assert slot.incarnation == 1 and slot.error is None
        assert not slot.stop_for(1)
        assert slot.env_steps == 7
    finally:
        sup.stop()
        sup.join()
        slot.stop()
        slot.join()


def test_hello_without_connect_slots_is_an_error():
    server = TransportServer()
    server.start()
    try:
        with pytest.raises(TransportError, match="no connect-mode"):
            _hello(server.address, "")
    finally:
        server.stop()
        server.join()


# ---------------------------------------------------------------------------
# MetricsRegistry under restart (satellite): monotone counters, gauge reset
# ---------------------------------------------------------------------------

def test_apply_remote_is_idempotent_within_an_incarnation():
    m = MetricsRegistry("t")
    snap = {"counters": {"env_steps": 40.0}, "gauges": {"v": 3.0},
            "series": {}}
    m.apply_remote(snap)
    m.apply_remote(snap)                       # re-sent report: no change
    assert m.counter("env_steps") == 40.0
    assert m.gauge("v") == 3.0


def test_counters_aggregate_monotonically_across_incarnations():
    m = MetricsRegistry("t")
    m.apply_remote({"counters": {"env_steps": 40.0, "episodes": 5.0},
                    "gauges": {}, "series": {}})
    m.begin_remote_incarnation()
    # the replacement reports from zero — totals must never rewind
    m.apply_remote({"counters": {"env_steps": 3.0}, "gauges": {},
                    "series": {}})
    assert m.counter("env_steps") == 43.0
    assert m.counter("episodes") == 5.0        # key absent so far: kept
    m.apply_remote({"counters": {"env_steps": 9.0, "episodes": 1.0},
                    "gauges": {}, "series": {}})
    assert m.counter("env_steps") == 49.0      # absolute-per-incarnation
    assert m.counter("episodes") == 6.0
    snap = m.snapshot()
    assert snap["counters"] == {"env_steps": 49.0, "episodes": 6.0}


def test_gauges_reset_on_new_incarnation():
    m = MetricsRegistry("t")
    m.apply_remote({"counters": {}, "gauges": {"policy_version": 7.0},
                    "series": {}})
    m.begin_remote_incarnation()
    assert m.gauge("policy_version", default=-1.0) == -1.0
    assert "policy_version" not in m.snapshot()["gauges"]
    m.apply_remote({"counters": {}, "gauges": {"policy_version": 1.0},
                    "series": {}})
    assert m.gauge("policy_version") == 1.0


def test_series_fold_count_weighted_across_incarnations():
    m = MetricsRegistry("t")
    m.apply_remote({"counters": {}, "gauges": {},
                    "series": {"return": {"count": 4, "mean": 1.0,
                                          "last": 2.0}}})
    m.begin_remote_incarnation()
    m.apply_remote({"counters": {}, "gauges": {},
                    "series": {"return": {"count": 1, "mean": 6.0,
                                          "last": 6.0}}})
    s = m.snapshot()["series"]["return"]
    assert s["count"] == 5
    assert s["mean"] == pytest.approx(2.0)     # (4*1 + 1*6) / 5
    assert s["last"] == 6.0
    assert m.series_mean("return") == pytest.approx(2.0)


def test_local_counters_coexist_with_remote_incarnations():
    m = MetricsRegistry("t")
    m.inc("restarts")                          # supervisor-side local count
    m.apply_remote({"counters": {"env_steps": 10.0}, "gauges": {},
                    "series": {}})
    m.begin_remote_incarnation()
    m.inc("restarts")
    m.apply_remote({"counters": {"env_steps": 2.0}, "gauges": {},
                    "series": {}})
    snap = m.snapshot()
    assert snap["counters"]["restarts"] == 2.0
    assert snap["counters"]["env_steps"] == 12.0
