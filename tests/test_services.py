"""Service / Scheduler / ExperienceChannel architecture tests: the uniform
lifecycle, crash containment, the metrics registry, channel backpressure
policies, the real/imagined experience mix, the dynamic step barrier, and
the one-code-path guarantee (sync and async emit the same metric schema)."""
import threading
import time

import numpy as np
import pytest

from repro.runtime import (FifoChannel, MetricsRegistry,
                           MixedExperienceSource, RingChannel, Service,
                           ServiceRegistry, ServiceState)
from repro.runtime.scheduler import BarrierGate, _DynamicBarrier


# ---------------------------------------------------------------------------
# Service lifecycle
# ---------------------------------------------------------------------------

class _Ticker(Service):
    def __init__(self, name="ticker", fail=False):
        super().__init__(name, role="test")
        self.fail = fail

    def _run(self):
        if self.fail:
            raise RuntimeError("boom")
        while not self._stop.is_set():
            self.metrics.inc("ticks")
            time.sleep(0.005)


def test_service_lifecycle_states():
    s = _Ticker()
    assert s.status == ServiceState.NEW
    s.start()
    assert s.status == ServiceState.RUNNING
    with pytest.raises(RuntimeError):
        s.start()                      # double-start is a caller bug
    time.sleep(0.05)
    s.stop()
    s.join()
    assert s.status == ServiceState.STOPPED
    assert s.healthy
    assert s.metrics.counter("ticks") > 0
    assert s.uptime_s > 0


def test_service_crash_marks_failed():
    s = _Ticker(fail=True).start()
    s.join(timeout=2.0)
    assert s.status == ServiceState.FAILED
    assert not s.healthy
    assert "boom" in repr(s.error)
    assert "boom" in s.health()["error"]


def test_service_stop_before_start_is_safe():
    s = _Ticker()
    s.stop()
    assert s.status == ServiceState.STOPPED


def test_registry_orders_and_roles():
    reg = ServiceRegistry()
    a = reg.register(_Ticker("a"))
    b = reg.register(_Ticker("b"))
    with pytest.raises(ValueError):
        reg.register(_Ticker("a"))     # duplicate name
    assert [s.name for s in reg.all(role="test")] == ["a", "b"]
    reg.start_all(exclude_roles=("test",))
    assert a.status == ServiceState.NEW     # excluded role untouched
    reg.start_all()
    reg.stop_all()
    reg.join_all()
    assert all(h["state"] == ServiceState.STOPPED
               for h in reg.health().values())
    assert set(reg.snapshot()) == {"a", "b"}
    assert reg.deregister("a") is a
    assert "a" not in reg
    assert b is reg.get("b")


def test_metrics_registry():
    m = MetricsRegistry("t")
    assert m.inc("c", 2.0) == 2.0
    assert m.inc("c") == 3.0
    m.set_gauge("g", 7.0)
    m.record("s", 1.0)
    m.record("s", 3.0)
    assert m.series_mean("s") == 2.0
    with m.timer("busy_s"):
        time.sleep(0.01)
    snap = m.snapshot()
    assert snap["counters"]["c"] == 3.0
    assert snap["counters"]["busy_s"] >= 0.01
    assert snap["gauges"]["g"] == 7.0
    assert snap["series"]["s"] == {"count": 2, "mean": 2.0, "last": 3.0}


# ---------------------------------------------------------------------------
# ExperienceChannel backpressure policies
# ---------------------------------------------------------------------------

def test_fifo_channel_drop_oldest():
    ch = FifoChannel(2, policy="drop_oldest")
    assert all(ch.put(i) for i in range(4))
    assert ch.total_dropped == 2
    assert ch.pop_batch(2, timeout=0.1) == [2, 3]


def test_fifo_channel_drop_newest():
    ch = FifoChannel(2, policy="drop_newest")
    assert ch.put(0) and ch.put(1)
    assert not ch.put(2)               # rejected, queued data wins
    assert ch.total_dropped == 1
    assert ch.pop_batch(2, timeout=0.1) == [0, 1]


def test_fifo_channel_block_waits_for_consumer():
    ch = FifoChannel(1, policy="block", block_timeout=2.0)
    ch.put(0)
    t = threading.Thread(target=lambda: (time.sleep(0.05),
                                         ch.pop_batch(1, timeout=1.0)))
    t.start()
    t0 = time.monotonic()
    assert ch.put(1)                   # blocks until the pop frees a slot
    assert time.monotonic() - t0 >= 0.04
    t.join()
    assert ch.total_dropped == 0


def test_fifo_channel_block_timeout_rejects():
    ch = FifoChannel(1, policy="block", block_timeout=0.05)
    ch.put(0)
    assert not ch.put(1)
    assert ch.total_dropped == 1


def test_fifo_channel_bad_policy():
    with pytest.raises(ValueError):
        FifoChannel(4, policy="bogus")


def test_ring_channel_sampling():
    ch = RingChannel(4, seed=0)
    assert ch.sample(2) is None
    for i in range(10):
        ch.put(i)
    assert all(6 <= x < 10 for x in ch.sample(32))
    assert ch.stats()["pushed"] == 10.0


# ---------------------------------------------------------------------------
# MixedExperienceSource (B + B_img composition)
# ---------------------------------------------------------------------------

def _filled(n, tag):
    ch = FifoChannel(100)
    for i in range(n):
        ch.put((tag, i))
    return ch

def test_mixed_source_respects_ratio():
    src = MixedExperienceSource(_filled(10, "real"), _filled(10, "img"),
                                real_fraction=0.5)
    batch = src.pop_batch(8, timeout=1.0)
    tags = [t for t, _ in batch]
    assert tags.count("real") == 4 and tags.count("img") == 4
    assert src.real_consumed == 4 and src.imagined_consumed == 4


def test_mixed_source_pure_imagined():
    src = MixedExperienceSource(_filled(10, "real"), _filled(10, "img"),
                                real_fraction=0.0)
    batch = src.pop_batch(6, timeout=1.0)
    assert all(t == "img" for t, _ in batch)


def test_mixed_source_backfills_on_starvation():
    src = MixedExperienceSource(_filled(10, "real"), _filled(2, "img"),
                                real_fraction=0.25)
    batch = src.pop_batch(8, timeout=1.0)
    tags = [t for t, _ in batch]
    assert len(batch) == 8
    assert tags.count("img") == 2      # all that existed
    assert tags.count("real") == 6     # real backfilled beyond its 25%


def test_mixed_source_timeout_carries_partial():
    real, img = _filled(3, "real"), _filled(0, "img")
    src = MixedExperienceSource(real, img, real_fraction=1.0)
    assert src.pop_batch(8, timeout=0.05) is None    # only 3 available
    for i in range(5):
        real.put(("real", 100 + i))
    batch = src.pop_batch(8, timeout=1.0)
    assert len(batch) == 8             # the 3 carried + 5 fresh, none lost
    assert src.real_consumed == 8


def test_mixed_source_zero_fraction_is_a_hard_pin():
    """real_fraction=0.0 (paper §4) must NEVER consume real segments, even
    when imagination is starved — it waits instead of diluting the diet."""
    real, img = _filled(10, "real"), _filled(0, "img")
    src = MixedExperienceSource(real, img, real_fraction=0.0)
    assert src.pop_batch(4, timeout=0.05) is None
    assert src.real_consumed == 0 and len(real) == 10
    for i in range(4):
        img.put(("img", i))
    assert all(t == "img" for t, _ in src.pop_batch(4, timeout=1.0))


def test_mixed_source_one_fraction_is_a_hard_pin():
    real, img = _filled(0, "real"), _filled(10, "img")
    src = MixedExperienceSource(real, img, real_fraction=1.0)
    assert src.pop_batch(4, timeout=0.05) is None
    assert src.imagined_consumed == 0 and len(img) == 10


def test_mixed_source_rejects_bad_fraction():
    with pytest.raises(ValueError):
        MixedExperienceSource(_filled(1, "r"), _filled(1, "i"),
                              real_fraction=1.5)


# ---------------------------------------------------------------------------
# Dynamic step barrier (sync mode)
# ---------------------------------------------------------------------------

def test_dynamic_barrier_lockstep_and_leave():
    barrier = _DynamicBarrier()
    stop = threading.Event()
    arrived = []
    lock = threading.Lock()

    def worker(idx, steps):
        barrier.join()
        for s in range(steps):
            barrier.wait(stop)
            with lock:
                arrived.append((idx, s))
        barrier.leave()

    ts = [threading.Thread(target=worker, args=(i, 3 if i == 0 else 5))
          for i in range(3)]
    # stagger the joins so parties grows while others already wait
    for t in ts:
        t.start()
        time.sleep(0.01)
    for t in ts:
        t.join(timeout=5.0)
    assert not any(t.is_alive() for t in ts), "barrier deadlocked"
    # worker 0 leaves after 3 steps; the other two still finish 5 each
    assert len(arrived) == 3 + 5 + 5


def test_barrier_gate_permits_are_episode_quota():
    gate = BarrierGate(lockstep=False)
    stop = threading.Event()
    gate.release(2)
    assert gate.begin_episode(stop)
    assert gate.begin_episode(stop)
    got = []
    t = threading.Thread(target=lambda: got.append(gate.begin_episode(stop)))
    t.start()
    time.sleep(0.1)
    assert not got                     # quota exhausted: worker parked
    stop.set()
    t.join(timeout=2.0)
    assert got == [False]              # released by shutdown, not a permit


def test_barrier_gate_counts_aborted_episodes():
    """end_episode fires for aborted episodes too, so a permit can never
    leak and stall a sync round at the episode barrier."""
    gate = BarrierGate(lockstep=True)
    stop = threading.Event()
    gate.release(2)
    assert gate.begin_episode(stop)
    gate.end_episode()                 # completed normally
    assert gate.begin_episode(stop)
    gate.end_episode()                 # aborted mid-episode: still counted
    assert gate.completed == 2


def test_scheduler_fail_fast_on_crashed_service():
    from repro.runtime.scheduler import Scheduler

    class _Sys:
        registry = ServiceRegistry()
    t = _Sys.registry.register(_Ticker("crasher", fail=True)).start()
    t.join(timeout=2.0)
    assert Scheduler._failed(_Sys)     # poll loops break instead of spinning


# ---------------------------------------------------------------------------
# one code path, one schema: sync and async metrics agree
# ---------------------------------------------------------------------------

def _tiny_system(**kw):
    from repro.configs import get_config, reduced
    from repro.configs.base import RLConfig, RuntimeConfig
    from repro.runtime import AcceRLSystem
    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    rl = RLConfig(grad_accum=1, lr_policy=1e-4, lr_value=1e-3)
    rt = RuntimeConfig(num_rollout_workers=2, inference_batch=4)
    return AcceRLSystem(cfg, rl, rt, suite="spatial", segment_horizon=4,
                        max_episode_steps=8, batch_episodes=4, **kw)


@pytest.mark.slow
def test_sync_and_async_share_code_path_and_schema():
    """Acceptance: run_sync and run_async drive the SAME services; both
    reach the step budget and emit identical metric keys."""
    ma = _tiny_system(seed=0).run_async(train_steps=2, wall_timeout_s=240.0)
    ms = _tiny_system(seed=1).run_sync(train_steps=2, episodes_per_round=2,
                                       wall_timeout_s=240.0)
    assert ma["train_steps"] >= 2 and ms["train_steps"] >= 2
    assert ma["env_steps"] > 0 and ms["env_steps"] > 0
    assert set(ma) == set(ms)
    for key in ("wall_s", "train_steps", "env_steps", "episodes", "sps_env",
                "sps_train", "trainer_util", "inference_util",
                "mean_policy_lag", "mean_return", "success_rate",
                "buffer_dropped", "inference_batches", "sync_latency_s"):
        assert key in ma, key          # the pre-refactor schema, preserved


@pytest.mark.slow
def test_wm_attaches_without_subclassing():
    """Acceptance: the world model registers services on the bus of a plain
    AcceRLSystem — no orchestrator subclass anywhere."""
    from repro.configs.base import WMConfig
    from repro.runtime import AcceRLSystem
    from repro.wm import AcceRLWMSystem, WorldModelAttachment

    from repro.configs import get_config, reduced
    from repro.configs.base import RLConfig, RuntimeConfig

    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    rl = RLConfig(grad_accum=1, lr_policy=1e-4, lr_value=1e-3)
    rt = RuntimeConfig(num_rollout_workers=2, inference_batch=4)
    wm = WMConfig(imagine_horizon=2, history_frames=2, diffusion_steps=4,
                  obs_train_interval=2, reward_train_interval=5)
    sys_ = AcceRLWMSystem(cfg, rl, rt, wm, suite="spatial",
                          segment_horizon=4, max_episode_steps=8,
                          imagination_batch=4)
    assert type(sys_) is AcceRLSystem
    assert isinstance(sys_.attachments[0], WorldModelAttachment)
    # the SAME trainer service, rewired onto the mixed (B, B_img) source
    assert sys_.img_trainer is sys_.trainer
    assert isinstance(sys_.trainer.source, MixedExperienceSource)
    names = set(sys_.registry.snapshot())
    assert {"inference", "trainer", "wm-trainer",
            "imagination-0"} <= names
    m = sys_.run_wm(train_steps=1, wall_timeout_s=240.0)
    assert m["img_train_steps"] >= 1
    assert m["imagined_steps"] > 0
    assert set(m["wm_updates"]) == {"obs", "reward"}
    assert m["real_env_steps"] == m["env_steps"]


def test_mixed_diet_rejects_horizon_mismatch():
    """A mixed real/imagined diet collates both segment kinds into one
    super-batch — bind() must refuse mismatched time axes loudly instead
    of letting np.stack die inside the prefetcher thread."""
    from repro.configs import get_config, reduced
    from repro.configs.base import RLConfig, RuntimeConfig, WMConfig
    from repro.wm import AcceRLWMSystem

    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    rl = RLConfig(grad_accum=1)
    wm = WMConfig(imagine_horizon=2, history_frames=2, diffusion_steps=4)
    rt = RuntimeConfig(num_rollout_workers=1, mix_real_fraction=0.25)
    with pytest.raises(ValueError, match="segment_horizon"):
        AcceRLWMSystem(cfg, rl, rt, wm, segment_horizon=4,
                       max_episode_steps=8)
    # matching horizons bind fine; the pure-imagined extreme (0.0) never
    # mixes kinds, so mismatched horizons stay allowed there
    AcceRLWMSystem(cfg, rl, rt, wm, segment_horizon=2, max_episode_steps=8)
    rt0 = RuntimeConfig(num_rollout_workers=1, mix_real_fraction=0.0)
    AcceRLWMSystem(cfg, rl, rt0, wm, segment_horizon=4, max_episode_steps=8)
