"""Observability plane units: the span recorder (ring buffers, trace
context, Chrome-trace export), histogram metrics and their cross-
incarnation folds, bounded series storage, structured crash records, the
TelemetrySink service, the server's metrics.snapshot / trace.dump
endpoints, and the inference-tier saturation signal in ElasticPolicy.

The cross-PROCESS trace join (child span → worker.report → server fold →
one dump) lives in tests/test_telemetry_e2e.py (CI telemetry-smoke job).
"""
import json
import os
import pathlib
import subprocess
import sys
import threading
import time

import pytest

from repro.configs import get_config, reduced
from repro.configs.base import RLConfig, RuntimeConfig, TelemetryConfig
from repro.runtime import telemetry
from repro.runtime.service import (HIST_BUCKETS, HIST_MIN_EXP,
                                   SERIES_WINDOW, MetricsRegistry, Service,
                                   ServiceRegistry, _hist_bucket,
                                   _hist_merge)
from repro.runtime.transport import (ElasticPolicy, RemoteWorkerSpec,
                                     RestartPolicy, Supervisor,
                                     TransportServer)
from repro.runtime.transport.channel import WireClient
from repro.runtime.transport.remote import _merge_snapshots
from repro.runtime.transport.supervision import (SupervisedWorker,
                                                 WorkerEndpoint)


@pytest.fixture(autouse=True)
def _clean_telemetry():
    telemetry.reset()
    yield
    telemetry.reset()


# ---------------------------------------------------------------------------
# import gating: REPRO_TRACE unset must keep telemetry entirely unloaded
# ---------------------------------------------------------------------------

def test_trace_gating_is_import_inert():
    """The hot modules must not even IMPORT telemetry when REPRO_TRACE is
    unset; with it set, they must all bind a live _tel."""
    prog = ("import sys;"
            "import repro.runtime.rollout;"
            "import repro.runtime.trainer;"
            "import repro.runtime.experience;"
            "import repro.runtime.transport.channel;"
            "import repro.runtime.transport.server;"
            "import repro.runtime.transport.remote;"
            "import repro.runtime.transport.weights;"
            "import repro.runtime.transport.inference_plane;"
            "mod='repro.runtime.telemetry';"
            "assert (mod in sys.modules) == (%r), sorted(sys.modules)")
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    for gated in (False, True):
        env = {k: v for k, v in os.environ.items() if k != "REPRO_TRACE"}
        env["PYTHONPATH"] = src
        env["JAX_PLATFORMS"] = "cpu"
        if gated:
            env["REPRO_TRACE"] = "1"
        proc = subprocess.run([sys.executable, "-c", prog % gated],
                              env=env, capture_output=True, text=True)
        assert proc.returncode == 0, proc.stderr


# ---------------------------------------------------------------------------
# span recorder: context, rings, flows, Chrome export
# ---------------------------------------------------------------------------

def test_span_context_inheritance_and_wire_ctx():
    assert telemetry.wire_ctx() == {}
    with telemetry.span("outer", flow="start") as (trace, sid):
        assert telemetry.current() == (trace, sid)
        ctx = telemetry.wire_ctx()
        assert ctx == {"tr": trace, "sp": sid}
        with telemetry.span("inner") as (t2, s2):
            assert t2 == trace and s2 != sid   # same trace, new span
    assert telemetry.current() is None
    events = telemetry.drain()
    slices = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in slices} == {"outer", "inner"}
    inner = next(e for e in slices if e["name"] == "inner")
    assert inner["args"]["trace"] == trace
    assert inner["args"]["parent"] == sid
    flows = [e for e in events if e["ph"] == "s"]
    assert flows and flows[0]["id"] == trace and "bp" not in flows[0]


def test_instant_flow_step_binds_enclosing():
    telemetry.instant("hop", trace=42, flow="step")
    events = telemetry.drain()
    flow = next(e for e in events if e["ph"] == "t")
    assert flow["id"] == 42 and flow["bp"] == "e"


def test_ring_buffer_bounds_memory(monkeypatch):
    monkeypatch.setattr(telemetry, "BUF_EVENTS", 8)
    for i in range(20):
        telemetry.instant(f"e{i}")
    events = [e for e in telemetry.drain() if e["ph"] == "i"]
    assert len(events) <= 8
    assert events[-1]["name"] == "e19"          # newest survives the wrap


def test_extend_foreign_bounded(monkeypatch):
    monkeypatch.setattr(telemetry, "FOREIGN_EVENTS", 4)
    telemetry.extend_foreign([{"name": f"f{i}", "ph": "i"}
                              for i in range(10)])
    got = telemetry.drain()
    assert len(got) == 4 and got[-1]["name"] == "f9"


def test_dump_writes_chrome_trace_format(tmp_path):
    with telemetry.span("work", cat="test", args={"k": 1}, flow="start"):
        telemetry.instant("mark", trace=7)
    out = tmp_path / "trace.json"
    n = telemetry.dump(str(out), process_name="unit")
    doc = json.loads(out.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    events = doc["traceEvents"]
    assert len(events) == n + 1                 # + process_name metadata
    assert events[0] == {"name": "process_name", "ph": "M",
                         "pid": os.getpid(), "tid": 0,
                         "args": {"name": "unit"}}
    for e in events[1:]:
        assert {"name", "ph", "ts", "pid"} <= set(e)
        assert isinstance(e["ts"], int)
    sl = next(e for e in events if e.get("ph") == "X")
    assert sl["dur"] >= 1 and sl["args"]["k"] == 1
    assert telemetry.drain() == []              # dump drained the buffers


# ---------------------------------------------------------------------------
# histograms: layout, merge algebra, incarnation folds
# ---------------------------------------------------------------------------

def test_hist_bucket_layout():
    assert _hist_bucket(-1.0) == 0 and _hist_bucket(0.0) == 0
    assert _hist_bucket(2.0 ** (HIST_MIN_EXP - 3)) == 0
    assert _hist_bucket(2.0 ** HIST_MIN_EXP) == 1
    assert _hist_bucket(1.0) == -HIST_MIN_EXP + 1
    assert _hist_bucket(1e30) == HIST_BUCKETS - 1   # top bucket open
    # half-open buckets: [2^(i-1), 2^i)
    assert _hist_bucket(0.5) == _hist_bucket(0.75) != _hist_bucket(1.0)


def test_observe_and_hist_summary():
    m = MetricsRegistry("t")
    for v in (0.5, 1.5, 1.5, 8.0):
        m.observe("lat", v)
    h = m.hist("lat")
    assert h["count"] == 4 and h["sum"] == pytest.approx(11.5)
    assert h["min"] == 0.5 and h["max"] == 8.0
    assert sum(h["buckets"].values()) == 4
    assert all(isinstance(k, str) for k in h["buckets"])
    assert m.hist("missing") is None
    assert m.hist("missing", default={"count": 0})["count"] == 0
    assert m.snapshot()["hists"]["lat"]["count"] == 4


def test_hist_merge_is_associative_addition():
    a = {"count": 2, "sum": 3.0, "min": 1.0, "max": 2.0,
         "buckets": {"21": 2}}
    b = {"count": 1, "sum": 8.0, "min": 8.0, "max": 8.0,
         "buckets": {"24": 1}}
    ab = _hist_merge(a, b)
    assert ab["count"] == 3 and ab["sum"] == pytest.approx(11.0)
    assert ab["min"] == 1.0 and ab["max"] == 8.0
    assert ab["buckets"] == {"21": 2, "24": 1}
    assert _hist_merge(None, a) == a and _hist_merge(a, None) == a
    assert _hist_merge(None, None)["count"] == 0


def test_hist_incarnation_fold_monotone_and_no_double_count():
    """Satellite: histogram + series folds through begin_remote_incarnation
    stay monotone and double-count-free across a worker restart."""
    child = MetricsRegistry("child")
    for v in (1.0, 2.0, 4.0):
        child.observe("age", v)
        child.record("ret", v)
    parent = MetricsRegistry("slot")
    snap = child.snapshot()
    parent.apply_remote(snap)
    parent.apply_remote(snap)                   # re-report: idempotent
    assert parent.hist("age")["count"] == 3
    assert parent.snapshot()["series"]["ret"]["count"] == 3

    parent.begin_remote_incarnation()           # worker restarted
    assert parent.hist("age")["count"] == 3     # fold is monotone
    child2 = MetricsRegistry("child")           # re-reports from zero
    child2.observe("age", 16.0)
    child2.record("ret", 16.0)
    parent.apply_remote(child2.snapshot())
    h = parent.hist("age")
    assert h["count"] == 4 and h["sum"] == pytest.approx(23.0)
    assert h["max"] == 16.0
    s = parent.snapshot()["series"]["ret"]
    assert s["count"] == 4
    assert s["mean"] == pytest.approx(23.0 / 4)

    parent.begin_remote_incarnation()           # second restart, no report
    assert parent.hist("age")["count"] == 4     # still no double count


def test_merge_snapshots_folds_hists_across_services():
    a, b = MetricsRegistry("a"), MetricsRegistry("b")
    a.observe("wait", 1.0)
    b.observe("wait", 3.0)
    b.observe("other", 5.0)
    merged = _merge_snapshots([a.snapshot(), b.snapshot()])
    assert merged["hists"]["wait"]["count"] == 2
    assert merged["hists"]["wait"]["sum"] == pytest.approx(4.0)
    assert merged["hists"]["other"]["count"] == 1


# ---------------------------------------------------------------------------
# bounded series: the unbounded-append regression
# ---------------------------------------------------------------------------

def test_series_storage_is_bounded_but_mean_is_exact():
    m = MetricsRegistry("t")
    n = SERIES_WINDOW + 300
    for i in range(n):
        m.record("x", float(i))
    win = m.series("x")
    assert len(win) == SERIES_WINDOW            # memory stays O(window)
    assert win[-1] == float(n - 1)
    assert win[0] == float(n - SERIES_WINDOW)
    assert m.series_mean("x") == pytest.approx((n - 1) / 2)  # ALL samples
    snap = m.snapshot()["series"]["x"]
    assert snap["count"] == n and snap["last"] == float(n - 1)


# ---------------------------------------------------------------------------
# structured crash records
# ---------------------------------------------------------------------------

class _Crashy(Service):
    def _run(self):
        raise RuntimeError("boom in the loop")


def test_service_crash_record_surfaced_in_health():
    svc = _Crashy("crashy")
    t0 = time.monotonic()
    svc.start()
    for _ in range(200):
        if svc.crash is not None:
            break
        time.sleep(0.01)
    crash = svc.health()["crash"]
    assert crash is not None
    assert crash["service"] == "crashy"
    assert crash["error"] == repr(svc.error)
    assert "RuntimeError: boom in the loop" in crash["traceback"]
    assert crash["t_mono"] >= t0
    assert isinstance(crash["incarnation"], int)
    svc.stop()
    svc.join()


def test_mark_failed_records_crash_without_traceback_frame():
    svc = _Crashy("marked")
    svc.mark_failed(ValueError("external verdict"))
    crash = svc.health()["crash"]
    assert crash["service"] == "marked"
    assert "external verdict" in crash["error"]


def test_healthy_service_has_no_crash_record():
    svc = Service("fine")
    assert svc.health()["crash"] is None


# ---------------------------------------------------------------------------
# TelemetrySink: registry sampling, bounded history, JSONL
# ---------------------------------------------------------------------------

def test_telemetry_sink_samples_and_bounds_history(tmp_path):
    reg = ServiceRegistry()
    svc = reg.register(Service("worker"))
    svc.metrics.inc("ticks", 3.0)
    svc.metrics.observe("lat", 0.25)
    path = tmp_path / "sink.jsonl"
    sink = telemetry.TelemetrySink(reg, interval_s=10.0, history=3,
                                   path=str(path))
    sink.on_start()
    for _ in range(5):
        sink.sample()
    assert len(sink.tail()) == 3                # history bounded
    latest = sink.latest()
    assert latest["services"]["worker"]["counters"]["ticks"] == 3.0
    assert latest["services"]["worker"]["hists"]["lat"]["count"] == 1
    assert latest["health"]["worker"]["state"] == "new"
    sink.on_stop()                              # final sample + close
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == 6
    assert lines[-1]["services"]["worker"]["counters"]["ticks"] == 3.0


def test_sink_sample_carries_crash_records():
    reg = ServiceRegistry()
    svc = reg.register(_Crashy("crashy"))
    svc.mark_failed(RuntimeError("dead"))
    sink = telemetry.TelemetrySink(reg)
    s = sink.sample()
    assert s["health"]["crashy"]["crash"]["service"] == "crashy"


def test_telemetry_config_on_runtime_config():
    rt = RuntimeConfig()
    assert rt.telemetry == TelemetryConfig()
    assert rt.telemetry.sink is False


# ---------------------------------------------------------------------------
# wire endpoints: metrics.snapshot + trace.dump
# ---------------------------------------------------------------------------

def test_server_metrics_snapshot_and_trace_dump_endpoints():
    srv = TransportServer()
    srv.start()
    try:
        client = WireClient(srv.address)
        resp, _ = client.request({"m": "metrics.snapshot"})
        assert resp["ok"] and srv.name in resp["sample"]["services"]
        # with a provider (the orchestrator wires the sink) the sample is
        # whatever the provider returns
        srv.snapshot_provider = lambda: {"services": {"x": 1}}
        resp, _ = client.request({"m": "metrics.snapshot"})
        assert resp["sample"] == {"services": {"x": 1}}
        # this test process is not trace-gated: trace.dump says so
        resp, _ = client.request({"m": "trace.dump"})
        assert resp["ok"] and resp["enabled"] is False
        assert resp["events"] == []
        client.close()
    finally:
        srv.stop()
        srv.join()


# ---------------------------------------------------------------------------
# elastic supervision: inference-tier saturation signal
# ---------------------------------------------------------------------------

class StubServer:
    def __init__(self):
        self.sinks = {}

    def register_worker_sink(self, name, host):
        self.sinks[name] = host

    def set_hello_handler(self, fn):
        pass


class FakeEndpoint(WorkerEndpoint):
    mode = "spawn"

    def __init__(self):
        self._failure = None

    def launch(self, spec):
        self._failure = None

    def failure(self):
        return self._failure


def _spec(name):
    return RemoteWorkerSpec(name=name,
                            cfg=reduced(get_config("deepseek-7b")),
                            rl=RLConfig(), rt=RuntimeConfig(),
                            address=("127.0.0.1", 1))


class ElasticSupervisor(Supervisor):
    def _elastic_add(self, spec):
        slot = SupervisedWorker(spec, FakeEndpoint(), self.server)
        slot.start()
        self.slots.append(slot)
        return slot


def test_tier_policy_validation():
    ElasticPolicy(tier_queue_hot=8.0, tier_fill_hot=0.95)
    with pytest.raises(ValueError):
        ElasticPolicy(tier_queue_hot=-1.0)
    with pytest.raises(ValueError):
        ElasticPolicy(tier_fill_hot=1.5)


def test_saturated_tier_triggers_scale_up():
    """Satellite: a saturated inference tier must scale the fleet up even
    when the experience queue alone would not."""
    signals = {"depth_frac": 0.5,                # mid-queue: no depth case
               "infer_queue_depth": 12.0, "infer_window_fill": 0.2}
    sup = ElasticSupervisor(StubServer(), RestartPolicy())
    sup.enable_elastic(ElasticPolicy(min_workers=0, max_workers=2,
                                     interval_s=1.0, tier_queue_hot=8.0),
                       lambda seq: _spec(f"elastic-{seq}"),
                       lambda: signals)
    sup._elastic_step(100.0)
    assert len(sup.slots) == 1, "hot tier queue must trigger scale-up"
    assert sup.metrics.gauge("elastic_tier_saturated") == 1.0
    signals["infer_queue_depth"] = 0.0           # pressure gone
    sup._elastic_step(102.0)
    assert len(sup.slots) == 1
    assert sup.metrics.gauge("elastic_tier_saturated") == 0.0


def test_saturated_tier_blocks_scale_down():
    signals = {"depth_frac": 0.0, "infer_window_fill": 0.0}
    sup = ElasticSupervisor(StubServer(), RestartPolicy())
    sup.enable_elastic(ElasticPolicy(min_workers=0, max_workers=1,
                                     interval_s=1.0, tier_fill_hot=0.9),
                       lambda seq: _spec(f"elastic-{seq}"),
                       lambda: signals)
    sup._elastic_step(100.0)
    assert len(sup.slots) == 1
    # queue says scale down, but the tier is saturated: hold the fleet
    signals["depth_frac"] = 1.0
    signals["infer_window_fill"] = 0.95
    sup._elastic_step(102.0)
    assert len(sup.slots) == 1 and sup.slots[0].phase == "up"
    signals["infer_window_fill"] = 0.0           # pressure gone: drain
    sup._elastic_step(104.0)
    assert sup.slots[0].phase == "draining"


def test_tier_thresholds_default_off():
    signals = {"depth_frac": 0.5,
               "infer_queue_depth": 1e9, "infer_window_fill": 1.0}
    sup = ElasticSupervisor(StubServer(), RestartPolicy())
    sup.enable_elastic(ElasticPolicy(min_workers=0, max_workers=2,
                                     interval_s=1.0),
                       lambda seq: _spec(f"elastic-{seq}"),
                       lambda: signals)
    sup._elastic_step(100.0)
    assert sup.slots == [], "tier signals are opt-in (0 disables)"
