"""Inference-plane tests: eq.-1 window math properties, the
``_collect_window`` anchoring regression, and the ``infer.*`` wire
contract (dedup, cumulative acks, reconnect replay, drain-mid-stream,
version-tag parity with the local path)."""
import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import RuntimeConfig
from repro.runtime import InferenceService, VersionedWeightStore
from repro.runtime.inference import _Request, pad_to_bucket, split_window
from repro.runtime.transport.inference_plane import (InferenceBroker,
                                                     RemoteInferenceClient)
from repro.runtime.transport.server import TransportServer


def _tiny():
    import dataclasses
    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    return dataclasses.replace(cfg, num_prefix_tokens=1)


def _obs(rng, cfg):
    return (rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
            rng.random(192).astype(np.float32))


# ---------------------------------------------------------------------------
# window math properties (seeded sweep — no hypothesis in the image)
# ---------------------------------------------------------------------------

def test_split_window_pad_properties():
    """For any n and bucket ladder: the split partitions n, no chunk
    exceeds the largest bucket, and the pad accounting that feeds the
    ``padded_slots`` counter is exact and non-negative."""
    rng = np.random.default_rng(7)
    ladders = [(1, 2, 4, 8, 16, 32), (4, 8), (1, 3, 7, 20), (5,)]
    for _ in range(500):
        buckets = ladders[rng.integers(len(ladders))]
        n = int(rng.integers(1, 200))
        sizes = split_window(n, buckets)
        assert sum(sizes) == n                       # partitions n
        assert all(1 <= s <= buckets[-1] for s in sizes)
        # all-but-last chunks are FULL largest buckets (no fragmentation)
        assert all(s == buckets[-1] for s in sizes[:-1])
        pads = [pad_to_bucket(s, buckets) - s for s in sizes]
        assert all(p >= 0 for p in pads)
        # padded batch sizes are real buckets
        for s, p in zip(sizes, pads):
            assert (s + p) in buckets
        # the eq.-1 accounting InferenceService increments per batch
        assert sum(pads) == sum(
            pad_to_bucket(s, buckets) for s in sizes) - n


def test_pad_to_bucket_monotone_and_tight():
    buckets = (1, 2, 4, 8, 16, 32)
    for n in range(1, 33):
        nb = pad_to_bucket(n, buckets)
        assert nb >= n and nb in buckets
        # tight: no smaller bucket also fits
        assert all(b < n for b in buckets if b < nb)


# ---------------------------------------------------------------------------
# _collect_window anchoring regression (satellite: degenerate 1-item
# batches when requests aged in the queue during a busy batch)
# ---------------------------------------------------------------------------

def test_collect_window_anchors_to_collection_start():
    """Requests that sat queued while a previous batch was in flight must
    NOT expire the window instantly: the T_max timer anchors to when
    collection starts, so all queued requests are swept into one window."""
    cfg = _tiny()
    rt = RuntimeConfig(num_inference_workers=1, inference_batch=8,
                       inference_max_wait_s=0.15)
    svc = InferenceService(cfg, VersionedWeightStore(), rt)  # never started
    rng = np.random.default_rng(0)
    for _ in range(3):
        obs, frame = _obs(rng, cfg)
        req = _Request(obs, frame, 0)
        req.t_arrival -= 10.0        # aged: queued during a busy batch
        svc._q.put(req)
    t0 = time.monotonic()
    reqs = svc._collect_window()
    elapsed = time.monotonic() - t0
    # the buggy anchoring returned a DEGENERATE 1-item window immediately
    # (t_now - t_arrival >= T_max on the first get)
    assert len(reqs) == 3
    assert elapsed >= 0.9 * rt.inference_max_wait_s
    assert svc.metrics.snapshot()["series"]["queue_wait_s"]["count"] == 3


def test_degenerate_batch_counter_and_gauges():
    """A lone request served by T_max expiry counts as a degenerate batch
    and the autoscaling gauges (queue depth, window fill) are exported."""
    import jax
    from repro.models.policy import init_policy_params
    cfg = _tiny()
    rt = RuntimeConfig(num_inference_workers=1, inference_batch=4,
                       inference_max_wait_s=0.05)
    store = VersionedWeightStore()
    store.publish(init_policy_params(cfg, jax.random.PRNGKey(0)), 0)
    svc = InferenceService(cfg, store, rt).start()
    try:
        rng = np.random.default_rng(0)
        obs, frame = _obs(rng, cfg)
        res = svc.submit(obs, frame, 0).result(timeout=120.0)
        assert res["policy_version"] == 0
        assert svc.degenerate_batches >= 1
        gauges = svc.metrics.snapshot()["gauges"]
        assert "queue_depth" in gauges and "window_fill" in gauges
        assert 0.0 < gauges["window_fill"] <= 1.0
    finally:
        svc.stop()


# ---------------------------------------------------------------------------
# broker unit contract: seq dedup, cumulative acks, redelivery
# ---------------------------------------------------------------------------

class _EchoPool:
    """Resolves every request immediately with its step echoed back."""

    def __init__(self):
        self.submits = 0

    def submit(self, obs, frame, step):
        self.submits += 1
        fut = Future()
        fut.set_result({"actions": np.asarray(obs),
                        "logp": np.zeros(2, np.float32),
                        "value": float(step), "policy_version": 1})
        return fut


def _submit_body(seq):
    from repro.runtime.transport.codec import encode_pytree
    return encode_pytree({"obs": np.arange(4, dtype=np.int32),
                          "frame": None, "step": seq})


def test_broker_dedup_acks_and_redelivery():
    from repro.runtime.transport.codec import decode_pytree
    pool = _EchoPool()
    broker = InferenceBroker(pool)
    h = broker.handle_open({"client": "w0"})
    assert h["ok"] and h["known_seq"] == -1

    assert broker.handle_submit({"client": "w0", "seq": 0},
                                _submit_body(0))["ok"]
    # replayed frame: re-ACKed, never re-executed
    dup = broker.handle_submit({"client": "w0", "seq": 0}, _submit_body(0))
    assert dup.get("dup") and pool.submits == 1
    assert broker.handle_submit({"client": "w0", "seq": 1},
                                _submit_body(1))["ok"]
    assert broker.handle_open({"client": "w0"})["known_seq"] == 1

    resp, body = broker.handle_result({"client": "w0", "ack": 0,
                                       "timeout": 1.0})
    assert resp["ok"] and resp["base"] == 0 and resp["count"] == 2
    items = decode_pytree(body, copy=True)
    assert [int(i["seq"]) for i in items] == [0, 1]

    # un-acked → redelivered verbatim (a lost reply loses nothing)
    resp2, body2 = broker.handle_result({"client": "w0", "ack": 0,
                                         "timeout": 1.0})
    assert resp2["base"] == 0 and resp2["count"] == 2

    # cumulative ack prunes; a STALE-EPOCH ack (beyond anything this
    # broker delivered) is ignored rather than corrupting the outbox
    resp3, _ = broker.handle_result({"client": "w0", "ack": 1000,
                                     "timeout": 0.0})
    assert resp3["ok"] and resp3["count"] == 2
    resp4, _ = broker.handle_result({"client": "w0", "ack": 1,
                                     "timeout": 0.0})
    assert resp4["ok"] and resp4["base"] == 1 and resp4["count"] == 1
    resp5, _ = broker.handle_result({"client": "w0", "ack": 2,
                                     "timeout": 0.0})
    assert not resp5["ok"]                  # fully acked: outbox is empty


# ---------------------------------------------------------------------------
# wire contract: client <-> server roundtrip, ring delivery, replay
# ---------------------------------------------------------------------------

class _SlowPool:
    """Holds every future until released (models an in-flight batch)."""

    def __init__(self):
        self.held = []
        self.lock = threading.Lock()
        self.release_now = False

    def submit(self, obs, frame, step):
        fut = Future()
        with self.lock:
            if self.release_now:
                fut.set_result(self._res(obs, step))
            else:
                self.held.append((fut, np.asarray(obs), step))
        return fut

    @staticmethod
    def _res(obs, step):
        return {"actions": np.asarray(obs), "logp": np.zeros(2, np.float32),
                "value": float(step), "policy_version": 2}

    def release(self):
        with self.lock:
            self.release_now = True
            held, self.held = self.held, []
        for fut, obs, step in held:
            fut.set_result(self._res(obs, step))


def test_remote_client_roundtrip_and_ring():
    from repro.runtime.transport.channel import shared_memory
    pool = _EchoPool()
    srv = TransportServer()
    srv.set_inference(InferenceBroker(pool))
    srv.start()
    try:
        cli = RemoteInferenceClient(
            srv.address, client_id="w0",
            use_ring=shared_memory is not None)
        futs = [cli.submit(np.arange(4, dtype=np.int32) * i, None, i)
                for i in range(10)]
        for i, f in enumerate(futs):
            res = f.result(timeout=15.0)
            assert res["value"] == float(i)
            assert res["policy_version"] == 1
            np.testing.assert_array_equal(res["actions"], np.arange(4) * i)
        assert cli.stats()["results"] == 10
        cli.close()
    finally:
        srv.stop()
        srv.join(timeout=5.0)


def test_unconfigured_server_rejects_infer():
    from repro.runtime.transport.channel import TransportError, WireClient
    srv = TransportServer()
    srv.start()
    try:
        cli = WireClient(srv.address)
        with pytest.raises(TransportError):
            cli.request({"m": "infer.open", "client": "w0"})
        cli.close()
    finally:
        srv.stop()
        srv.join(timeout=5.0)


def test_reconnect_replay_exactly_once_across_tier_restart():
    """Kill the tier with requests in flight; a replacement broker (new
    epoch, empty watermark) comes up on the SAME port. The client redials,
    replays every un-answered request, and every future resolves exactly
    once with a coherent result."""
    pool1 = _SlowPool()
    srv1 = TransportServer()
    srv1.set_inference(InferenceBroker(pool1))
    srv1.start()
    host, port = srv1.address
    cli = RemoteInferenceClient((host, port), client_id="w0",
                                reconnect_attempts=40,
                                reconnect_backoff_s=0.05)
    futs = [cli.submit(np.full(3, i, np.int32), None, i) for i in range(6)]
    # in flight: the pool holds all 6; "kill" the tier (results lost)
    assert not any(f.done() for f in futs)
    srv1.stop()
    srv1.join(timeout=5.0)

    pool2 = _SlowPool()
    pool2.release_now = True                 # replacement serves instantly
    srv2 = TransportServer(host=host, port=port)
    srv2.set_inference(InferenceBroker(pool2))
    srv2.start()
    try:
        for i, f in enumerate(futs):
            res = f.result(timeout=30.0)     # replayed to the new epoch
            assert res["value"] == float(i)
            np.testing.assert_array_equal(res["actions"],
                                          np.full(3, i, np.int32))
        # exactly-once: one resolve per request, no duplicates surfaced
        assert cli.stats()["results"] == 6
        assert cli.epoch_changes >= 1
        # the client keeps working against the replacement
        late = cli.submit(np.full(3, 9, np.int32), None, 9)
        assert late.result(timeout=15.0)["value"] == 9.0
        cli.close()
    finally:
        srv2.stop()
        srv2.join(timeout=5.0)


def test_version_tag_parity_and_drain_swap_mid_stream():
    """Remote results carry the SAME policy_version the local submit path
    reports, and a drain+publish mid-stream moves new requests to the new
    version without torn tags."""
    import jax
    from repro.models.policy import init_policy_params
    cfg = _tiny()
    rt = RuntimeConfig(num_inference_workers=1, inference_batch=4,
                       inference_max_wait_s=0.02)
    store = VersionedWeightStore()
    params = init_policy_params(cfg, jax.random.PRNGKey(0))
    store.publish(params, 0)
    svc = InferenceService(cfg, store, rt).start()
    srv = TransportServer()
    srv.set_inference(InferenceBroker(svc))
    srv.start()
    try:
        cli = RemoteInferenceClient(srv.address, client_id="w0")
        rng = np.random.default_rng(0)
        obs, frame = _obs(rng, cfg)
        remote = cli.submit(obs, frame, 0).result(timeout=120.0)
        local = svc.submit(obs, frame, 0).result(timeout=120.0)
        assert remote["policy_version"] == local["policy_version"] == 0
        assert remote["actions"].shape == local["actions"].shape
        assert isinstance(remote["value"], float)

        # drain-flag swap mid-stream: requests submitted while draining
        # are served only after the swap, tagged with the NEW version
        store.begin_publish()
        queued = [cli.submit(*_obs(rng, cfg), 1) for _ in range(3)]
        time.sleep(0.1)
        assert not any(f.done() for f in queued)   # pool honors the drain
        store.publish(params, 1)
        versions = {f.result(timeout=120.0)["policy_version"]
                    for f in queued}
        assert versions == {1}
        cli.close()
    finally:
        srv.stop()
        srv.join(timeout=5.0)
        svc.stop()


def test_spec_wire_roundtrip_inference_fields():
    from repro.configs.base import RLConfig
    from repro.runtime.transport import (RemoteWorkerSpec, spec_from_wire,
                                         spec_to_wire)
    spec = RemoteWorkerSpec(
        name="w0", cfg=_tiny(), rl=RLConfig(), rt=RuntimeConfig(),
        address=("127.0.0.1", 1234), inference="remote",
        infer_address=("127.0.0.1", 5678),
        infer_listen=("0.0.0.0", 9012))
    got = spec_from_wire(spec_to_wire(spec))
    assert got.inference == "remote"
    assert got.infer_address == ("127.0.0.1", 5678)
    assert got.infer_listen == ("0.0.0.0", 9012)
    assert isinstance(got.infer_address, tuple)
