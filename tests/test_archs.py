"""Deliverable (f): per-architecture smoke tests.

For each of the ten assigned architectures, instantiate the REDUCED
same-family variant (≤2 layers, d_model ≤ 512, ≤4 experts) and run one
forward + one train step on CPU asserting output shapes and no NaNs, plus
prefill/decode consistency (the serve path). The FULL configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.configs.base import RLConfig
from repro.core.train_step import init_train_state, make_train_step
from repro.data.trajectory import dummy_batch
from repro.models import transformer

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module", params=ASSIGNED_ARCHS)
def arch_setup(request):
    cfg = reduced(get_config(request.param))
    params = transformer.init_params(cfg, KEY)
    return request.param, cfg, params


def _prefix(cfg, b):
    if cfg.num_prefix_tokens:
        return jnp.asarray(
            np.random.default_rng(0).standard_normal(
                (b, min(cfg.num_prefix_tokens, 4),
                 transformer.FRONTEND_DIM)), jnp.float32)
    return None


def test_reduced_config_limits(arch_setup):
    name, cfg, _ = arch_setup
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


def test_forward_shapes_and_finiteness(arch_setup):
    name, cfg, params = arch_setup
    b, t = 2, 16
    tokens = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (b, t)),
        jnp.int32)
    out = transformer.forward(cfg, params, tokens, _prefix(cfg, b))
    p = 0 if _prefix(cfg, b) is None else _prefix(cfg, b).shape[1]
    assert out["logits"].shape == (b, t + p, cfg.action_vocab_size)
    assert out["hidden"].shape == (b, t + p, cfg.d_model)
    assert np.isfinite(np.asarray(out["logits"])).all(), name


def test_prefill_decode_consistency(arch_setup):
    """Teacher-forced forward and prefill+decode must produce the same
    logits for the same token stream (KV-cache / SSM-state correctness)."""
    name, cfg, params = arch_setup
    b, t = 2, 12
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t + 1)),
                         jnp.int32)
    full = transformer.forward(cfg, params, tokens)
    res, cache = transformer.prefill(cfg, params, tokens[:, :t],
                                     cache_len=t + 4)
    dec, cache = transformer.decode(cfg, params, tokens[:, t], cache)
    got = np.asarray(dec["logits"][:, 0])
    want = np.asarray(full["logits"][:, t])
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_decode_matches_stepwise(arch_setup):
    """Multi-step decode: logits at each step match teacher forcing."""
    name, cfg, params = arch_setup
    b, t0, steps = 1, 6, 3
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t0 + steps)),
                         jnp.int32)
    _, cache = transformer.prefill(cfg, params, tokens[:, :t0],
                                   cache_len=t0 + steps)
    for i in range(steps):
        dec, cache = transformer.decode(cfg, params, tokens[:, t0 + i],
                                        cache)
    full = transformer.forward(cfg, params, tokens)
    np.testing.assert_allclose(
        np.asarray(dec["logits"][:, 0]),
        np.asarray(full["logits"][:, t0 + steps - 1]),
        rtol=5e-3, atol=5e-3)


def test_sliding_window_decode(arch_setup):
    """The long_500k fallback: ring-buffer window cache stays finite and
    matches windowed teacher forcing for attention archs."""
    name, cfg, params = arch_setup
    if cfg.is_attention_free:
        pytest.skip("attention-free: native O(1) state, no window cache")
    window = 8
    b, t = 1, 12
    rng = np.random.default_rng(4)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t + 1)),
                         jnp.int32)
    full = transformer.forward(cfg, params, tokens, window=window)
    _, cache = transformer.prefill(cfg, params, tokens[:, :t],
                                   cache_len=window, window=window)
    dec, _ = transformer.decode(cfg, params, tokens[:, t], cache,
                                window=window)
    np.testing.assert_allclose(np.asarray(dec["logits"][:, 0]),
                               np.asarray(full["logits"][:, t]),
                               rtol=5e-3, atol=5e-3)


def test_one_train_step(arch_setup):
    """One RL train step per arch: loss finite, params move, no NaNs."""
    name, cfg, params = arch_setup
    rl = RLConfig(grad_accum=2, lr_policy=1e-4, lr_value=1e-3)
    state = init_train_state(cfg, KEY)
    batch = dummy_batch(4, 3, 8, cfg.action_dim, cfg.vocab_size,
                        cfg.action_vocab_size,
                        num_prefix=min(cfg.num_prefix_tokens, 4) or 0)
    step = make_train_step(cfg, rl, donate=False)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), name
    assert np.isfinite(float(metrics["grad_norm"])), name
    moved = jax.tree.reduce(
        lambda acc, pq: acc or bool(jnp.any(pq)), jax.tree.map(
            lambda a, b: jnp.any(a != b), state.params, new_state.params),
        False)
    assert moved, f"{name}: parameters did not update"
    leaves = jax.tree.leaves(new_state.params)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in leaves)


def test_blockwise_attention_matches_dense(arch_setup):
    name, cfg, params = arch_setup
    if cfg.is_attention_free:
        pytest.skip("no attention")
    b, t = 1, 64
    tokens = jnp.asarray(
        np.random.default_rng(5).integers(0, cfg.vocab_size, (b, t)),
        jnp.int32)
    dense = transformer.forward(cfg, params, tokens)
    blocked = transformer.forward(cfg, params, tokens, block=16)
    np.testing.assert_allclose(np.asarray(blocked["logits"]),
                               np.asarray(dense["logits"]),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_and_balance():
    cfg = reduced(get_config("dbrx-132b"))
    from repro.models import moe as moe_lib
    params = moe_lib.moe_init(KEY, cfg.d_model, cfg.moe, jnp.float32)
    x = jnp.asarray(np.random.default_rng(6).standard_normal(
        (2, 32, cfg.d_model)), jnp.float32)
    out, aux = moe_lib.moe_forward(params, x, cfg.moe)
    assert out.shape == x.shape
    assert float(aux["dropped_frac"]) <= 0.25
    assert float(aux["load_balance"]) >= 0.0


def test_uniform_decode_matches_scatter_path():
    """§Perf: the lockstep (scalar-slot) cache update must be numerically
    identical to the batched-scatter path when positions are uniform."""
    cfg = reduced(get_config("internlm2-1.8b"))
    params = transformer.init_params(cfg, KEY)
    b, t = 2, 6
    rng = np.random.default_rng(7)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t + 2)),
                         jnp.int32)
    _, c1 = transformer.prefill(cfg, params, tokens[:, :t], cache_len=t + 2)
    _, c2 = transformer.prefill(cfg, params, tokens[:, :t], cache_len=t + 2)
    for i in range(2):
        d1, c1 = transformer.decode(cfg, params, tokens[:, t + i], c1,
                                    uniform=False)
        d2, c2 = transformer.decode(cfg, params, tokens[:, t + i], c2,
                                    uniform=True)
    np.testing.assert_allclose(np.asarray(d1["logits"]),
                               np.asarray(d2["logits"]), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(c1.attn.k, np.float32),
                               np.asarray(c2.attn.k, np.float32))


def test_split_inproj_equivalent_families():
    """§Perf: the shard-aligned split projection is the same model family —
    both layouts train and decode without NaNs and agree between their own
    forward/decode paths."""
    import dataclasses
    base = reduced(get_config("mamba2-2.7b"))
    split = dataclasses.replace(
        base, ssm=dataclasses.replace(base.ssm, fused_in_proj=False))
    for cfg in (base, split):
        params = transformer.init_params(cfg, KEY)
        tokens = jnp.asarray(
            np.random.default_rng(8).integers(0, cfg.vocab_size, (1, 9)),
            jnp.int32)
        full = transformer.forward(cfg, params, tokens)
        assert np.isfinite(np.asarray(full["logits"])).all()
        _, cache = transformer.prefill(cfg, params, tokens[:, :8],
                                       cache_len=12)
        dec, _ = transformer.decode(cfg, params, tokens[:, 8], cache)
        np.testing.assert_allclose(np.asarray(dec["logits"][:, 0]),
                                   np.asarray(full["logits"][:, 8]),
                                   rtol=2e-3, atol=2e-3)
