"""Runtime integration tests: buffers, weight store + drain, the
dynamic-window batching trigger, segmenting, and a short end-to-end async
run (trainer steps happen, policy version advances, lag bounded)."""
import time

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import RLConfig, RuntimeConfig
from repro.data.replay import FIFOReplayBuffer, RingReplayBuffer
from repro.runtime import (DirectTransport, DiskTransport,
                           SerializedTransport, VersionedWeightStore)
from repro.runtime.inference import pad_to_bucket
from repro.runtime.rollout import episode_to_segments


def _tiny():
    import dataclasses
    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    return dataclasses.replace(cfg, num_prefix_tokens=1)


# ---------------------------------------------------------------------------
# buffers
# ---------------------------------------------------------------------------

def test_fifo_order_and_drop():
    buf = FIFOReplayBuffer(capacity=3)
    for i in range(5):
        buf.push(i)
    assert buf.total_dropped == 2
    assert buf.pop_batch(3, timeout=0.1) == [2, 3, 4]   # oldest first


def test_fifo_nonblocking_producer():
    """Full buffer never blocks the producer (full asynchrony)."""
    buf = FIFOReplayBuffer(capacity=1)
    t0 = time.monotonic()
    for i in range(1000):
        buf.push(i)
    assert time.monotonic() - t0 < 1.0
    assert len(buf) == 1


def test_ring_buffer_sampling():
    buf = RingReplayBuffer(capacity=10)
    assert buf.sample(2) is None
    for i in range(25):
        buf.push(i)
    s = buf.sample(50)
    assert all(15 <= x < 25 for x in s)     # only the newest capacity kept


# ---------------------------------------------------------------------------
# weight store + transports + drain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("transport", [DirectTransport(),
                                       SerializedTransport(),
                                       DiskTransport()])
def test_store_roundtrip(transport):
    import jax
    params = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
              "nested": {"b": np.ones(4, np.float32)}}
    store = VersionedWeightStore(transport=transport)
    store.publish(params, 3)
    got, v = store.acquire()
    assert v == 3
    np.testing.assert_array_equal(np.asarray(got["w"]), params["w"])
    np.testing.assert_array_equal(np.asarray(got["nested"]["b"]),
                                  params["nested"]["b"])


def test_drain_protocol():
    store = VersionedWeightStore()
    store.publish({"w": 1}, 0)
    assert not store.draining
    store.begin_publish()
    assert store.draining                 # inference stops scheduling
    store.publish({"w": 2}, 1)
    assert not store.draining             # cleared atomically with swap
    got, v = store.acquire(newer_than=0)
    assert v == 1 and got["w"] == 2


def test_acquire_blocks_until_newer():
    store = VersionedWeightStore()
    store.publish({"w": 1}, 0)
    assert store.acquire(newer_than=0, timeout=0.2) is None


# ---------------------------------------------------------------------------
# eq. 1 dynamic window
# ---------------------------------------------------------------------------

def test_bucket_padding():
    buckets = (1, 2, 4, 8, 16, 32)
    assert pad_to_bucket(1, buckets) == 1
    assert pad_to_bucket(3, buckets) == 4
    assert pad_to_bucket(9, buckets) == 16
    # regression: n > largest bucket used to return buckets[-1], making the
    # pad count negative so the stacked batch silently kept n rows
    with pytest.raises(ValueError):
        pad_to_bucket(100, buckets)
    from repro.runtime.inference import split_window
    assert split_window(100, buckets) == [32, 32, 32, 4]
    assert split_window(32, buckets) == [32]
    assert split_window(5, buckets) == [5]
    assert sum(split_window(33, buckets)) == 33


def test_oversized_window_served_in_chunks():
    """inference_batch > the largest bucket: every request still gets a
    correctly-shaped result (the window is split, not under-padded)."""
    from repro.models.policy import init_policy_params
    import jax
    cfg = _tiny()
    rt = RuntimeConfig(num_inference_workers=1, inference_batch=6,
                       inference_max_wait_s=2.0, batch_buckets=(1, 2, 4))
    store = VersionedWeightStore()
    store.publish(init_policy_params(cfg, jax.random.PRNGKey(0)), 0)
    from repro.runtime import InferenceService
    service = InferenceService(cfg, store, rt).start()
    try:
        rng = np.random.default_rng(0)
        futs = [service.submit(
            rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
            rng.random(192).astype(np.float32), 0) for _ in range(6)]
        for f in futs:
            res = f.result(timeout=120.0)
            assert res["actions"].shape == (cfg.action_dim,)
        assert service.requests_served == 6
        assert service.batches_run >= 2     # 6 reqs over max bucket 4 → split
    finally:
        service.stop()


def test_dynamic_window_trigger_batch_size():
    """|Q| >= B triggers immediately; otherwise T_max bounds the wait."""
    from repro.models.policy import init_policy_params
    import jax
    cfg = _tiny()
    rt = RuntimeConfig(num_inference_workers=1, inference_batch=4,
                       inference_max_wait_s=0.5)
    store = VersionedWeightStore()
    store.publish(init_policy_params(cfg, jax.random.PRNGKey(0)), 0)
    from repro.runtime import InferenceService
    service = InferenceService(cfg, store, rt).start()
    try:
        rng = np.random.default_rng(0)
        futs = [service.submit(
            rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
            rng.random(192).astype(np.float32), 0) for _ in range(4)]
        t0 = time.monotonic()
        for f in futs:
            f.result(timeout=120.0)
        # batch of 4 == B fired without waiting T_max (generous compile slack)
        assert service.batches_run >= 1
        one = service.submit(
            rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
            rng.random(192).astype(np.float32), 0)
        res = one.result(timeout=60.0)     # lone request: released by T_max
        assert "actions" in res
    finally:
        service.stop()


# ---------------------------------------------------------------------------
# segmenting (eq. 2 layout)
# ---------------------------------------------------------------------------

def _fake_traj(t, a=3):
    return {
        "obs_tokens": [np.full(5, i, np.int32) for i in range(t + 1)],
        "frames": [np.full(7, i, np.float32) for i in range(t + 1)],
        "actions": [np.full(a, i, np.int32) for i in range(t + 1)],
        "behavior_logp": [np.zeros(a, np.float32)] * (t + 1),
        "values": [float(i) for i in range(t + 1)],
        "rewards": [0.1 * i for i in range(t)],
        "dones": [0.0] * (t - 1) + [1.0],
        "steps": list(range(t + 1)),
        "policy_version": 5, "task_id": 2, "success": 1.0,
    }


def test_segments_cover_episode_exactly():
    t, h = 10, 4
    segs = episode_to_segments(_fake_traj(t), h)
    assert len(segs) == 3                   # 4 + 4 + 2(padded)
    assert sum(int(s["mask"].sum()) for s in segs) == t
    # bootstrap slot of segment k = first obs of segment k+1
    np.testing.assert_array_equal(segs[0]["obs_tokens"][-1],
                                  segs[1]["obs_tokens"][0])
    # eq. 2 shapes: T+1 entries for obs/actions/μ/v, T for r/done/mask
    s = segs[0]
    assert len(s["obs_tokens"]) == h + 1
    assert len(s["rewards"]) == h
    assert s["policy_version"] == 5


def test_segment_padding_masked():
    segs = episode_to_segments(_fake_traj(5), 4)
    tail = segs[-1]
    assert tail["mask"].tolist() == [1.0, 0.0, 0.0, 0.0]
    assert tail["rewards"][1] == 0.0        # padded reward zeroed


# ---------------------------------------------------------------------------
# end-to-end async smoke
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_async_system_end_to_end():
    from repro.runtime import AcceRLSystem
    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    rl = RLConfig(grad_accum=1, lr_policy=1e-4, lr_value=1e-3)
    rt = RuntimeConfig(num_rollout_workers=2, inference_batch=4)
    sys_ = AcceRLSystem(cfg, rl, rt, suite="spatial", segment_horizon=4,
                        max_episode_steps=8, batch_episodes=4)
    m = sys_.run_async(train_steps=2, wall_timeout_s=240.0)
    assert m["train_steps"] >= 2
    assert m["env_steps"] > 0
    assert m["episodes"] > 0
    assert 0 <= m["mean_policy_lag"] < 50
