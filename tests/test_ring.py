"""ShmRing contract tests: SPSC ordering, wraparound, full-ring
backpressure, torn-write/partial-commit invisibility + recovery, and the
cross-process data path (ISSUE 5 satellite coverage)."""
import multiprocessing

import numpy as np
import pytest

from repro.runtime.transport.ring import (HEADER_SIZE, RECORD_HEADER,
                                          RingError, ShmRing, shared_memory)

pytestmark = pytest.mark.skipif(
    shared_memory is None, reason="multiprocessing.shared_memory unavailable")


@pytest.fixture()
def ring():
    r = ShmRing.create(1 << 12)
    yield r
    r.close()
    r.unlink()


def test_roundtrip_order_and_stats(ring):
    payloads = [bytes([i]) * (i + 1) for i in range(10)]
    for p in payloads:
        assert ring.push(p, timeout=1.0)
    assert len(ring) == 10
    for p in payloads:
        assert ring.pop(timeout=1.0) == p
    s = ring.stats()
    assert s["items_pushed"] == 10 and s["items_popped"] == 10
    assert s["used_bytes"] == 0 and s["torn_discards"] == 0


def test_empty_pop_times_out(ring):
    assert ring.pop(timeout=0.05) is None


def test_wraparound_many_sizes():
    """Records of varying size cross the end-of-buffer boundary hundreds
    of times; every payload survives byte-exact and in order."""
    r = ShmRing.create(256)
    try:
        rng = np.random.default_rng(0)
        for i in range(1000):
            n = int(rng.integers(1, r.max_record() + 1))
            payload = bytes([i % 251]) * n
            assert r.push(payload, timeout=1.0)
            assert r.pop(timeout=1.0) == payload, f"iteration {i}, n={n}"
        # offsets are monotone: we really did lap the buffer many times
        assert r.stats()["items_pushed"] == 1000
    finally:
        r.close()
        r.unlink()


def test_wraparound_with_queued_records():
    """Several records in flight while the write position laps the read
    position — the interleaving exercises the WRAP-marker path with a
    non-empty queue."""
    r = ShmRing.create(512)
    try:
        sent = popped = 0
        expect = []
        for i in range(300):
            payload = bytes([i % 256]) * (17 + (i * 13) % 60)
            assert r.push(payload, timeout=1.0)
            expect.append(payload)
            sent += 1
            while len(r) > 3:                # drain with a bounded lag
                got = r.pop(timeout=1.0)
                assert got == expect[popped]
                popped += 1
        while popped < sent:
            assert r.pop(timeout=1.0) == expect[popped]
            popped += 1
    finally:
        r.close()
        r.unlink()


def test_full_ring_blocks_then_frees():
    r = ShmRing.create(128)
    try:
        assert r.push(b"a" * 40, timeout=0.1)
        assert r.push(b"b" * 40, timeout=0.1)
        assert not r.push(b"c" * 40, timeout=0.05)   # full: verdict, no hang
        assert r.pop(timeout=0.1) == b"a" * 40
        assert r.push(b"c" * 40, timeout=0.5)        # space freed
    finally:
        r.close()
        r.unlink()


def test_oversized_record_raises():
    r = ShmRing.create(256)
    try:
        with pytest.raises(RingError):
            r.push(b"x" * (r.max_record() + 1), timeout=0.1)
    finally:
        r.close()
        r.unlink()


def test_torn_write_is_invisible_and_recoverable():
    """A producer that died between reserve (write advanced) and commit:
    the consumer NEVER sees the partial record, and recover() discards
    the uncommitted tail so a successor producer can take over."""
    r = ShmRing.create(1 << 10)
    try:
        assert r.push(b"committed", timeout=0.1)
        view = r.reserve(64, timeout=0.1)        # reserve ...
        view[:32] = b"q" * 32                    # ... copy HALF ...
        view.release()                           # ... and die (no commit)
        # the committed record is served; the torn one is invisible
        consumer = ShmRing.attach(r.name)
        assert consumer.pop(timeout=0.1) == b"committed"
        assert consumer.pop(timeout=0.05) is None
        # a successor producer recovers the ring before producing
        successor = ShmRing.attach(r.name)
        assert successor.recover() is True
        assert successor.stats()["torn_discards"] == 1
        assert successor.recover() is False      # idempotent
        assert successor.push(b"after", timeout=0.5)
        assert consumer.pop(timeout=0.5) == b"after"
        consumer.close()
        successor.close()
    finally:
        r.close()
        r.unlink()


def test_corrupt_record_raises_not_garbage():
    r = ShmRing.create(512)
    try:
        assert r.push(b"x" * 24, timeout=0.1)
        # stomp the record header's seq field
        RECORD_HEADER.pack_into(r._shm.buf, HEADER_SIZE, 999, 24, 0)
        with pytest.raises(RingError):
            r.pop(timeout=0.1)
    finally:
        r.close()
        r.unlink()


def test_attach_bad_magic_raises():
    seg = shared_memory.SharedMemory(create=True, size=HEADER_SIZE + 64)
    try:
        with pytest.raises(RingError):
            ShmRing.attach(seg.name)
    finally:
        seg.close()
        seg.unlink()


def test_close_unblocks_waiters(ring):
    import threading
    out = []
    t = threading.Thread(target=lambda: out.append(ring.pop(timeout=30.0)))
    t.start()
    import time
    time.sleep(0.1)
    ring.close()
    t.join(timeout=5.0)
    assert not t.is_alive() and out == [None]


# ---------------------------------------------------------------------------
# Zero-copy pops (ISSUE 9): leases, split-record fallback, overwrite refusal
# ---------------------------------------------------------------------------


def test_pop_view_zero_copy_roundtrip(ring):
    payloads = [bytes([i]) * 100 for i in range(5)]
    for p in payloads:
        assert ring.push(p, timeout=1.0)
    for p in payloads:
        view = ring.pop_view(timeout=1.0)
        assert view is not None and not view.copied
        assert bytes(view.data) == p
        assert view.data.readonly          # consumers cannot scribble back
        view.release()
    s = ring.stats()
    assert s["views_served"] == 5
    assert s["bytes_copied"] == 0          # nothing was memcpy'd out
    assert s["views_live"] == 0


def test_split_record_served_as_copy():
    """A record wrapping the end of the buffer is stored SPLIT (no tail
    skip) and served through the copy fallback — byte-exact, flagged."""
    r = ShmRing.create(256)
    try:
        served_split = 0
        for i in range(200):
            payload = bytes([i % 251]) * (40 + i % 50)
            assert r.push(payload, timeout=1.0)
            view = r.pop_view(timeout=1.0)
            assert bytes(view.data) == payload, f"iteration {i}"
            served_split += int(view.copied)
            view.release()
        s = r.stats()
        assert served_split > 0            # the wrap case actually happened
        assert s["split_fallbacks"] == served_split
        assert s["bytes_copied"] > 0       # only the split records copied
    finally:
        r.close()
        r.unlink()


def test_live_view_blocks_producer_overwrite():
    """Leased bytes count as occupied: a producer that would overwrite a
    live view gets the full-ring verdict instead, and space frees the
    moment the lease is released."""
    r = ShmRing.create(128)
    try:
        assert r.push(b"a" * 40, timeout=0.1)
        view = r.pop_view(timeout=0.1)
        assert bytes(view.data) == b"a" * 40
        # read offset has NOT advanced: two more pushes fill the ring and
        # the third is refused while the lease pins the region
        assert r.push(b"b" * 40, timeout=0.1)
        assert not r.push(b"c" * 40, timeout=0.05)
        view.release()
        assert r.push(b"c" * 40, timeout=0.5)      # lease gone, space back
        assert r.pop(timeout=0.1) == b"b" * 40
        assert r.pop(timeout=0.1) == b"c" * 40
    finally:
        r.close()
        r.unlink()


def test_out_of_order_release_advances_in_order():
    """Releases free space only as an ordered prefix: releasing a LATER
    view first reclaims nothing until the earlier one goes too."""
    r = ShmRing.create(256)
    try:
        assert r.push(b"x" * 60, timeout=0.1)
        assert r.push(b"y" * 60, timeout=0.1)
        v1 = r.pop_view(timeout=0.1)
        v2 = r.pop_view(timeout=0.1)
        v2.release()                               # out of order
        assert r.stats()["views_live"] == 2        # v2 parked behind v1
        assert not r.push(b"z" * 90, timeout=0.05)  # v1 still pins the head
        v1.release()
        assert r.stats()["views_live"] == 0
        assert r.push(b"z" * 90, timeout=0.5)
    finally:
        r.close()
        r.unlink()


def test_pop_view_corrupt_seq_raises(ring):
    assert ring.push(b"x" * 24, timeout=0.1)
    RECORD_HEADER.pack_into(ring._shm.buf, HEADER_SIZE, 999, 24, 0)
    with pytest.raises(RingError):
        ring.pop_view(timeout=0.1)


def test_publish_blob_and_read_at():
    """The weight-lane primitive: one blob per version, positional reads,
    stale seq detected instead of serving torn bytes."""
    r = ShmRing.create(1 << 10)
    try:
        reader = ShmRing.attach(r.name)
        pos1, seq1 = r.publish_blob(b"v1" * 100)
        assert reader.read_at(pos1, seq1, 200) == b"v1" * 100
        pos2, seq2 = r.publish_blob(b"v2" * 120)
        assert seq2 > seq1
        assert reader.read_at(pos2, seq2, 240) == b"v2" * 120
        # lap the ring so v1's record is actually overwritten: the stale
        # (pos, seq) now fails header validation instead of serving
        # someone else's bytes
        for i in range(8):
            pos2, seq2 = r.publish_blob(bytes([i]) * 300)
        assert reader.read_at(pos1, seq1, 200) is None
        assert reader.read_at(pos2, seq2, 300) == bytes([7]) * 300
        reader.close()
    finally:
        r.close()
        r.unlink()


def _child_producer(name, count):
    r = ShmRing.attach(name)
    for i in range(count):
        payload = np.full(64 + i % 32, i % 256, np.uint8).tobytes()
        if not r.push(payload, timeout=30.0):
            raise SystemExit(2)
    r.close()


def test_cross_process_spsc():
    """The real topology: producer in another (spawned) process, consumer
    here — every record arrives intact and in order."""
    r = ShmRing.create(1 << 12)
    try:
        count = 200
        ctx = multiprocessing.get_context("spawn")
        proc = ctx.Process(target=_child_producer, args=(r.name, count))
        proc.start()
        for i in range(count):
            got = r.pop(timeout=60.0)
            assert got == np.full(64 + i % 32, i % 256, np.uint8).tobytes()
        proc.join(timeout=30.0)
        assert proc.exitcode == 0
    finally:
        r.close()
        r.unlink()
