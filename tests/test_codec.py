"""Wire codec edge cases: bf16, empty arrays, 0-d scalars, nested
dict/tuple/list pytrees, version/schema header validation, zero-copy
decode, and round-trip parity with the in-process channel (a segment that
crosses the wire must be indistinguishable from one that did not)."""
import numpy as np
import pytest

from repro.runtime.experience import FifoChannel
from repro.runtime.rollout import episode_to_segments
from repro.runtime.transport.codec import (CodecError, decode_pytree,
                                           encode_pytree)


def assert_tree_equal(a, b, path=""):
    assert type(a) is type(b) or (
        isinstance(a, np.ndarray) and isinstance(b, np.ndarray)), \
        f"{path}: {type(a)} vs {type(b)}"
    if isinstance(a, dict):
        assert list(a.keys()) == list(b.keys()), path
        for k in a:
            assert_tree_equal(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b), path
        for i, (x, y) in enumerate(zip(a, b)):
            assert_tree_equal(x, y, f"{path}[{i}]")
    elif isinstance(a, (np.ndarray, np.generic)):
        assert a.dtype == b.dtype, f"{path}: {a.dtype} vs {b.dtype}"
        assert a.shape == b.shape, f"{path}: {a.shape} vs {b.shape}"
        np.testing.assert_array_equal(np.asarray(a, np.float64)
                                      if a.dtype.name == "bfloat16"
                                      else a,
                                      np.asarray(b, np.float64)
                                      if b.dtype.name == "bfloat16"
                                      else b, err_msg=path)
    else:
        assert a == b, path


# ---------------------------------------------------------------------------
# structure round trips
# ---------------------------------------------------------------------------

def test_roundtrip_nested_structures():
    tree = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "nested": {"ints": np.arange(5, dtype=np.int64),
                   "tup": (np.float32(1.5), [np.ones(2), None, "label"]),
                   "flags": [True, False, 3, 2.5]},
        "none": None,
    }
    out = decode_pytree(encode_pytree(tree))
    assert_tree_equal(out, tree)
    assert isinstance(out["nested"]["tup"], tuple)
    assert isinstance(out["nested"]["tup"][1], list)


def test_roundtrip_bf16():
    jnp = pytest.importorskip("jax.numpy")
    x = jnp.linspace(-3.0, 3.0, 37).astype(jnp.bfloat16).reshape(1, 37)
    out = decode_pytree(encode_pytree({"w": x, "b": np.asarray(x)[0]}))
    assert out["w"].dtype.name == "bfloat16"
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.asarray(x, np.float32))
    np.testing.assert_array_equal(np.asarray(out["b"], np.float32),
                                  np.asarray(x, np.float32)[0])


def test_roundtrip_empty_arrays():
    tree = {"e1": np.zeros((0,), np.float32),
            "e2": np.zeros((3, 0, 2), np.int32),
            "full": np.ones((2, 2))}
    out = decode_pytree(encode_pytree(tree))
    assert_tree_equal(out, tree)
    assert out["e2"].shape == (3, 0, 2)


def test_roundtrip_zero_d_scalars():
    tree = {"v": np.int32(7), "f": np.float32(-2.5),
            "arr0": np.array(5.5)}
    out = decode_pytree(encode_pytree(tree))
    assert isinstance(out["v"], np.int32) and out["v"] == 7
    assert isinstance(out["f"], np.float32) and out["f"] == np.float32(-2.5)
    # 0-d ndarray stays a 0-d ndarray (not promoted to a scalar or 1-d)
    assert isinstance(out["arr0"], np.ndarray) and out["arr0"].shape == ()


def test_zero_copy_views_and_copy_mode():
    tree = {"x": np.arange(64, dtype=np.float32)}
    blob = encode_pytree(tree)
    view = decode_pytree(blob)["x"]
    assert view.base is not None           # zero-copy: a view over the blob
    assert not view.flags.writeable
    copied = decode_pytree(blob, copy=True)["x"]
    assert copied.flags.writeable
    copied[:] = 0                           # writable, independent
    np.testing.assert_array_equal(view, tree["x"])


def test_non_contiguous_and_device_arrays():
    jnp = pytest.importorskip("jax.numpy")
    x = np.arange(24, dtype=np.float32).reshape(4, 6)
    tree = {"t": x.T, "dev": jnp.arange(8)}   # transpose = non-contiguous
    out = decode_pytree(encode_pytree(tree))
    np.testing.assert_array_equal(out["t"], x.T)
    np.testing.assert_array_equal(out["dev"], np.arange(8))


# ---------------------------------------------------------------------------
# header validation
# ---------------------------------------------------------------------------

def test_bad_magic_version_truncation():
    blob = encode_pytree({"x": np.ones(4)})
    with pytest.raises(CodecError):
        decode_pytree(b"XXXX" + blob[4:])          # magic
    bad_ver = bytearray(blob)
    bad_ver[4:6] = (99).to_bytes(2, "big")
    with pytest.raises(CodecError):
        decode_pytree(bytes(bad_ver))              # wire version
    with pytest.raises(CodecError):
        decode_pytree(blob[:len(blob) - 8])        # truncated body
    with pytest.raises(CodecError):
        decode_pytree(b"ACR")                      # shorter than preamble


def test_non_string_dict_keys_rejected():
    with pytest.raises(CodecError):
        encode_pytree({1: np.ones(2)})


def test_unencodable_leaf_rejected():
    with pytest.raises(CodecError):
        encode_pytree({"fn": lambda: None})


# ---------------------------------------------------------------------------
# parity with the in-process channel
# ---------------------------------------------------------------------------

def _fake_episode(t=7, frame_dim=6, action_dim=3):
    rng = np.random.default_rng(0)
    traj = {
        "obs_tokens": [rng.integers(0, 50, 5).astype(np.int32)
                       for _ in range(t + 1)],
        "frames": [rng.standard_normal(frame_dim).astype(np.float32)
                   for _ in range(t + 1)],
        "actions": [rng.integers(0, 8, action_dim).astype(np.int32)
                    for _ in range(t + 1)],
        "behavior_logp": [rng.standard_normal(action_dim).astype(np.float32)
                          for _ in range(t + 1)],
        "values": [float(v) for v in rng.standard_normal(t + 1)],
        "rewards": [float(v) for v in rng.standard_normal(t)],
        "dones": [0.0] * (t - 1) + [1.0],
        "steps": list(range(t + 1)),
        "policy_version": 3,
        "task_id": 1,
        "success": 1.0,
    }
    return episode_to_segments(traj, horizon=4)


def test_segment_parity_with_in_process_channel():
    """A rollout segment decoded off the wire must be leaf-for-leaf equal
    (values, dtypes, shapes, scalar-ness) to the one the in-process
    channel delivers."""
    segments = _fake_episode()
    local = FifoChannel(16)
    for seg in segments:
        local.put(seg)
    popped = local.pop_batch(len(segments), timeout=1.0)
    wired = decode_pytree(encode_pytree(segments))
    assert len(wired) == len(popped)
    for a, b in zip(popped, wired):
        assert_tree_equal(b, a)
        assert isinstance(b["policy_version"], np.int32)
