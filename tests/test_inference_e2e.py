"""Disaggregated-inference-plane acceptance: spawned rollout workers
served by one SHARED continuous-batching tier behind the transport, with
``kill -9`` of the tier mid-episode recovering through supervised restart
(same fixed port), worker redial, and exactly-once result replay.

These spawn jax-initializing subprocesses — slow by nature; CI runs them
in the dedicated inference-smoke job under a hard SIGKILL timeout."""
import os
import signal
import threading
import time

import pytest

from repro.configs import get_config, reduced
from repro.configs.base import (RLConfig, RuntimeConfig, SupervisionConfig,
                                TransportConfig)


def _system(*, spawn_workers=1, inference_plane="spawn", restart="never",
            max_restarts=2, seed=0):
    from repro.runtime import AcceRLSystem
    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    rl = RLConfig(grad_accum=1, lr_policy=1e-4, lr_value=1e-3)
    rt = RuntimeConfig(
        num_rollout_workers=0, inference_batch=4,
        transport=TransportConfig(
            remote_rollout_workers=spawn_workers,
            heartbeat_s=0.1, token="infer-e2e",
            inference_plane=inference_plane,
            reconnect_attempts=25, reconnect_backoff_s=0.1,
            supervision=SupervisionConfig(
                restart=restart, max_restarts=max_restarts,
                backoff_initial_s=0.05, backoff_max_s=0.5)))
    return AcceRLSystem(cfg, rl, rt, suite="spatial", segment_horizon=4,
                        max_episode_steps=8, batch_episodes=4, seed=seed)


@pytest.mark.slow
def test_host_mode_serves_remote_workers_from_parent_pool():
    """Host mode: the parent's own InferenceService answers ``infer.*``
    requests from a spawned worker — with ZERO local rollout workers,
    every request the parent pool serves arrived over the wire."""
    sys_ = _system(spawn_workers=1, inference_plane="host", seed=0)
    m = sys_.run_async(train_steps=2, wall_timeout_s=240.0)
    assert m["train_steps"] >= 2
    # the parent pool did the remote worker's inference
    assert sys_.inference.requests_served > 0
    srv = sys_.transport_server.metrics.snapshot()["counters"]
    assert srv.get("infer_submits", 0) > 0
    assert srv.get("infer_results", 0) > 0
    entry = m["services"]["remote-rollout-0"]
    assert entry["counters"]["env_steps"] > 0
    # version tags flowed back over the wire into the worker's gauge
    assert entry["gauges"]["policy_version"] >= 0


@pytest.mark.slow
def test_spawn_tier_sigkill_mid_episode_recovers_exactly_once():
    """Acceptance: SIGKILL the shared inference tier mid-episode. The
    Supervisor respawns it on the SAME fixed port, workers redial and
    replay their in-flight requests to the new epoch, training reaches
    its budget, and every service ends healthy with coherent policy
    versions."""
    sys_ = _system(spawn_workers=2, inference_plane="spawn",
                   restart="on_failure", max_restarts=3, seed=1)
    plane = sys_.inference_plane_host
    assert plane is not None
    addr_before = sys_.infer_address
    worker_slots = sys_.remote_hosts
    killed = [0]

    def killer():
        deadline = time.monotonic() + 180.0
        while time.monotonic() < deadline:
            # mid-episode: workers are producing (so requests are in
            # flight against the tier) when the tier dies
            if (any(s.env_steps > 0 for s in worker_slots)
                    and plane.process is not None):
                killed[0] = plane.process.pid
                os.kill(plane.process.pid, signal.SIGKILL)
                return
            time.sleep(0.05)

    t = threading.Thread(target=killer, daemon=True)
    t.start()
    m = sys_.run_async(train_steps=2, wall_timeout_s=300.0)
    t.join(timeout=5.0)

    assert killed[0], "killer never fired"
    assert m["train_steps"] >= 2
    assert plane.restarts >= 1, "tier kill was never detected"
    # the replacement rebinds the SAME pre-allocated address — that is
    # what lets workers simply redial instead of re-discovering the tier
    assert sys_.infer_address == addr_before
    health = sys_.health()
    assert health["inference-plane"]["state"] == "stopped", health
    assert health["inference-plane"]["error"] is None
    for i in range(2):
        assert health[f"remote-rollout-{i}"]["state"] == "stopped", health
        entry = m["services"][f"remote-rollout-{i}"]
        assert entry["counters"]["env_steps"] > 0
        # coherent version tags across the kill: the gauge is the version
        # the worker last rolled out with — a real published version, not
        # a torn/stale sentinel
        assert 0 <= entry["gauges"]["policy_version"] <= m["train_steps"]
    # the tier's report bridges pool + broker pressure for ElasticPolicy
    tier = m["services"]["inference-plane"]
    assert tier["counters"]["requests"] > 0
    assert "queue_depth" in tier["gauges"]
    assert "window_fill" in tier["gauges"]
    assert m["mean_policy_lag"] >= 0.0
