"""Per-kernel interpret-mode validation: shape/dtype sweeps against the
pure-jnp oracles in ``repro.kernels.ref`` (deliverable (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gipo_loss import gipo_loss_fused
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,s,h,kv,d", [
    (1, 128, 128, 4, 4, 64),      # MHA square
    (2, 128, 128, 4, 1, 64),      # MQA
    (2, 64, 256, 8, 2, 64),       # GQA, cross lengths
    (1, 100, 100, 4, 2, 64),      # non-multiple of block (padding path)
    (1, 256, 256, 2, 2, 128),     # MXU-width head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_reference(b, t, s, h, kv, d, dtype):
    q = jnp.asarray(RNG.standard_normal((b, t, h, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, s, kv, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, s, kv, d)), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    exp = ref.reference_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    b, t, h, d = 1, 256, 2, 64
    q = jnp.asarray(RNG.standard_normal((b, t, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, t, h, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, t, h, d)), jnp.float32)
    out = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                          interpret=True)
    exp = ref.reference_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_blocksizes_agree():
    b, t, h, d = 1, 256, 2, 64
    q = jnp.asarray(RNG.standard_normal((b, t, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, t, h, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, t, h, d)), jnp.float32)
    a = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    c = flash_attention(q, k, v, block_q=128, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# fused GIPO loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,v", [(64, 32), (300, 64), (512, 256),
                                 (1000, 48)])
@pytest.mark.parametrize("sigma", [0.2, 0.5])
def test_gipo_fused_matches_reference(n, v, sigma):
    logits = jnp.asarray(RNG.standard_normal((n, v)) * 3, jnp.float32)
    targets = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
    logp_old = jnp.asarray(RNG.standard_normal(n) * 0.3, jnp.float32)
    adv = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    mask = jnp.asarray((RNG.random(n) > 0.15).astype(np.float32))
    l1, m1 = gipo_loss_fused(logits, targets, logp_old, adv, mask, sigma,
                             block_n=128, interpret=True)
    l2, m2 = ref.reference_gipo_loss(logits, targets, logp_old, adv, mask,
                                     sigma)
    assert float(l1) == pytest.approx(float(l2), rel=1e-4, abs=1e-5)
    assert float(m1["ratio_mean"]) == pytest.approx(
        float(m2["ratio_mean"]), rel=1e-4)
    assert float(m1["omega_mean"]) == pytest.approx(
        float(m2["omega_mean"]), rel=1e-4)


def test_gipo_fused_bf16_logits():
    n, v = 256, 64
    logits = jnp.asarray(RNG.standard_normal((n, v)), jnp.bfloat16)
    targets = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
    logp_old = jnp.zeros(n)
    adv = jnp.ones(n)
    mask = jnp.ones(n)
    l1, _ = gipo_loss_fused(logits, targets, logp_old, adv, mask, 0.2,
                            interpret=True)
    l2, _ = ref.reference_gipo_loss(logits.astype(jnp.float32), targets,
                                    logp_old, adv, mask, 0.2)
    assert float(l1) == pytest.approx(float(l2), rel=5e-2, abs=5e-2)


# ---------------------------------------------------------------------------
# SSD chunked scan (the state-space duality test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 3, 16, 8, 32),
    (1, 128, 1, 64, 128, 64),     # mamba2-2.7b-like head
    (2, 256, 4, 32, 16, 128),
])
def test_ssd_scan_matches_recurrent_oracle(b, t, h, p, n, chunk):
    x = jnp.asarray(RNG.standard_normal((b, t, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.random((b, t, h)) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(RNG.random(h) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((b, t, n)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((b, t, n)), jnp.float32)
    y1, s1 = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y2, s2 = ref.reference_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)


def test_ssd_scan_bf16_inputs():
    b, t, h, p, n = 1, 64, 2, 16, 8
    x = jnp.asarray(RNG.standard_normal((b, t, h, p)), jnp.bfloat16)
    dt = jnp.asarray(RNG.random((b, t, h)) * 0.1 + 0.01, jnp.float32)
    A = -jnp.ones(h)
    Bm = jnp.asarray(RNG.standard_normal((b, t, n)), jnp.bfloat16)
    Cm = jnp.asarray(RNG.standard_normal((b, t, n)), jnp.bfloat16)
    y1, s1 = ssd_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    y2, s2 = ref.reference_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=5e-2,
                               atol=5e-2)


def test_model_ssd_chunked_matches_kernel_oracle():
    """The model-layer SSD (models/ssm.ssd_chunked) agrees with the same
    oracle the kernel is tested against — one source of truth."""
    from repro.models.ssm import ssd_chunked
    b, t, h, p, n = 2, 128, 3, 16, 8
    x = jnp.asarray(RNG.standard_normal((b, t, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.random((b, t, h)) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(RNG.random(h) + 0.5, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((b, t, n)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((b, t, n)), jnp.float32)
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    y2, s2 = ref.reference_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)
