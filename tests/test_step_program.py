"""Train-step IR (runtime/step_program.py): dataflow validation, fused
parity against the historical step, microbatch gradient-accumulation
parity, and the ZeRO-2 optimizer-state wiring."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import RLConfig
from repro.core.train_step import init_train_state, make_train_step
from repro.data.trajectory import dummy_batch
from repro.runtime.step_program import (StageSpec, StepProgram,
                                        build_train_step_program)

CFG = reduced(get_config("deepseek-7b"), layers=2, d_model=64)


def _batch(b=4, seed=0):
    return dummy_batch(b, 4, 12, CFG.action_dim, CFG.vocab_size,
                       CFG.action_vocab_size, seed=seed)


def _max_diff(t1, t2):
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), t1, t2)
    return max(jax.tree.leaves(d))


# ---------------------------------------------------------------------------
# IR structure
# ---------------------------------------------------------------------------

def test_program_shape():
    prog = build_train_step_program(CFG, RLConfig(grad_accum=3))
    assert [s.name for s in prog.stages] == [
        "collate", "fwd_bwd", "grad_reduce", "optim_update", "publish"]
    assert prog.n_micro == 3
    assert prog.stage("fwd_bwd").per_micro
    assert prog.stage("grad_reduce").init is not None
    assert prog.stage("collate").kind == "host"
    assert prog.stage("publish").kind == "host"
    desc = prog.describe()
    for name in ("collate", "fwd_bwd", "grad_reduce", "optim_update"):
        assert name in desc
    with pytest.raises(KeyError):
        prog.stage("nope")


def test_program_rejects_dangling_input():
    with pytest.raises(ValueError, match="reads"):
        StepProgram(name="bad", inputs=("a",), stages=(
            StageSpec("s1", inputs=("a", "ghost"), outputs=("b",)),))


def test_program_rejects_duplicate_stage():
    with pytest.raises(ValueError, match="duplicate"):
        StepProgram(name="bad", inputs=("a",), stages=(
            StageSpec("s1", inputs=("a",), outputs=("b",)),
            StageSpec("s1", inputs=("b",), outputs=("c",))))


def test_stage_dataflow_chains():
    """Later stages may only read external feeds or earlier outputs —
    the declared order must itself be a valid topological order."""
    prog = build_train_step_program(CFG, RLConfig())
    produced = set(prog.inputs)
    for s in prog.stages:
        assert all(b in produced for b in s.inputs)
        produced.update(s.outputs)


# ---------------------------------------------------------------------------
# fused parity: the IR's fused form IS the historical train step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused_loss", [True, False])
def test_fused_form_matches_make_train_step(fused_loss):
    rl = RLConfig(grad_accum=2, fused_loss=fused_loss, lr_policy=1e-4,
                  lr_value=1e-3)
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    batch = _batch(seed=7)

    s1, m1 = make_train_step(CFG, rl, donate=False)(state, batch)
    prog = build_train_step_program(CFG, rl)
    s2, m2 = prog.fused(donate=False)(state, batch)

    assert _max_diff(s1.params, s2.params) == 0.0
    assert float(m1["loss"]) == float(m2["loss"])
    assert int(s2.version) == 1


# ---------------------------------------------------------------------------
# microbatch gradient-accumulation parity (satellite): K accumulated
# micro-batches == one full batch at fixed seed, fused and plain paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused_loss", [True, False])
@pytest.mark.parametrize("k", [2, 4])
def test_grad_accum_parity(fused_loss, k):
    # full-ones mask → every micro-batch carries the same token count, so
    # the mean-of-means equals the full-batch mean exactly
    rl_full = RLConfig(grad_accum=1, fused_loss=fused_loss,
                       lr_policy=1e-4, lr_value=1e-3)
    rl_micro = RLConfig(grad_accum=k, fused_loss=fused_loss,
                        lr_policy=1e-4, lr_value=1e-3)
    state = init_train_state(CFG, jax.random.PRNGKey(1))
    batch = _batch(b=8, seed=11)
    assert np.all(np.asarray(batch.mask) == 1.0)

    s_full, m_full = make_train_step(CFG, rl_full, donate=False)(state, batch)
    s_k, m_k = make_train_step(CFG, rl_micro, donate=False)(state, batch)

    assert _max_diff(s_full.params, s_k.params) < 1e-5
    # the accumulated adv stats are sums — identical partitioning or not
    assert abs(float(s_full.adv_norm.count) - float(s_k.adv_norm.count)) < 1e-3


# ---------------------------------------------------------------------------
# ZeRO-2 wiring (satellite): moments under shard_moments_spec, realized
# per-device footprint == the analytic claim
# ---------------------------------------------------------------------------

def test_moment_shardings_single_device_noop():
    """On a 1-device mesh init_train_state's ZeRO path must be a no-op."""
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    s0 = init_train_state(CFG, jax.random.PRNGKey(0))
    s1 = init_train_state(CFG, jax.random.PRNGKey(0), mesh=mesh)
    assert _max_diff(s0.opt.mu, s1.opt.mu) == 0.0


def test_program_declares_zero_specs():
    """With a mesh, optim_update's state buffer declares params under the
    TP rules and moments additionally sharded over ``data``."""
    from jax.sharding import AbstractMesh
    from jax.sharding import PartitionSpec as P
    mesh = AbstractMesh((("data", 16), ("model", 16)))
    prog = build_train_step_program(CFG, RLConfig(), mesh=mesh)
    specs = prog.stage("optim_update").specs["state"]
    assert set(specs) == {"params", "moments", "scalars"}
    assert specs["scalars"] == P()
    n_zero = sum(
        1 for pp, mp in zip(jax.tree.leaves(specs["params"]),
                            jax.tree.leaves(specs["moments"]))
        if mp != pp and any(
            "data" in (e if isinstance(e, tuple) else (e,)) for e in mp))
    assert n_zero > 0, "no moment tensor picked up a data-axis shard"


_REALIZED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp
from jax.sharding import Mesh
import numpy as np
from repro.optim import adamw, zero

D = 8
mesh = Mesh(np.array(jax.devices()).reshape(D, 1), ("data", "model"))
# every axis divisible by D -> the analytic bound is achieved exactly
params = {"w1": jnp.zeros((64, 32)), "w2": jnp.zeros((16, 128)),
          "b": jnp.zeros((256,))}
opt = adamw.init(params)
opt = zero.shard_opt_state(opt, mesh)
count = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
want = zero.moments_bytes_per_device(count, D, zero=True)
got = zero.realized_moments_bytes_per_device(opt)
assert got == want, (got, want)
# and the un-sharded baseline really is D x bigger
assert zero.realized_moments_bytes_per_device(adamw.init(params)) \
    == zero.moments_bytes_per_device(count, D, zero=False)
print("OK", got)
"""


def test_realized_moments_bytes_match_analytic():
    """Spawn with 8 forced CPU devices: the measured per-device moment
    footprint equals ``moments_bytes_per_device`` (the §3.1 claim)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _REALIZED_SCRIPT],
                         capture_output=True, text=True, timeout=300,
                         env=env)
    assert out.returncode == 0, out.stderr
    assert out.stdout.startswith("OK")
