"""Pipelined two-stage training vs the sequential same-device baseline.

The claim (ISSUE 10 / ROADMAP "pipelined multi-device training runtime"):
running the policy trainer and the world-model trainer as pipeline stages
on DISJOINT submeshes (runtime/pipeline_exec.py static schedules) is at
least as fast per round as running the two stages back-to-back on one
device — and reports how much of each stream's round is bubble.

Forces a 2-CPU-device XLA backend so the submeshes are real. On hosts
with >= 2 physical cores the speedup assertion is enforced (same gating
pattern as benchmarks/backpressure.py); on 1-core hosts the numbers are
still recorded, the assertion is skipped.

    REPRO_BENCH_OUT=/tmp/bench PYTHONPATH=src python -m benchmarks.pipeline
"""
from __future__ import annotations

import multiprocessing
import os
import time

# must land before the first jax import (device count is fixed at backend
# init) — append, never clobber, any caller-provided XLA_FLAGS
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2").strip()

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, tiny_cfg
from repro.configs.base import RLConfig, WMConfig
from repro.core.train_step import init_train_state
from repro.data.trajectory import dummy_batch
from repro.envs.toy_manipulation import FRAME_DIM
from repro.optim import adamw
from repro.runtime.pipeline_exec import PipelineExecutor, SubmeshLayout
from repro.runtime.step_program import build_train_step_program
from repro.wm import denoiser as dn

ROUNDS = 4
K = 2                               # policy micro-batches per round
WM_MICRO = 2                        # WM cycles per round


def _wm_stage(wm: WMConfig, cfg):
    """A real M_obs denoiser train step with its own carried state —
    the second pipeline stage."""
    key = jax.random.PRNGKey(7)
    params = dn.denoiser_init(key, FRAME_DIM, cfg.action_dim,
                              cfg.action_vocab_size, wm)
    opt = adamw.init(params)
    step = dn.make_denoiser_train_step(wm)
    holder = {"params": params, "opt": opt, "key": key}

    def run(batch):
        f1, hist, ac = batch
        holder["key"], sub = jax.random.split(holder["key"])
        holder["params"], holder["opt"], loss = step(
            holder["params"], holder["opt"], sub, f1, hist, ac)
        jax.block_until_ready(loss)
        return {"loss": float(loss)}

    return run


def _wm_batches(wm: WMConfig, cfg, n, *, batch=16, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        f1 = rng.standard_normal((batch, FRAME_DIM)).astype(np.float32)
        hist = rng.standard_normal(
            (batch, wm.history_frames, FRAME_DIM)).astype(np.float32)
        ac = rng.integers(0, cfg.action_vocab_size,
                          (batch, cfg.action_dim)).astype(np.int32)
        out.append((f1, hist, ac))
    return out


def main() -> None:
    cfg = tiny_cfg(layers=2, d_model=64)
    rl = RLConfig(grad_accum=K, fused_loss=True, lr_policy=1e-4,
                  lr_value=1e-3)
    wm = WMConfig(history_frames=2, denoiser_layers=2, denoiser_d_model=64,
                  diffusion_steps=4)
    prog = build_train_step_program(cfg, rl)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    batch = dummy_batch(4, 4, 12, cfg.action_dim, cfg.vocab_size,
                        cfg.action_vocab_size, num_prefix=1, seed=3)

    # -- sequential baseline: both stages back-to-back on device 0 ----------
    fused = prog.fused(donate=False)
    seq_wm = _wm_stage(wm, cfg)
    dev0 = jax.devices()[0]
    with jax.default_device(dev0):
        # warmup (compile both stages)
        jax.block_until_ready(fused(state, batch))
        for b in _wm_batches(wm, cfg, WM_MICRO, seed=99):
            seq_wm(b)
        seq_times = []
        s = state
        for r in range(ROUNDS):
            wmb = _wm_batches(wm, cfg, WM_MICRO, seed=r)
            t0 = time.perf_counter()
            s, m = fused(s, batch)
            jax.block_until_ready(m["loss"])
            for b in wmb:
                seq_wm(b)
            seq_times.append(time.perf_counter() - t0)
    t_seq = float(np.median(seq_times))

    # -- pipelined: policy on device 0, WM on device 1 ----------------------
    layout = SubmeshLayout.split(jax.devices())
    assert layout.disjoint, "forced 2-device backend did not split"
    pipe_wm = _wm_stage(wm, cfg)
    feeds: list = []
    ex = PipelineExecutor(prog, layout)
    ex.set_wm_stage(pipe_wm, lambda: feeds.pop() if feeds else None,
                    wm_micro=WM_MICRO)
    # warmup (compile on the pipeline's devices)
    feeds.extend(_wm_batches(wm, cfg, WM_MICRO, seed=99))
    ex.run_round(state, batch)
    pipe_times, bubbles = [], []
    s = state
    for r in range(ROUNDS):
        feeds.extend(_wm_batches(wm, cfg, WM_MICRO, seed=r))
        t0 = time.perf_counter()
        s, m, _ = ex.run_round(s, batch)
        pipe_times.append(time.perf_counter() - t0)
        bubbles.append(dict(ex.last_bubble))
    peak_grad = ex.peak_grad_bytes
    ex.close()
    t_pipe = float(np.median(pipe_times))

    speedup = t_seq / max(t_pipe, 1e-9)
    cores = multiprocessing.cpu_count() or 1
    result = {
        "rounds": ROUNDS,
        "policy_microbatches": K,
        "wm_microbatches": WM_MICRO,
        "cpu_count": cores,
        "t_seq_round_ms": t_seq * 1e3,
        "t_pipe_round_ms": t_pipe * 1e3,
        "speedup_x": speedup,
        "bubble_frac_policy": float(np.mean(
            [b.get("policy", 0.0) for b in bubbles])),
        "bubble_frac_wm": float(np.mean(
            [b.get("wm", 0.0) for b in bubbles])),
        # 1F1B bound: live grads never exceed ONE micro-batch's tree
        "peak_live_grads_bytes": int(peak_grad),
    }
    print(f"sequential {t_seq * 1e3:.1f} ms/round | pipelined "
          f"{t_pipe * 1e3:.1f} ms/round | speedup {speedup:.2f}x | "
          f"bubbles policy={result['bubble_frac_policy']:.2f} "
          f"wm={result['bubble_frac_wm']:.2f} | cores={cores}")
    grad_tree = sum(l.nbytes for l in jax.tree.leaves(state.params))
    assert peak_grad == grad_tree, (peak_grad, grad_tree)
    if cores >= 2:
        # two real cores: overlapping the stages must not be slower than
        # running them back-to-back
        assert speedup >= 1.0, (
            f"pipelined round slower than sequential on a {cores}-core "
            f"host: {t_pipe * 1e3:.1f} ms vs {t_seq * 1e3:.1f} ms")
    save("BENCH_pipeline", result)


if __name__ == "__main__":
    main()
