"""Device-ingest benchmark (ISSUE 9): the zero-copy pop pipeline.

Three sections, each pinning one layer of the ingest path:

  * ``ring``     — the :class:`ShmRing` pop primitive in isolation:
    copying ``pop()`` vs zero-copy ``pop_view()`` over identical record
    streams. Record size divides the ring capacity exactly, so the
    zero-copy run never hits the split-record fallback — its
    ``bytes_copied`` counter is EXACTLY zero and both byte counters are
    deterministic (``*_bytes`` keys are exact-gated by the perf gate).
  * ``pipeline`` — the end-to-end consumer path: a prefilled server-side
    channel drained through a :class:`ShmRingChannel` into a staging
    :class:`Prefetcher` (collate → pooled slab), with ``zero_copy_pop``
    off (ring records memcpy'd out) vs on (decoded items view the ring,
    leases released after collate). The reduction in per-pop copied
    bytes is counter-asserted here AND exact-gated via the JSON.
  * ``window``   — adaptive vs static PutStream windowing against a
    server-side channel with and without induced RTT jitter (periodic
    sleeps in the apply path, which delay the cumulative acks). Steady
    RTT must not throttle below the static window (asserted with ≥2
    CPUs); under jitter the adaptive stream must actually back off.

Emits ``BENCH_ingest.json`` (honors ``REPRO_BENCH_OUT``), gated by
``benchmarks.perf_gate`` against the committed baseline.
"""
from __future__ import annotations

import multiprocessing
import threading
import time
from typing import Dict

import numpy as np

from benchmarks.common import save
from repro.data.prefetch import Prefetcher
from repro.runtime.experience import FifoChannel
from repro.runtime.transport import (PutStream, ShmRingChannel,
                                     TransportServer)
from repro.runtime.transport.ring import ShmRing


# ---------------------------------------------------------------------------
# ring section: the pop primitive, copy vs zero-copy
# ---------------------------------------------------------------------------

def _drive_ring(zero_copy: bool, *, records: int) -> Dict:
    """Alternating push/pop over a fresh ring. The padded record size
    (header + payload) divides the capacity, so records never wrap the
    end of the buffer and the zero-copy path never falls back to a
    split-record copy — both byte counters are deterministic."""
    capacity = 1 << 20
    payload = bytes(capacity // 16 - 16)         # record header is 16 B
    r = ShmRing.create(capacity)
    try:
        t0 = time.perf_counter()
        for _ in range(records):
            assert r.push(payload, timeout=5.0)
            if zero_copy:
                view = r.pop_view(timeout=5.0)
                assert view is not None
                # a real consumer reads the bytes in place (collate);
                # len() keeps the loop honest without a memcpy
                assert len(view.data) == len(payload)
                view.release()
            else:
                got = r.pop(timeout=5.0)
                assert got is not None and len(got) == len(payload)
        wall = time.perf_counter() - t0
        s = r.stats()
    finally:
        r.close()
        r.unlink()
    return {
        "mode": "zero_copy" if zero_copy else "copy",
        "records": records,
        "record_bytes_each": len(payload),
        "pop_bytes": int(s["bytes_copied"]),
        "views_served": int(s["views_served"]),
        "split_fallbacks": int(s["split_fallbacks"]),
        "pop_item_us": round(wall / records * 1e6, 3),
        "items_per_sec": round(records / wall, 1),
    }


# ---------------------------------------------------------------------------
# pipeline section: channel → ring → prefetcher staging, end to end
# ---------------------------------------------------------------------------

def _drive_pipeline(zero_copy: bool, *, batches: int, batch: int = 16,
                    item_floats: int = 4096) -> Dict:
    """Prefilled channel drained through a ShmRingChannel into a staging
    prefetcher. Prefilling keeps every pop reply at exactly ``batch``
    items, so the reply sizes — and therefore the ring byte counters —
    are deterministic across runs."""
    server = TransportServer()
    local = FifoChannel(batches * batch + 64, policy="drop_oldest")
    server.add_channel("bench", local)
    server.start()
    item = {"x": np.zeros(item_floats, np.float32)}
    local.put_many([item] * (batches * batch))
    chan = ShmRingChannel(server.address, "bench", ring_bytes=32 << 20,
                          put_window=1, zero_copy_pop=zero_copy)
    collate = lambda segs: {"x": np.stack([s["x"] for s in segs])}
    pf = Prefetcher(chan, batch, collate=collate, depth=2,
                    stage_batches=True, drain_timeout_s=0.05)
    pf.start()
    t0 = time.perf_counter()
    for _ in range(batches):
        b = pf.get(timeout=30.0)
        assert b is not None and b["x"].shape == (batch, item_floats)
    wall = time.perf_counter() - t0
    ring = chan.ring_stats()
    pfm = pf.metrics()
    pf.stop()
    chan.close()
    server.stop()
    server.join()
    items = batches * batch
    return {
        "mode": "zero_copy" if zero_copy else "copy",
        "batches": batches,
        "batch": batch,
        "payload_bytes_each": item_floats * 4,
        # ring-side payload memcpys — the copy being eliminated. NOT
        # `_bytes`-suffixed on purpose: trailing empty polls from the
        # prefetcher make the exact value timing-dependent, so the claim
        # is enforced by the hard asserts in run(), not the exact gate
        "ring_copied": int(ring["bytes_copied"]),
        "ring_views_served": int(ring["views_served"]),
        "ring_split_fallbacks": int(ring["split_fallbacks"]),
        # staging copies happen either way (collate → pooled slab)
        "leases_released": int(pfm["views_served"]),
        "staging_reuse": int(pfm["staging_reuse"]),
        "staging_slabs": int(pfm["staging_slabs"]),
        "pop_item_us": round(wall / items * 1e6, 3),
        "items_per_sec": round(items / wall, 1),
    }


# ---------------------------------------------------------------------------
# window section: adaptive vs static streaming under RTT jitter
# ---------------------------------------------------------------------------

class _JitterFifo(FifoChannel):
    """FifoChannel whose apply path periodically sleeps: every
    ``period``-th flush eats ``spike_s`` before accepting, which delays
    the cumulative ack behind it — an induced server-side RTT spike."""

    def __init__(self, capacity: int, *, spike_s: float, period: int):
        super().__init__(capacity, policy="drop_oldest", block_timeout=0.2)
        self._spike_s = spike_s
        self._period = max(int(period), 1)
        self._applies = 0

    def put_many(self, items):
        self._applies += 1
        if self._spike_s and self._applies % self._period == 0:
            time.sleep(self._spike_s)
        return super().put_many(items)


def _drive_window(adaptive: bool, spike_s: float, *, duration_s: float,
                  window: int = 32, flush: int = 8,
                  item_floats: int = 256) -> Dict:
    server = TransportServer()
    chan = _JitterFifo(1 << 14, spike_s=spike_s, period=7)
    server.add_channel("bench", chan)
    server.start()
    stop = threading.Event()

    def drain() -> None:
        while not stop.is_set():
            chan.pop_many(1024, timeout=0.02)

    drainer = threading.Thread(target=drain, daemon=True)
    drainer.start()
    payload = [{"x": np.zeros(item_floats, np.float32)}] * flush
    stream = PutStream(server.address, "bench", window=window,
                       adaptive=adaptive)
    t0 = time.monotonic()
    while time.monotonic() - t0 < duration_s:
        stream.put_many(payload)
    stream.flush(30.0)
    wall = time.monotonic() - t0
    st = stream.stats()
    stream.close()
    stop.set()
    drainer.join(timeout=2.0)
    server.stop()
    server.join()
    return {
        "windowing": "adaptive" if adaptive else "static",
        "jitter": "on" if spike_s else "off",
        "window": window,
        "items_acked": int(st["items_acked"]),
        "items_per_sec": round(st["items_acked"] / wall, 1),
        "window_effective": int(st["window_effective"]),
        "window_backoffs": int(st["window_backoffs"]),
    }


def run(quick: bool = True) -> Dict:
    result: Dict = {}

    # -- ring section --------------------------------------------------------
    records = 512 if quick else 4096
    ring = {r["mode"]: r for r in
            (_drive_ring(zc, records=records) for zc in (False, True))}
    for rec in ring.values():
        print(f"  ring/{rec['mode']:9s}: {rec['items_per_sec']:9.1f} "
              f"pops/s  copied {rec['pop_bytes']:>10d} B "
              f"(views {rec['views_served']})")
    # the whole point, counter-asserted: the zero-copy pop path must not
    # memcpy payloads out of the ring (and with aligned records it copies
    # NOTHING — no split fallback can fire)
    assert ring["copy"]["pop_bytes"] == records * ring["copy"]["record_bytes_each"]
    assert ring["zero_copy"]["pop_bytes"] == 0
    assert ring["zero_copy"]["views_served"] == records
    assert ring["zero_copy"]["split_fallbacks"] == 0
    result["ring"] = ring

    # -- pipeline section ----------------------------------------------------
    batches = 40 if quick else 160
    pipeline = {r["mode"]: r for r in
                (_drive_pipeline(zc, batches=batches)
                 for zc in (False, True))}
    for rec in pipeline.values():
        print(f"  pipeline/{rec['mode']:9s}: {rec['items_per_sec']:9.1f} "
              f"items/s  ring copied {rec['ring_copied']:>10d} B "
              f"(leases {rec['leases_released']}, "
              f"slab reuse {rec['staging_reuse']})")
    items = batches * pipeline["copy"]["batch"]
    # zero-copy mode must strictly reduce ring-side memcpys, serve every
    # item as a leased view, and actually recycle staging slabs
    assert pipeline["zero_copy"]["ring_copied"] \
        < pipeline["copy"]["ring_copied"]
    assert pipeline["zero_copy"]["leases_released"] == items
    assert pipeline["copy"]["leases_released"] == 0
    for rec in pipeline.values():
        assert rec["staging_reuse"] > 0
    result["pipeline"] = pipeline

    # -- window section ------------------------------------------------------
    duration = 1.5 if quick else 6.0
    spike = 0.05
    window: Dict = {}
    for _round in range(2):              # best-of-2 interleaved (noise)
        for adaptive in (False, True):
            for jitter in (0.0, spike):
                rec = _drive_window(adaptive, jitter, duration_s=duration)
                key = f"{rec['windowing']}_{rec['jitter']}"
                if (key not in window or rec["items_per_sec"]
                        > window[key]["items_per_sec"]):
                    window[key] = rec
    for key in ("static_off", "adaptive_off", "static_on", "adaptive_on"):
        rec = window[key]
        print(f"  window/{key:12s}: {rec['items_per_sec']:9.1f} items/s  "
              f"(eff {rec['window_effective']}, "
              f"backoffs {rec['window_backoffs']})")
    for jit in ("off", "on"):
        window[f"adaptive_over_static_{jit}"] = round(
            window[f"adaptive_{jit}"]["items_per_sec"]
            / max(window[f"static_{jit}"]["items_per_sec"], 1e-9), 4)
    print(f"  window: adaptive/static steady "
          f"x{window['adaptive_over_static_off']}  "
          f"jitter x{window['adaptive_over_static_on']}")
    if multiprocessing.cpu_count() >= 2:
        # under steady RTT the controller must not throttle delivery
        # below the static window; under jitter it must actually back off
        assert window["adaptive_over_static_off"] >= 0.9, window
        assert window["adaptive_on"]["window_backoffs"] >= 1, window
    result["window"] = window

    save("BENCH_ingest", result)
    return result


if __name__ == "__main__":
    run()
