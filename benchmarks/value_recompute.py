"""Paper Figure 7 + App. C.1: the value-recomputation mechanism.

  (a) Step-time: the fused JIT-GAE step vs the traditional pipeline with a
      SEPARATE value re-inference pass (the paper reports ~30% end-to-end
      speedup from fusing it into the training forward).
  (b) Stability: short stale-data training with recompute ON vs OFF
      (OFF uses collection-time values for GAE — misaligned targets).
  (c) Equivalence: within a frozen-parameter accumulation window the fused
      advantages match a forced re-inference exactly (eq. 7 argument).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import numpy as np

from benchmarks.common import save, timeit, tiny_cfg
from repro.configs.base import RLConfig
from repro.core.train_step import (TrainState, _score_batch,
                                   init_train_state, make_train_step)
from repro.data.trajectory import dummy_batch


def run(quick: bool = True) -> Dict:
    cfg = tiny_cfg(layers=2, d_model=128)
    rl_on = RLConfig(grad_accum=2, value_recompute=True)
    rl_off = RLConfig(grad_accum=2, value_recompute=False)
    batch = dummy_batch(8, 6, 12, cfg.action_dim, cfg.vocab_size,
                        cfg.action_vocab_size, num_prefix=1)

    state = init_train_state(cfg, jax.random.PRNGKey(0))
    fused = make_train_step(cfg, rl_on, donate=False)

    # traditional pipeline: a full extra value forward over the batch,
    # then the same train step (values recomputed separately).
    score = jax.jit(functools.partial(_score_batch, cfg, remat=False))

    def separate(state, batch):
        _, values, _ = score(state.params, batch)
        batch = batch._replace(behavior_value=values)
        return fused(state, batch)

    t_fused = timeit(lambda: fused(state, batch), iters=5)
    t_sep = timeit(lambda: separate(state, batch), iters=5)
    speedup = (t_sep - t_fused) / t_sep
    print(f"  fused {t_fused*1e3:.1f} ms vs separate {t_sep*1e3:.1f} ms "
          f"-> {speedup*100:.1f}% step-time saving (paper: ~30% e2e)")

    # --- (c) equivalence within the frozen-param window ---------------------
    from repro.core import gae
    _, values, _ = _score_batch(cfg, state.params, batch, remat=False)
    adv_fused, _ = gae.jit_gae_from_forward(
        values, batch.rewards, batch.dones, rl_on.discount,
        rl_on.gae_lambda)
    # "forced re-inference": same params (frozen window) — must be identical
    _, values2, _ = _score_batch(cfg, state.params, batch, remat=False)
    adv_reinfer, _ = gae.jit_gae_from_forward(
        values2, batch.rewards, batch.dones, rl_on.discount,
        rl_on.gae_lambda)
    equiv_err = float(np.abs(np.asarray(adv_fused)
                             - np.asarray(adv_reinfer)).max())
    print(f"  fused-vs-reinference advantage max err: {equiv_err:.2e}")

    # --- (b) stability: recompute ON vs OFF on drifting values --------------
    steps = 30 if quick else 120
    curves = {}
    for name, rl in (("revalue_on", rl_on), ("revalue_off", rl_off)):
        st = init_train_state(cfg, jax.random.PRNGKey(1))
        step_fn = make_train_step(cfg, rl, donate=False)
        rng = np.random.default_rng(0)
        losses = []
        for it in range(steps):
            b = dummy_batch(8, 6, 12, cfg.action_dim, cfg.vocab_size,
                            cfg.action_vocab_size, num_prefix=1,
                            seed=it)
            # stale values: behavior_value drifts from truth as it ages
            b = b._replace(behavior_value=b.behavior_value
                           + rng.normal(0, 0.5 + 0.05 * it,
                                        b.behavior_value.shape
                                        ).astype(np.float32))
            st, m = step_fn(st, b)
            losses.append(float(m["value_loss"]))
        curves[name] = losses
        print(f"  {name}: final value-loss {np.mean(losses[-5:]):.4f}")

    result = {"t_fused_ms": t_fused * 1e3, "t_separate_ms": t_sep * 1e3,
              "step_time_saving": speedup, "equivalence_max_err": equiv_err,
              "stability_value_loss": curves}
    save("value_recompute", result)
    return result


if __name__ == "__main__":
    run()
