"""Paper Table 8: weight-synchronization overhead across transport paths.

Three transports (App. G.3): NCCL-analogue direct reference swap,
host-mediated serialize/deserialize (PCIe path), and shared-storage
checkpoint reload (AReaL-style). Measures publish→acquire latency and the
resulting policy lag in a live async run.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np

from benchmarks.common import save, tiny_cfg
from repro.configs.base import RLConfig, RuntimeConfig
from repro.models.policy import init_policy_params
from repro.runtime import (AcceRLSystem, DirectTransport, DiskTransport,
                           SerializedTransport, VersionedWeightStore)


def sync_latency(transport, params, iters: int = 5) -> Dict:
    store = VersionedWeightStore(transport=transport)
    lat = []
    for v in range(iters):
        t0 = time.perf_counter()
        store.begin_publish()
        store.publish(params, v)
        got = store.acquire(newer_than=v - 1, timeout=10.0)
        assert got is not None
        jax.block_until_ready(got[0])
        lat.append(time.perf_counter() - t0)
    return {"median_ms": float(np.median(lat) * 1e3),
            "p90_ms": float(np.percentile(lat, 90) * 1e3)}


def live_policy_lag(transport, wall: float, seed: int = 0) -> float:
    cfg = tiny_cfg(layers=2, d_model=64)
    rl = RLConfig(grad_accum=1, lr_policy=1e-4, lr_value=1e-3)
    rt = RuntimeConfig(num_rollout_workers=4, inference_batch=4)
    sys_ = AcceRLSystem(cfg, rl, rt, suite="spatial", segment_horizon=4,
                        max_episode_steps=10, batch_episodes=4,
                        transport=transport, seed=seed)
    m = sys_.run_async(train_steps=10_000, wall_timeout_s=wall)
    return m["mean_policy_lag"]


def run(quick: bool = True) -> Dict:
    # a mid-size parameter tree so serialization/disk costs are visible
    cfg = tiny_cfg(layers=4, d_model=256)
    params = init_policy_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(np.prod(x.shape) for x in jax.tree.leaves(params))
    wall = 20.0 if quick else 60.0

    result: Dict = {"n_params": int(n_params)}
    for name, t in (("nccl_direct", DirectTransport()),
                    ("host_serialized", SerializedTransport()),
                    ("shared_storage", DiskTransport())):
        lat = sync_latency(t, params)
        result[name] = {"latency": lat}
        print(f"  {name:16s}: publish->acquire {lat['median_ms']:8.2f} ms")
    for name, t in (("nccl_direct", DirectTransport()),
                    ("shared_storage", DiskTransport())):
        lag = live_policy_lag(t, wall)
        result[name]["policy_lag"] = lag
        print(f"  {name:16s}: live policy lag {lag:.3f} versions")

    save("sync_overhead", result)
    return result


if __name__ == "__main__":
    run()
