"""Backpressure policies under saturation (ROADMAP "Backpressure policies
under load"): sweep drop_oldest / drop_newest / block on a FifoChannel with
producers deliberately outrunning the consumer, and measure the trade each
policy makes —

  * **drop_oldest** (paper default) — producers never block, throughput is
    maximal, and staleness stays BOUNDED (the queue holds only the newest
    ``capacity`` items);
  * **drop_newest** — queued data wins, so accepted items are the OLDEST:
    staleness at pop grows with the run;
  * **block** — producer throughput is clamped to the consumer's rate
    (accept rate ≈ pop rate), buying low drop counts with idle producers.

A second section measures the WIRE data plane: the same producer payload
pushed through a real ``TransportServer`` + ``SocketChannel`` pair, one
item per round-trip (``put``) vs one codec blob per flush (``put_many``)
— the framing/RTT overhead the batched endpoint exists to amortize.

Channel-level only — no model, no jax — so the numbers isolate the data
plane. Emits ``BENCH_backpressure.json`` (registered with the perf gate:
the committed baseline under ``experiments/bench`` is compared by CI; the
fixed-duration ``t_wall_s`` keys are the gated stability signal).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import save
from repro.runtime.experience import BACKPRESSURE_POLICIES, FifoChannel


def _drive(policy: str, *, duration_s: float, capacity: int = 64,
           producers: int = 4, produce_hz: float = 200.0,
           consume_hz: float = 25.0, batch: int = 8) -> Dict:
    """Producers push stamped items at ``producers * produce_hz``; one
    consumer pops ``batch`` at ``consume_hz`` — a deliberate ~order-of-
    magnitude oversubscription."""
    chan = FifoChannel(capacity, policy=policy, block_timeout=0.05)
    stop = threading.Event()
    accepted = [0] * producers
    offered = [0] * producers
    ages: List[float] = []
    depths: List[int] = []
    popped = [0]

    def producer(idx: int) -> None:
        period = 1.0 / produce_hz
        while not stop.is_set():
            offered[idx] += 1
            if chan.put({"t": time.monotonic(), "idx": idx}):
                accepted[idx] += 1
            time.sleep(period)

    def consumer() -> None:
        period = 1.0 / consume_hz
        while not stop.is_set():
            got = chan.pop_batch(min(batch, max(len(chan), 1)),
                                 timeout=period)
            now = time.monotonic()
            if got:
                popped[0] += len(got)
                ages.extend(now - item["t"] for item in got)
            depths.append(len(chan))
            time.sleep(period)

    threads = [threading.Thread(target=producer, args=(i,), daemon=True)
               for i in range(producers)]
    threads.append(threading.Thread(target=consumer, daemon=True))
    t0 = time.monotonic()
    for t in threads:
        t.start()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join(timeout=2.0)
    wall = time.monotonic() - t0

    ages_a = np.asarray(ages) if ages else np.zeros(1)
    return {
        "policy": policy,
        "t_wall_s": round(wall, 3),
        "capacity": capacity,
        "producers": producers,
        "offered": int(sum(offered)),
        "accepted": int(sum(accepted)),
        "rejected": int(sum(offered) - sum(accepted)),
        "dropped": int(chan.total_dropped),
        "popped": int(popped[0]),
        "accept_rate": round(sum(accepted) / max(sum(offered), 1), 4),
        "accepted_per_s": round(sum(accepted) / wall, 1),
        "staleness_mean": round(float(ages_a.mean()), 4),
        "staleness_p95": round(float(np.percentile(ages_a, 95)), 4),
        "depth_mean": round(float(np.mean(depths)) if depths else 0.0, 2),
    }


def _drive_wire(batched: bool, *, duration_s: float, item_floats: int = 512,
                flush: int = 16) -> Dict:
    """One producer pushes fixed-size items over a real socket transport;
    ``batched`` flushes ``flush`` items per ``put_many`` round-trip,
    otherwise one ``put`` RPC per item. A drain thread keeps the hosted
    channel from saturating so the number isolates wire overhead."""
    from repro.runtime.transport import SocketChannel, TransportServer

    server = TransportServer()
    local = FifoChannel(8192, policy="drop_oldest")
    server.add_channel("bench", local)
    server.start()
    remote = SocketChannel(server.address, "bench")
    payload = {"x": np.zeros(item_floats, np.float32),
               "meta": {"t": 0.0, "idx": 0}}
    stop = threading.Event()

    def drain() -> None:
        while not stop.is_set():
            local.pop_batch(max(min(len(local), 256), 1), timeout=0.02)

    drainer = threading.Thread(target=drain, daemon=True)
    drainer.start()
    sent = accepted = 0
    t0 = time.monotonic()
    while time.monotonic() - t0 < duration_s:
        if batched:
            verdicts = remote.put_many([payload] * flush)
            sent += flush
            accepted += sum(verdicts)
        else:
            accepted += bool(remote.put(payload))
            sent += 1
    wall = time.monotonic() - t0
    stop.set()
    drainer.join(timeout=2.0)
    remote.close()
    server.stop()
    server.join()
    rpcs = int(server.metrics.counter("requests"))
    return {
        "mode": "batched" if batched else "single",
        "t_wall_s": round(wall, 3),
        "flush": flush if batched else 1,
        "item_bytes": int(payload["x"].nbytes),
        "items_sent": sent,
        "items_accepted": accepted,
        "rpcs": rpcs,
        "items_per_rpc": round(sent / max(rpcs, 1), 2),
        "items_per_sec": round(sent / wall, 1),
    }


def run(quick: bool = True) -> Dict:
    duration = 2.0 if quick else 8.0
    result: Dict = {"duration_s_requested": duration, "sweep": []}
    for policy in BACKPRESSURE_POLICIES:
        rec = _drive(policy, duration_s=duration)
        result["sweep"].append(rec)
        print(f"  {policy:12s}: accept {rec['accept_rate']:5.1%} "
              f"dropped {rec['dropped']:5d} "
              f"staleness {rec['staleness_mean']*1e3:7.1f}ms "
              f"(p95 {rec['staleness_p95']*1e3:7.1f}ms) "
              f"depth {rec['depth_mean']:5.1f}")

    by = {r["policy"]: r for r in result["sweep"]}
    # the structural claims, asserted so a regression fails the benchmark
    # run itself (the perf gate additionally bands the committed numbers):
    # block CLAMPS producer throughput to the consumer (its accept *rate*
    # is high only because producers stall); drop_oldest keeps producers
    # at full speed; drop_newest trades throughput for maximal staleness.
    assert (by["drop_oldest"]["accepted_per_s"]
            > 1.5 * by["block"]["accepted_per_s"]), \
        "drop_oldest must out-accept the consumer-clamped block policy"
    assert (by["drop_newest"]["staleness_mean"]
            > by["drop_oldest"]["staleness_mean"]), \
        "drop_newest keeps old data: staleness must exceed drop_oldest"
    assert by["block"]["dropped"] < by["drop_oldest"]["dropped"], \
        "block must drop (time out) less than drop_oldest evicts"
    result["claims"] = {
        "drop_oldest_over_block_throughput": round(
            by["drop_oldest"]["accepted_per_s"]
            / max(by["block"]["accepted_per_s"], 1e-9), 2),
        "drop_newest_over_drop_oldest_staleness": round(
            by["drop_newest"]["staleness_mean"]
            / max(by["drop_oldest"]["staleness_mean"], 1e-9), 2),
    }

    # -- wire section: put vs put_many over a real socket transport ----------
    wire = {"single": _drive_wire(False, duration_s=duration),
            "batched": _drive_wire(True, duration_s=duration)}
    speedup = round(wire["batched"]["items_per_sec"]
                    / max(wire["single"]["items_per_sec"], 1e-9), 2)
    wire["batched_over_single_throughput"] = speedup
    for rec in (wire["single"], wire["batched"]):
        print(f"  wire/{rec['mode']:8s}: {rec['items_per_sec']:8.1f} "
              f"items/s  ({rec['items_per_rpc']:5.2f} items/rpc)")
    print(f"  wire: batched/single throughput x{speedup}")
    # put_many's whole point: fewer round-trips per item. The throughput
    # win follows but is load-sensitive (shared CI runners), so ONLY the
    # structural claim is hard-asserted; the ratio is reported data.
    assert (wire["batched"]["items_per_rpc"]
            > 4 * wire["single"]["items_per_rpc"]), \
        "put_many must amortize framing across many items per RPC"
    result["wire"] = wire

    save("BENCH_backpressure", result)
    return result


if __name__ == "__main__":
    run()
