"""Backpressure policies under saturation (ROADMAP "Backpressure policies
under load"): sweep drop_oldest / drop_newest / block on a FifoChannel with
producers deliberately outrunning the consumer, and measure the trade each
policy makes —

  * **drop_oldest** (paper default) — producers never block, throughput is
    maximal, and staleness stays BOUNDED (the queue holds only the newest
    ``capacity`` items);
  * **drop_newest** — queued data wins, so accepted items are the OLDEST:
    staleness at pop grows with the run;
  * **block** — producer throughput is clamped to the consumer's rate
    (accept rate ≈ pop rate), buying low drop counts with idle producers.

A second section measures the WIRE data plane: the same producer payload
pushed through a real ``TransportServer`` + ``SocketChannel`` pair, one
item per round-trip (``put``) vs one codec blob per flush (``put_many``)
— the framing/RTT overhead the batched endpoint exists to amortize.

The STREAMING section (ISSUE 5) measures the pipelined data plane with
the producer in a real spawned subprocess (two interpreters — in-process
threads would serialize encode/decode on one GIL and hide the overlap
pipelining buys):

  * ``batched``        — PR 4's path: one blocking ``put_many`` RPC per
    flush, the producer idles an RTT + server decode per flush;
  * ``pipelined``      — ``PutStream``: fire-and-forget frames, windowed
    acks; producer encode overlaps server decode;
  * ``pipelined_ring`` — the same stream with payloads through the
    persistent SHM ring (``ShmRingChannel``): zero per-message segment
    churn, blobs encoded straight into the ring reservation;

plus a POP-latency comparison of the two out-of-band reply planes:
per-message SHM segments (create/attach/unlink each pop) vs the
persistent ring (one memcpy in, one out).

The RECOVERY section (ISSUE 6) prices the resilient control plane:

  * ``journal_put_ratio`` — streaming-put throughput with the hosted
    channel journaled vs plain, interleaved best-of-2. The journal's
    promise is <5% steady-state cost (one crc32 per flush, the wire
    encoding reused verbatim, writes group-committed at ack
    boundaries), asserted on ≥2-CPU hosts so the cheap-journal claim
    cannot silently rot (on one CPU the server-side cost serializes
    against the producer and the ratio measures core starvation);
  * ``t_recover_s`` — time-to-first-pop of a replacement server:
    journal resume + state replay + serve, the window workers spend
    redialing after a parent crash (the gated stability signal).

The TELEMETRY section (ISSUE 8) prices the observability plane on the
same spawned-producer put path:

  * ``off`` — REPRO_TRACE unset: the child asserts the telemetry module
    was never even imported by the transport stack (the gate is at
    import time, so the off path carries one ``is None`` check, nothing
    else);
  * ``on``  — REPRO_TRACE=1: every flush runs inside a ``rollout.put``
    span with a fresh trace id riding the frame headers — the full
    per-flush cost a traced rollout worker pays.

The span recorder is an append to a preallocated per-thread ring, so the
claim is <5% put-path overhead (``on_over_off_throughput >= 0.95``,
asserted on ≥2-CPU hosts); the per-item ``put_item_*_ms`` keys are
perf-gated so the hot path cannot silently grow a step-function cost.

Channel-level only — no model, no jax — so the numbers isolate the data
plane. Emits ``BENCH_backpressure.json`` (registered with the perf gate:
the committed baseline under ``experiments/bench`` is compared by CI; the
fixed-duration ``t_wall_s`` keys are the gated stability signal).
"""
from __future__ import annotations

import multiprocessing
import os
import threading
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import save
from repro.runtime.experience import BACKPRESSURE_POLICIES, FifoChannel


def _drive(policy: str, *, duration_s: float, capacity: int = 64,
           producers: int = 4, produce_hz: float = 200.0,
           consume_hz: float = 25.0, batch: int = 8) -> Dict:
    """Producers push stamped items at ``producers * produce_hz``; one
    consumer pops ``batch`` at ``consume_hz`` — a deliberate ~order-of-
    magnitude oversubscription."""
    chan = FifoChannel(capacity, policy=policy, block_timeout=0.05)
    stop = threading.Event()
    accepted = [0] * producers
    offered = [0] * producers
    ages: List[float] = []
    depths: List[int] = []
    popped = [0]

    def producer(idx: int) -> None:
        period = 1.0 / produce_hz
        while not stop.is_set():
            offered[idx] += 1
            if chan.put({"t": time.monotonic(), "idx": idx}):
                accepted[idx] += 1
            time.sleep(period)

    def consumer() -> None:
        period = 1.0 / consume_hz
        while not stop.is_set():
            got = chan.pop_batch(min(batch, max(len(chan), 1)),
                                 timeout=period)
            now = time.monotonic()
            if got:
                popped[0] += len(got)
                ages.extend(now - item["t"] for item in got)
            depths.append(len(chan))
            time.sleep(period)

    threads = [threading.Thread(target=producer, args=(i,), daemon=True)
               for i in range(producers)]
    threads.append(threading.Thread(target=consumer, daemon=True))
    t0 = time.monotonic()
    for t in threads:
        t.start()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join(timeout=2.0)
    wall = time.monotonic() - t0

    ages_a = np.asarray(ages) if ages else np.zeros(1)
    return {
        "policy": policy,
        "t_wall_s": round(wall, 3),
        "capacity": capacity,
        "producers": producers,
        "offered": int(sum(offered)),
        "accepted": int(sum(accepted)),
        "rejected": int(sum(offered) - sum(accepted)),
        "dropped": int(chan.total_dropped),
        "popped": int(popped[0]),
        "accept_rate": round(sum(accepted) / max(sum(offered), 1), 4),
        "accepted_per_s": round(sum(accepted) / wall, 1),
        "staleness_mean": round(float(ages_a.mean()), 4),
        "staleness_p95": round(float(np.percentile(ages_a, 95)), 4),
        "depth_mean": round(float(np.mean(depths)) if depths else 0.0, 2),
    }


def _drive_wire(batched: bool, *, duration_s: float, item_floats: int = 512,
                flush: int = 16) -> Dict:
    """One producer pushes fixed-size items over a real socket transport;
    ``batched`` flushes ``flush`` items per ``put_many`` round-trip,
    otherwise one ``put`` RPC per item. A drain thread keeps the hosted
    channel from saturating so the number isolates wire overhead."""
    from repro.runtime.transport import SocketChannel, TransportServer

    server = TransportServer()
    local = FifoChannel(8192, policy="drop_oldest")
    server.add_channel("bench", local)
    server.start()
    remote = SocketChannel(server.address, "bench")
    payload = {"x": np.zeros(item_floats, np.float32),
               "meta": {"t": 0.0, "idx": 0}}
    stop = threading.Event()

    def drain() -> None:
        while not stop.is_set():
            local.pop_batch(max(min(len(local), 256), 1), timeout=0.02)

    drainer = threading.Thread(target=drain, daemon=True)
    drainer.start()
    sent = accepted = 0
    t0 = time.monotonic()
    while time.monotonic() - t0 < duration_s:
        if batched:
            verdicts = remote.put_many([payload] * flush)
            sent += flush
            accepted += sum(verdicts)
        else:
            accepted += bool(remote.put(payload))
            sent += 1
    wall = time.monotonic() - t0
    stop.set()
    drainer.join(timeout=2.0)
    remote.close()
    server.stop()
    server.join()
    rpcs = int(server.metrics.counter("requests"))
    return {
        "mode": "batched" if batched else "single",
        "t_wall_s": round(wall, 3),
        "flush": flush if batched else 1,
        "item_bytes": int(payload["x"].nbytes),
        "items_sent": sent,
        "items_accepted": accepted,
        "rpcs": rpcs,
        "items_per_rpc": round(sent / max(rpcs, 1), 2),
        "items_per_sec": round(sent / wall, 1),
    }


def _stream_child(mode: str, address, duration_s: float, flush: int,
                  item_floats: int, window: int, q) -> None:
    """Subprocess producer body (spawn target): hammer one flush shape at
    the server for ``duration_s`` through the selected put path, then
    report counts through ``q``."""
    from repro.runtime.transport import (PutStream, ShmRingChannel,
                                         SocketChannel)

    payload = [{"x": np.zeros(item_floats, np.float32),
                "meta": {"t": 0.0, "idx": 0}}] * flush
    stream = chan = None
    if mode == "batched":
        chan = SocketChannel(tuple(address), "bench")
        put = lambda: sum(chan.put_many(payload))          # noqa: E731
    elif mode == "pipelined":
        stream = PutStream(tuple(address), "bench", window=window)
        put = lambda: sum(stream.put_many(payload))        # noqa: E731
    elif mode == "pipelined_ring":
        chan = ShmRingChannel(tuple(address), "bench", put_window=window,
                              ring_bytes=32 << 20)
        stream = chan._put_stream()
        put = lambda: sum(chan.put_many(payload))          # noqa: E731
    else:
        raise ValueError(mode)
    sent = accepted = 0
    t0 = time.monotonic()
    while time.monotonic() - t0 < duration_s:
        accepted += put()
        sent += flush
    if stream is not None:
        # throughput counts only ACKED items over the wall including the
        # drain — fire-and-forget does not get credit for unacked frames
        stream.flush(30.0)
        accepted = int(stream.stats()["items_accepted"])
    wall = time.monotonic() - t0
    q.put({"sent": sent, "accepted": accepted, "wall": wall,
           "frames": (stream.stats()["frames_sent"] if stream is not None
                      else sent // flush)})
    if chan is not None:
        chan.close()
    elif stream is not None:
        stream.close()


def _drive_stream(mode: str, *, duration_s: float, item_floats: int = 512,
                  flush: int = 4, window: int = 64) -> Dict:
    """One cross-process producer run of the streaming benchmark.

    ``flush=4`` is the realistic shape: a 30-step episode at segment
    horizon 8 flushes 4 segments — small flushes are exactly where the
    per-RPC round-trip dominates and pipelining pays.
    """
    from repro.runtime.transport import TransportServer

    server = TransportServer()
    local = FifoChannel(16384, policy="drop_oldest")
    server.add_channel("bench", local)
    server.start()
    stop = threading.Event()

    def drain() -> None:
        while not stop.is_set():
            local.pop_many(1024, timeout=0.02)

    drainer = threading.Thread(target=drain, daemon=True)
    drainer.start()
    ctx = multiprocessing.get_context("spawn")
    q = ctx.Queue()
    proc = ctx.Process(target=_stream_child,
                       args=(mode, server.address, duration_s, flush,
                             item_floats, window, q))
    proc.start()
    got = q.get(timeout=120.0)
    proc.join(timeout=30.0)
    if proc.is_alive():
        proc.kill()
    stop.set()
    drainer.join(timeout=2.0)
    server.stop()
    server.join()
    item_bytes = item_floats * 4
    return {
        "mode": mode,
        "t_wall_s": round(got["wall"], 3),
        "flush": flush,
        "window": window if mode != "batched" else 0,
        "item_bytes": item_bytes,
        "items_sent": int(got["sent"]),
        "items_accepted": int(got["accepted"]),
        "frames": int(got["frames"]),
        "items_per_sec": round(got["accepted"] / got["wall"], 1),
    }


def _telemetry_child(traced: bool, address, duration_s: float, flush: int,
                     item_floats: int, window: int, q) -> None:
    """Spawned producer for the telemetry section: the put loop of a
    traced rollout worker (per-flush span + fresh trace id on the wire)
    vs the same loop with the recorder disarmed. Asserts the
    import-gating contract inside the fresh interpreter: REPRO_TRACE off
    means the transport stack never even imports the telemetry module."""
    import sys as _sys
    from repro.runtime.transport import PutStream

    assert ("repro.runtime.telemetry" in _sys.modules) == traced, (
        "telemetry import gating broken: module "
        + ("missing with" if traced else "loaded without") + " REPRO_TRACE")
    tel = None
    if traced:
        from repro.runtime import telemetry as tel
    payload = [{"x": np.zeros(item_floats, np.float32),
                "meta": {"t": 0.0, "idx": 0}}] * flush
    stream = PutStream(tuple(address), "bench", window=window)
    sent = 0
    t0 = time.monotonic()
    while time.monotonic() - t0 < duration_s:
        if tel is not None:
            with tel.span("rollout.put", cat="bench", trace=tel.new_id(),
                          flow="start"):
                stream.put_many(payload)
        else:
            stream.put_many(payload)
        sent += flush
    stream.flush(30.0)
    accepted = int(stream.stats()["items_accepted"])
    wall = time.monotonic() - t0
    q.put({"sent": sent, "accepted": accepted, "wall": wall,
           "events": len(tel.drain()) if tel is not None else 0})
    stream.close()


def _drive_telemetry(traced: bool, *, duration_s: float,
                     item_floats: int = 512, flush: int = 4,
                     window: int = 64) -> Dict:
    """One spawned-producer run of the tracing-overhead comparison; the
    REPRO_TRACE env the child inherits is flipped around the spawn (the
    parent server stays untraced both ways, so the delta isolates the
    PRODUCER-side cost a rollout worker pays)."""
    from repro.runtime.transport import TransportServer

    server = TransportServer()
    local = FifoChannel(1 << 14, policy="drop_oldest")
    server.add_channel("bench", local)
    server.start()
    stop = threading.Event()

    def drain() -> None:
        while not stop.is_set():
            local.pop_many(1024, timeout=0.02)

    drainer = threading.Thread(target=drain, daemon=True)
    drainer.start()
    ctx = multiprocessing.get_context("spawn")
    q = ctx.Queue()
    prior = os.environ.pop("REPRO_TRACE", None)
    if traced:
        os.environ["REPRO_TRACE"] = "1"
    try:
        proc = ctx.Process(target=_telemetry_child,
                           args=(traced, server.address, duration_s, flush,
                                 item_floats, window, q))
        proc.start()
    finally:
        os.environ.pop("REPRO_TRACE", None)
        if prior is not None:
            os.environ["REPRO_TRACE"] = prior
    got = q.get(timeout=120.0)
    proc.join(timeout=30.0)
    if proc.is_alive():
        proc.kill()
    stop.set()
    drainer.join(timeout=2.0)
    server.stop()
    server.join()
    return {
        "tracing": "on" if traced else "off",
        "t_wall_s": round(got["wall"], 3),
        "flush": flush,
        "window": window,
        "items_sent": int(got["sent"]),
        "items_accepted": int(got["accepted"]),
        "trace_events": int(got["events"]),
        "items_per_sec": round(got["accepted"] / got["wall"], 1),
    }


def _drive_pop(ring: bool, *, pops: int, batch: int = 16,
               item_floats: int = 4096) -> Dict:
    """Per-pop RPC latency of the two out-of-band reply planes: the
    channel is pre-filled, so every pop is purely data-plane work.

    256 KiB blobs (16 × 16 KiB segments) sit well above the SHM
    threshold but below memcpy dominance — the regime where the segment
    plane's per-message ``shm_open``/``mmap``/``unlink`` actually shows
    (at multi-MB blobs both planes converge on pure copy bandwidth)."""
    from repro.runtime.transport import (ShmChannel, ShmRingChannel,
                                         TransportServer)

    server = TransportServer()
    local = FifoChannel((pops + 8) * batch, policy="drop_oldest")
    server.add_channel("bench", local)
    server.start()
    item = {"x": np.zeros(item_floats, np.float32)}
    local.put_many([item] * ((pops + 4) * batch))
    if ring:
        chan = ShmRingChannel(server.address, "bench",
                              ring_bytes=64 << 20, put_window=1)
    else:
        chan = ShmChannel(server.address, "bench")
    lat = []
    for i in range(pops + 4):
        t0 = time.perf_counter()
        got = chan.pop_many(batch, timeout=10.0)
        dt = time.perf_counter() - t0
        assert got is not None and len(got) == batch
        if i >= 4:                     # warmup excluded
            lat.append(dt)
    chan.close()
    server.stop()
    server.join()
    lat_a = np.asarray(lat)
    counters = server.metrics.snapshot()["counters"]
    return {
        "plane": "ring" if ring else "segment",
        "batch": batch,
        "blob_bytes_approx": int(item_floats * 4 * batch),
        # the MEDIAN is the gated latency signal (`_ms` suffix): robust
        # to scheduler spikes on shared runners. Mean/p95 are reported
        # for the tail story but deliberately NOT gate-suffixed — one
        # preempted pop would blow a 2.5x band through no fault of the
        # data plane.
        "pop_ms_p50": round(float(np.median(lat_a) * 1e3), 3),
        "pop_mean_millis_ungated": round(float(lat_a.mean() * 1e3), 3),
        "pop_p95_millis_ungated": round(
            float(np.percentile(lat_a, 95) * 1e3), 3),
        "shm_segments_created": int(counters.get("shm_segments_created", 0)),
        "ring_records_out": int(counters.get("ring_records_out", 0)),
    }


def _put_run(journal_dir, *, duration_s: float, item_floats: int = 512,
             flush: int = 4, window: int = 64) -> float:
    """Acked-items/s of one in-process PutStream producer against a
    hosted channel — journaled into ``journal_dir`` when given, plain
    otherwise. Same thread layout both ways, so the ratio isolates the
    journal's append cost (pops journaled too: the drain is part of the
    steady state being priced)."""
    from repro.runtime.transport import (PutStream, TransportJournal,
                                         TransportServer)

    journal = (TransportJournal(journal_dir, compact_bytes=1 << 30)
               if journal_dir else None)
    chan = FifoChannel(1 << 15, policy="drop_oldest")
    if journal is not None:
        chan = journal.wrap("bench", chan)
    server = TransportServer(journal=journal)
    server.add_channel("bench", chan)
    server.start()
    payload = [{"x": np.zeros(item_floats, np.float32)}] * flush
    stop = threading.Event()

    def drain() -> None:
        while not stop.is_set():
            chan.pop_many(1024, timeout=0.02)

    drainer = threading.Thread(target=drain, daemon=True)
    drainer.start()
    stream = PutStream(server.address, "bench", window=window)
    t0 = time.monotonic()
    while time.monotonic() - t0 < duration_s:
        stream.put_many(payload)
    stream.flush(30.0)
    acked = int(stream.stats()["items_accepted"])
    wall = time.monotonic() - t0
    stream.close()
    stop.set()
    drainer.join(timeout=2.0)
    server.stop()
    server.join()
    return acked / wall


def _journal_tmpdir(prefix: str) -> str:
    """A journal scratch dir on tmpfs when the host has one: the section
    prices the journal MECHANISM (encode/crc/group-commit syscalls), and
    a slow container disk whose writeback throttles at ~100MB/s would
    price the deployment's disk instead. Real deployments journal to
    hardware whose page-cache absorption outruns the experience plane."""
    import tempfile
    base = "/dev/shm" if os.path.isdir("/dev/shm") else None
    return tempfile.mkdtemp(prefix=prefix, dir=base)


def _drive_recovery(*, duration_s: float, n_items: int = 4096,
                    item_floats: int = 256) -> Dict:
    """The recovery section: journal overhead ratio + replacement
    time-to-first-pop."""
    import shutil

    from repro.runtime.transport import (PutStream, SocketChannel,
                                         TransportJournal, TransportServer)

    # -- steady-state journal cost: interleaved best-of-2 --------------------
    plain = journaled = 0.0
    for _ in range(2):
        plain = max(plain, _put_run(None, duration_s=duration_s))
        jdir = _journal_tmpdir("acrl_bench_journal_")
        try:
            journaled = max(journaled, _put_run(jdir, duration_s=duration_s))
        finally:
            shutil.rmtree(jdir, ignore_errors=True)

    # -- time-to-first-pop of a replacement server ---------------------------
    jdir = _journal_tmpdir("acrl_bench_recover_")
    try:
        journal = TransportJournal(jdir, compact_bytes=1 << 30)
        chan = journal.wrap("bench", FifoChannel(n_items))
        server = TransportServer(journal=journal)
        server.add_channel("bench", chan)
        server.start()
        stream = PutStream(server.address, "bench", window=64)
        item = {"x": np.zeros(item_floats, np.float32)}
        for _ in range(n_items // 16):
            stream.put_many([item] * 16)
        assert stream.flush(30.0)
        stream.close()
        server.stop()                  # on_stop compacts to one snapshot
        server.join()

        t0 = time.perf_counter()
        journal2 = TransportJournal(jdir, resume=True)
        chan2 = journal2.wrap("bench", FifoChannel(n_items))
        server2 = TransportServer(journal=journal2)
        server2.add_channel("bench", chan2)
        server2.resume_from_journal()
        server2.start()
        pop = SocketChannel(server2.address, "bench")
        first = pop.pop_many(64, timeout=10.0)
        t_recover = time.perf_counter() - t0
        assert first, "replacement server never served a pop"
        recovered = int(server2.metrics.counter("journal_recovered_items"))
        assert recovered == n_items, (recovered, n_items)
        pop.close()
        server2.stop()
        server2.join()
    finally:
        shutil.rmtree(jdir, ignore_errors=True)

    return {
        "plain_put_items_per_sec": round(plain, 1),
        "journaled_put_items_per_sec": round(journaled, 1),
        "journal_put_ratio": round(journaled / max(plain, 1e-9), 4),
        "recovered_items": recovered,
        "t_recover_s": round(t_recover, 4),
    }


def run(quick: bool = True) -> Dict:
    duration = 2.0 if quick else 8.0
    result: Dict = {"duration_s_requested": duration, "sweep": []}
    for policy in BACKPRESSURE_POLICIES:
        rec = _drive(policy, duration_s=duration)
        result["sweep"].append(rec)
        print(f"  {policy:12s}: accept {rec['accept_rate']:5.1%} "
              f"dropped {rec['dropped']:5d} "
              f"staleness {rec['staleness_mean']*1e3:7.1f}ms "
              f"(p95 {rec['staleness_p95']*1e3:7.1f}ms) "
              f"depth {rec['depth_mean']:5.1f}")

    by = {r["policy"]: r for r in result["sweep"]}
    # the structural claims, asserted so a regression fails the benchmark
    # run itself (the perf gate additionally bands the committed numbers):
    # block CLAMPS producer throughput to the consumer (its accept *rate*
    # is high only because producers stall); drop_oldest keeps producers
    # at full speed; drop_newest trades throughput for maximal staleness.
    assert (by["drop_oldest"]["accepted_per_s"]
            > 1.5 * by["block"]["accepted_per_s"]), \
        "drop_oldest must out-accept the consumer-clamped block policy"
    assert (by["drop_newest"]["staleness_mean"]
            > by["drop_oldest"]["staleness_mean"]), \
        "drop_newest keeps old data: staleness must exceed drop_oldest"
    assert by["block"]["dropped"] < by["drop_oldest"]["dropped"], \
        "block must drop (time out) less than drop_oldest evicts"
    result["claims"] = {
        "drop_oldest_over_block_throughput": round(
            by["drop_oldest"]["accepted_per_s"]
            / max(by["block"]["accepted_per_s"], 1e-9), 2),
        "drop_newest_over_drop_oldest_staleness": round(
            by["drop_newest"]["staleness_mean"]
            / max(by["drop_oldest"]["staleness_mean"], 1e-9), 2),
    }

    # -- wire section: put vs put_many over a real socket transport ----------
    wire = {"single": _drive_wire(False, duration_s=duration),
            "batched": _drive_wire(True, duration_s=duration)}
    speedup = round(wire["batched"]["items_per_sec"]
                    / max(wire["single"]["items_per_sec"], 1e-9), 2)
    wire["batched_over_single_throughput"] = speedup
    for rec in (wire["single"], wire["batched"]):
        print(f"  wire/{rec['mode']:8s}: {rec['items_per_sec']:8.1f} "
              f"items/s  ({rec['items_per_rpc']:5.2f} items/rpc)")
    print(f"  wire: batched/single throughput x{speedup}")
    # put_many's whole point: fewer round-trips per item. The throughput
    # win follows but is load-sensitive (shared CI runners), so ONLY the
    # structural claim is hard-asserted; the ratio is reported data.
    assert (wire["batched"]["items_per_rpc"]
            > 4 * wire["single"]["items_per_rpc"]), \
        "put_many must amortize framing across many items per RPC"
    result["wire"] = wire

    # -- streaming section: pipelined puts + ring-vs-segment pops ------------
    # best-of-2 interleaved rounds per mode: spawned-producer throughput
    # is scheduler-noisy on shared runners, and the claim under test is
    # the data plane's CAPABILITY, not one draw of the noise
    streaming: Dict = {}
    modes = ("batched", "pipelined", "pipelined_ring")
    for _round in range(2):
        for mode in modes:
            rec = _drive_stream(mode, duration_s=duration)
            if (mode not in streaming or rec["items_per_sec"]
                    > streaming[mode]["items_per_sec"]):
                streaming[mode] = rec
    for mode in modes:
        rec = streaming[mode]
        print(f"  streaming/{rec['mode']:14s}: {rec['items_per_sec']:9.1f} "
              f"items/s  ({rec['frames']} frames, "
              f"window {rec['window']})")
    for key in ("pipelined", "pipelined_ring"):
        streaming[f"{key}_over_batched_throughput"] = round(
            streaming[key]["items_per_sec"]
            / max(streaming["batched"]["items_per_sec"], 1e-9), 2)
    print(f"  streaming: pipelined/batched "
          f"x{streaming['pipelined_over_batched_throughput']}  "
          f"ring x{streaming['pipelined_ring_over_batched_throughput']}")
    # ISSUE 5 acceptance: the pipelined put path must at least double the
    # batched request/response throughput (it removes one blocking RTT +
    # server decode per flush from the producer's critical path). Judged
    # on the best pipelined variant — which of socket/ring wins is a
    # machine property, the pipelining claim is not. The claim IS a
    # parallelism claim (producer encode overlapping server decode), so
    # on a single-CPU box there is nothing to overlap and the ratios are
    # reported data only.
    best = max(streaming["pipelined"]["items_per_sec"],
               streaming["pipelined_ring"]["items_per_sec"])
    if (multiprocessing.cpu_count() or 1) >= 2:
        assert best >= 2.0 * streaming["batched"]["items_per_sec"], \
            "pipelined put stream must be >= 2x the batched RPC path"
        # ... and the plain-socket stream must never regress to batched
        # speed, or a no-ring-path bug would hide behind a healthy ring
        assert (streaming["pipelined"]["items_per_sec"]
                >= 1.2 * streaming["batched"]["items_per_sec"]), \
            "socket-mode pipelined stream regressed to ~batched throughput"
    else:
        print("  streaming: single CPU — overlap speedup asserts skipped")

    pops = 60 if quick else 150
    pop: Dict = {}
    for _round in range(2):              # best-of-2, interleaved (noise)
        for ring_plane, key in ((False, "segment"), (True, "ring")):
            rec = _drive_pop(ring_plane, pops=pops)
            if key not in pop or rec["pop_ms_p50"] < pop[key]["pop_ms_p50"]:
                pop[key] = rec
    pop["ring_over_segment_latency"] = round(
        pop["ring"]["pop_ms_p50"]
        / max(pop["segment"]["pop_ms_p50"], 1e-9), 3)
    for rec in (pop["segment"], pop["ring"]):
        print(f"  pop/{rec['plane']:8s}: {rec['pop_ms_p50']:7.3f} ms p50 "
              f"(mean {rec['pop_mean_millis_ungated']:7.3f}, "
              f"p95 {rec['pop_p95_millis_ungated']:7.3f}, "
              f"segments {rec['shm_segments_created']}, "
              f"ring records {rec['ring_records_out']})")
    # the persistent ring must beat per-message segment churn on the pop
    # path, and must actually have carried the blobs
    assert pop["ring"]["pop_ms_p50"] < pop["segment"]["pop_ms_p50"], \
        "ring pop latency must undercut per-segment SHM"
    assert pop["ring"]["shm_segments_created"] == 0
    assert pop["ring"]["ring_records_out"] >= pops
    assert pop["segment"]["shm_segments_created"] >= pops
    streaming["pop"] = pop
    result["streaming"] = streaming

    # -- telemetry section: tracing-ON vs OFF put-path overhead --------------
    telem: Dict = {}
    for _round in range(2):              # best-of-2 interleaved (noise)
        for traced, key in ((False, "off"), (True, "on")):
            rec = _drive_telemetry(traced, duration_s=duration)
            if (key not in telem
                    or rec["items_per_sec"] > telem[key]["items_per_sec"]):
                telem[key] = rec
    ratio = round(telem["on"]["items_per_sec"]
                  / max(telem["off"]["items_per_sec"], 1e-9), 4)
    telem["on_over_off_throughput"] = ratio
    # per-item cost as gated wall-time keys, so the tracing hot path
    # cannot silently grow a step-function cost between PRs
    for key in ("off", "on"):
        telem[f"put_item_{key}_ms"] = round(
            1e3 / max(telem[key]["items_per_sec"], 1e-9), 5)
    for key in ("off", "on"):
        rec = telem[key]
        print(f"  telemetry/{rec['tracing']:3s}: "
              f"{rec['items_per_sec']:9.1f} items/s  "
              f"({rec['trace_events']} events recorded)")
    print(f"  telemetry: on/off put throughput x{ratio}")
    # tracing-OFF must be exactly inert (the child additionally asserts
    # the module never imported); tracing-ON must have actually traced
    assert telem["off"]["trace_events"] == 0, \
        "untraced producer recorded events — REPRO_TRACE gating broken"
    assert telem["on"]["trace_events"] > 0, \
        "traced producer recorded nothing — span recorder dead"
    # ISSUE 8 acceptance: the span recorder is an append to a
    # preallocated per-thread ring + one 8-byte urandom id per flush —
    # <5% of the put path. On a single CPU the spawned producer
    # serializes against the server/drain threads and the ratio
    # measures core starvation, so it is reported data there.
    if (multiprocessing.cpu_count() or 1) >= 2:
        assert ratio >= 0.95, \
            f"tracing costs >5% put throughput: x{ratio}"
    else:
        print("  telemetry: single CPU — overhead assert skipped")
    result["telemetry"] = telem

    # -- recovery section: journal overhead + replacement warm-up ------------
    recovery = _drive_recovery(duration_s=duration)
    print(f"  recovery: journaled/plain put throughput "
          f"x{recovery['journal_put_ratio']}  "
          f"({recovery['journaled_put_items_per_sec']:.0f} vs "
          f"{recovery['plain_put_items_per_sec']:.0f} items/s)  "
          f"time-to-first-pop {recovery['t_recover_s']*1e3:.1f}ms "
          f"({recovery['recovered_items']} items replayed)")
    # ISSUE 6 acceptance: the write-ahead journal must cost <5% streaming
    # put throughput — its whole design (apply-then-append reusing the
    # wire blob, group-committed writes at ack boundaries, no fsync on
    # the hot path) exists to make parent crash-safety effectively free.
    # The cost lands on the SERVER side of the stream; with ≥2 CPUs it
    # rides a core the producer isn't using, which is the deployment
    # shape the claim is about — on a single CPU every server-side
    # cycle serializes against the producer and the ratio only measures
    # core starvation, so it is reported data there, not a gate.
    if (multiprocessing.cpu_count() or 1) >= 2:
        assert recovery["journal_put_ratio"] >= 0.95, (
            f"journal costs >5% put throughput: "
            f"x{recovery['journal_put_ratio']}")
    else:
        print("  recovery: single CPU — journal overhead assert skipped")
    result["recovery"] = recovery

    save("BENCH_backpressure", result)
    return result


if __name__ == "__main__":
    run()
