"""Paper Table 2: task success across the four suites — RL (GIPO
fine-tuning, the AcceRL pipeline) vs the supervised (OpenVLA-OFT stand-in)
baseline.

The reproduced CLAIM is relative: RL fine-tuning recovers errors the
supervised policy compounds, with the largest gap on the long-horizon
suite (paper: 99.1 vs 90.7 on Long).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import (bc_train, collect_demos, eval_policy, save,
                               tiny_cfg)
from repro.configs.base import RLConfig, RuntimeConfig
from repro.envs.toy_manipulation import SUITES
from repro.runtime import AcceRLSystem


def run(quick: bool = True) -> Dict:
    cfg = tiny_cfg(layers=2, d_model=64)
    suites = list(SUITES)
    bc_eps = 80 if quick else 200
    bc_steps = 250 if quick else 800
    rl_wall = 90.0 if quick else 300.0
    eval_eps = 16 if quick else 40
    max_steps = {"long": 30}.get

    result: Dict = {}
    for suite in suites:
        ms = max_steps(suite) or 16
        demos = collect_demos(suite, cfg, episodes=bc_eps, max_steps=ms)
        bc_params, _ = bc_train(cfg, demos, steps=bc_steps)
        sft = eval_policy(cfg, bc_params, suite, episodes=eval_eps,
                          max_steps=ms)

        rl = RLConfig(grad_accum=1, lr_policy=5e-5, lr_value=5e-4,
                      gipo_sigma=0.5, kl_coef=0.05)
        rt = RuntimeConfig(num_rollout_workers=4, inference_batch=4)
        sys_ = AcceRLSystem(cfg, rl, rt, suite=suite, segment_horizon=6,
                            max_episode_steps=ms, batch_episodes=6)
        # RL fine-tunes the supervised checkpoint (the paper's setup)
        sys_.trainer.state = sys_.trainer.state._replace(params=bc_params)
        sys_.run_async(train_steps=10_000, wall_timeout_s=rl_wall)
        got = sys_.store.acquire(timeout=5.0)
        rl_params = got[0] if got else bc_params
        rl_res = eval_policy(cfg, rl_params, suite, episodes=eval_eps,
                             max_steps=ms)
        result[suite] = {"sft": sft, "rl": rl_res}
        print(f"  {suite:8s}: SFT {sft['success_rate']:.2f} -> "
              f"RL {rl_res['success_rate']:.2f}")

    save("task_success", result)
    return result


if __name__ == "__main__":
    run()
