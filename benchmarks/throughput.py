"""Paper Table 1 + Figure 3: synchronous vs asynchronous throughput,
rollout-worker scaling, the eq.-1 dynamic-batching window, and the
multi-process mode (remote rollout workers behind the transport
subsystem vs the same workers in-process).

CPU-structural reproduction: absolute SPS is hardware-bound, but the
CLAIMS are relative — async > sync under long-tail env latency, near-linear
worker scaling, and the batching window bounding wait latency.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import save, tiny_cfg
from repro.configs.base import RLConfig, RuntimeConfig, TransportConfig
from repro.envs.toy_manipulation import lognormal_latency
from repro.runtime import AcceRLSystem


def _system(workers: int, latency_ms: float, seed: int = 0) -> AcceRLSystem:
    cfg = tiny_cfg(layers=2, d_model=64)
    rl = RLConfig(grad_accum=1, lr_policy=1e-4, lr_value=1e-3)
    rt = RuntimeConfig(num_rollout_workers=workers, inference_batch=8,
                       inference_max_wait_s=0.01)
    return AcceRLSystem(cfg, rl, rt, suite="spatial", segment_horizon=4,
                        max_episode_steps=12, batch_episodes=8,
                        latency=lognormal_latency(latency_ms, sigma=1.2,
                                                  seed=seed),
                        seed=seed)


def run(quick: bool = True) -> Dict:
    wall = 25.0 if quick else 60.0
    worker_counts = [1, 2, 4, 8] if quick else [1, 2, 4, 8, 16]
    result: Dict = {"scaling": [], "latency_ms": 3.0}

    # --- (a) worker scaling (Fig. 3a) --------------------------------------
    for n in worker_counts:
        sys_ = _system(n, latency_ms=3.0, seed=n)
        m = sys_.run_async(train_steps=10_000, wall_timeout_s=wall)
        result["scaling"].append({
            "workers": n, "sps_env": m["sps_env"],
            "trainer_util": m["trainer_util"],
            "inference_util": m["inference_util"]})
        print(f"  async workers={n:2d}: env SPS={m['sps_env']:7.2f} "
              f"train util={m['trainer_util']:.2f}")

    # --- (b) sync vs async under identical resources (Table 1) -------------
    n = worker_counts[-1]
    sys_a = _system(n, latency_ms=3.0, seed=101)
    ma = sys_a.run_async(train_steps=10_000, wall_timeout_s=wall)
    sys_s = _system(n, latency_ms=3.0, seed=101)
    ms = sys_s.run_sync(train_steps=10_000, episodes_per_round=n,
                        wall_timeout_s=wall)
    speedup = ma["sps_env"] / max(ms["sps_env"], 1e-9)
    result["sync_vs_async"] = {
        "async": ma, "sync": ms, "speedup_env_sps": speedup}
    print(f"  sync SPS={ms['sps_env']:.2f} vs async SPS={ma['sps_env']:.2f}"
          f" -> speedup {speedup:.2f}x (paper: 2.4x)")

    # --- (c) eq.-1 dynamic window micro-benchmark --------------------------
    # oversized windows (n > largest bucket) are split before padding
    from repro.runtime.inference import pad_to_bucket, split_window
    buckets = (1, 2, 4, 8, 16, 32)
    result["bucket_pad"] = [
        {"n": n_, "chunks": [pad_to_bucket(c, buckets)
                             for c in split_window(n_, buckets)]}
        for n_ in (1, 3, 5, 9, 17, 33)]

    # --- (d) multi-process mode: remote rollout workers --------------------
    # the transport subsystem moves the SAME W rollout envs into a spawned
    # worker process (socket channels + weight-store wire). The child pays
    # jax init + jit (~5-10s) inside the wall, so the ratio UNDERSTATES
    # the remote path — the structural claim is only that training
    # proceeds across the process boundary at a comparable order of
    # magnitude; the wall is longer than the other sections to amortize
    # the spawn cost.
    mp_wall = 40.0 if quick else 75.0
    w = 2
    m_in = _system(w, latency_ms=3.0, seed=202).run_async(
        train_steps=10_000, wall_timeout_s=mp_wall)
    cfg = tiny_cfg(layers=2, d_model=64)
    rl = RLConfig(grad_accum=1, lr_policy=1e-4, lr_value=1e-3)
    rt = RuntimeConfig(
        num_rollout_workers=0, inference_batch=8,
        transport=TransportConfig(remote_rollout_workers=1,
                                  envs_per_worker=w))
    sys_r = AcceRLSystem(cfg, rl, rt, suite="spatial", segment_horizon=4,
                         max_episode_steps=12, batch_episodes=8,
                         remote_latency_ms=3.0, remote_latency_sigma=1.2,
                         seed=202)
    m_r = sys_r.run_async(train_steps=10_000, wall_timeout_s=mp_wall)
    xfer = m_r["services"]["transport"]["counters"]
    result["multiprocess"] = {
        "workers": w,
        "in_process": {k: m_in[k] for k in ("sps_env", "train_steps",
                                            "env_steps", "mean_policy_lag")},
        "remote": {k: m_r[k] for k in ("sps_env", "train_steps",
                                       "env_steps", "mean_policy_lag")},
        "remote_over_local_env_sps": m_r["sps_env"]
        / max(m_in["sps_env"], 1e-9),
        "wire_rx_bytes_total": xfer.get("rx_bytes", 0.0),
        "wire_tx_bytes_total": xfer.get("tx_bytes", 0.0),
        "wire_requests": xfer.get("requests", 0.0),
    }
    print(f"  multiprocess: in-proc SPS={m_in['sps_env']:.2f} vs remote "
          f"SPS={m_r['sps_env']:.2f} "
          f"({result['multiprocess']['remote_over_local_env_sps']:.2f}x, "
          f"{xfer.get('rx_bytes', 0) / 2**20:.1f} MiB over the wire)")

    save("throughput", result)
    return result


if __name__ == "__main__":
    run()
