"""Paper Figure 4b + Appendix A: online sample efficiency of AcceRL-WM.

Both systems start from the SAME suboptimal (BC-pretrained) checkpoint; the
WM system additionally gets M_obs/M_reward pre-trained on offline oracle
trajectories (the paper's 1,000 OOD trajectories). We count REAL
environment steps consumed to reach a target mean return — the paper's
claim is a ~200× reduction; the structural reproduction asserts
WM ≪ model-free.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import bc_train, collect_demos, save, tiny_cfg
from repro.configs.base import RLConfig, RuntimeConfig, WMConfig
from repro.runtime import AcceRLSystem
from repro.wm import AcceRLWMSystem
from repro.wm.wm_system import pretrain_world_model


def run(quick: bool = True) -> Dict:
    cfg = tiny_cfg(layers=2, d_model=64)
    suite = "spatial"
    wall = 60.0 if quick else 300.0
    rl = RLConfig(grad_accum=1, lr_policy=5e-5, lr_value=5e-4,
                  gipo_sigma=0.5)
    rt = RuntimeConfig(num_rollout_workers=3, inference_batch=4)
    wm = WMConfig(imagine_horizon=2, history_frames=2, diffusion_steps=4,
                  obs_train_interval=3, reward_train_interval=10,
                  reward_scale=5.0)

    # shared suboptimal init (few demos, few steps — deliberately weak)
    demos = collect_demos(suite, cfg, episodes=10, max_steps=12)
    init_params, _ = bc_train(cfg, demos, steps=40)

    # offline WM pretraining on oracle (OOD) trajectories
    n_traj = 50 if quick else 200
    pre = pretrain_world_model(suite, wm, trajectories=n_traj,
                               train_steps=150 if quick else 600,
                               action_vocab=cfg.action_vocab_size,
                               action_dim=cfg.action_dim, max_steps=12)

    # --- model-free AcceRL --------------------------------------------------
    sys_mf = AcceRLSystem(cfg, rl, rt, suite=suite, segment_horizon=4,
                          max_episode_steps=12, batch_episodes=4)
    sys_mf.trainer.state = sys_mf.trainer.state._replace(params=init_params)
    m_mf = sys_mf.run_async(train_steps=10_000, wall_timeout_s=wall)

    # --- AcceRL-WM ----------------------------------------------------------
    sys_wm = AcceRLWMSystem(cfg, rl, rt, wm, wm_params=pre, suite=suite,
                            segment_horizon=4, max_episode_steps=12,
                            imagination_batch=8)
    sys_wm.img_trainer.state = sys_wm.img_trainer.state._replace(
        params=init_params)
    m_wm = sys_wm.run_wm(train_steps=10_000, wall_timeout_s=wall)

    mf_steps_per_update = m_mf["env_steps"] / max(m_mf["train_steps"], 1)
    wm_steps_per_update = (m_wm["real_env_steps"]
                           / max(m_wm["img_train_steps"], 1))
    ratio = mf_steps_per_update / max(wm_steps_per_update, 1e-9)
    result = {
        "model_free": m_mf, "wm": m_wm,
        "mf_real_steps_per_update": mf_steps_per_update,
        "wm_real_steps_per_update": wm_steps_per_update,
        "sample_efficiency_ratio": ratio,
        "wm_pretrain_trajectories": n_traj,
    }
    print(f"  model-free: {m_mf['env_steps']} real steps / "
          f"{m_mf['train_steps']} updates = {mf_steps_per_update:.1f}")
    print(f"  WM:         {m_wm['real_env_steps']} real steps / "
          f"{m_wm['img_train_steps']} updates = {wm_steps_per_update:.1f} "
          f"(+{m_wm['imagined_steps']} imagined)")
    print(f"  real-sample efficiency ratio: {ratio:.1f}x (paper: up to 200x)")
    save("sample_efficiency", result)
    return result


if __name__ == "__main__":
    run()
