"""Paper Figure 4b + Appendix A: online sample efficiency of AcceRL-WM.

Both systems start from the SAME suboptimal (BC-pretrained) checkpoint; the
WM system additionally gets M_obs/M_reward pre-trained on offline oracle
trajectories (the paper's 1,000 OOD trajectories). We count REAL
environment steps consumed to reach a target mean return — the paper's
claim is a ~200× reduction; the structural reproduction asserts
WM ≪ model-free.

Additionally sweeps ``rt.mix_real_fraction`` ∈ {0.0, 0.25, 0.5} (ROADMAP
"Mixed real/imagined training diets"): the same WM system with the policy
trainer's MixedExperienceSource pinned to each real-segment share, so the
bench JSON records how the real/imagined diet trades real-step cost
against the pure-imagination extreme (0.0 = paper §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from benchmarks.common import bc_train, collect_demos, save, tiny_cfg
from repro.configs.base import RLConfig, RuntimeConfig, WMConfig
from repro.runtime import AcceRLSystem
from repro.wm import AcceRLWMSystem
from repro.wm.wm_system import pretrain_world_model


def run(quick: bool = True) -> Dict:
    cfg = tiny_cfg(layers=2, d_model=64)
    suite = "spatial"
    wall = 60.0 if quick else 300.0
    rl = RLConfig(grad_accum=1, lr_policy=5e-5, lr_value=5e-4,
                  gipo_sigma=0.5)
    rt = RuntimeConfig(num_rollout_workers=3, inference_batch=4)
    wm = WMConfig(imagine_horizon=2, history_frames=2, diffusion_steps=4,
                  obs_train_interval=3, reward_train_interval=10,
                  reward_scale=5.0)

    # shared suboptimal init (few demos, few steps — deliberately weak)
    demos = collect_demos(suite, cfg, episodes=10, max_steps=12)
    init_params, _ = bc_train(cfg, demos, steps=40)

    # offline WM pretraining on oracle (OOD) trajectories
    n_traj = 50 if quick else 200
    pre = pretrain_world_model(suite, wm, trajectories=n_traj,
                               train_steps=150 if quick else 600,
                               action_vocab=cfg.action_vocab_size,
                               action_dim=cfg.action_dim, max_steps=12)

    # --- model-free AcceRL --------------------------------------------------
    sys_mf = AcceRLSystem(cfg, rl, rt, suite=suite, segment_horizon=4,
                          max_episode_steps=12, batch_episodes=4)
    sys_mf.trainer.state = sys_mf.trainer.state._replace(params=init_params)
    m_mf = sys_mf.run_async(train_steps=10_000, wall_timeout_s=wall)

    # --- AcceRL-WM ----------------------------------------------------------
    sys_wm = AcceRLWMSystem(cfg, rl, rt, wm, wm_params=pre, suite=suite,
                            segment_horizon=4, max_episode_steps=12,
                            imagination_batch=8)
    sys_wm.img_trainer.state = sys_wm.img_trainer.state._replace(
        params=init_params)
    m_wm = sys_wm.run_wm(train_steps=10_000, wall_timeout_s=wall)

    mf_steps_per_update = m_mf["env_steps"] / max(m_mf["train_steps"], 1)
    wm_steps_per_update = (m_wm["real_env_steps"]
                           / max(m_wm["img_train_steps"], 1))
    ratio = mf_steps_per_update / max(wm_steps_per_update, 1e-9)
    result = {
        "model_free": m_mf, "wm": m_wm,
        "mf_real_steps_per_update": mf_steps_per_update,
        "wm_real_steps_per_update": wm_steps_per_update,
        "sample_efficiency_ratio": ratio,
        "wm_pretrain_trajectories": n_traj,
    }
    print(f"  model-free: {m_mf['env_steps']} real steps / "
          f"{m_mf['train_steps']} updates = {mf_steps_per_update:.1f}")
    print(f"  WM:         {m_wm['real_env_steps']} real steps / "
          f"{m_wm['img_train_steps']} updates = {wm_steps_per_update:.1f} "
          f"(+{m_wm['imagined_steps']} imagined)")
    print(f"  real-sample efficiency ratio: {ratio:.1f}x (paper: up to 200x)")

    # --- real/imagined diet curve (rt.mix_real_fraction sweep) -------------
    # segment_horizon must equal wm.imagine_horizon: a mixed diet collates
    # real and imagined segments into one super-batch (bind() enforces it)
    diet_wall = 30.0 if quick else 120.0
    result["diet_curve"] = []
    for frac in (0.0, 0.25, 0.5):
        rt_f = dataclasses.replace(rt, mix_real_fraction=frac)
        sys_f = AcceRLWMSystem(cfg, rl, rt_f, wm, wm_params=pre,
                               suite=suite,
                               segment_horizon=wm.imagine_horizon,
                               max_episode_steps=12, imagination_batch=8)
        sys_f.img_trainer.state = sys_f.img_trainer.state._replace(
            params=init_params)
        m_f = sys_f.run_wm(train_steps=10_000, wall_timeout_s=diet_wall)
        src = sys_f.trainer.source.stats()
        consumed = src["real_consumed"] + src["imagined_consumed"]
        rec = {
            "real_fraction": frac,
            "img_train_steps": m_f["img_train_steps"],
            "real_env_steps": m_f["real_env_steps"],
            "imagined_steps": m_f["imagined_steps"],
            "real_consumed": src["real_consumed"],
            "imagined_consumed": src["imagined_consumed"],
            "realized_real_share": (src["real_consumed"] / consumed
                                    if consumed else 0.0),
            "real_steps_per_update": (m_f["real_env_steps"]
                                      / max(m_f["img_train_steps"], 1)),
            "mean_return": m_f["mean_return"],
        }
        result["diet_curve"].append(rec)
        print(f"  diet f={frac:4.2f}: real share "
              f"{rec['realized_real_share']:.2f} | "
              f"{rec['real_steps_per_update']:.1f} real steps/update | "
              f"return {rec['mean_return']:.2f}")

    save("sample_efficiency", result)
    return result


if __name__ == "__main__":
    run()
