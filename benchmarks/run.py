"""Benchmark driver — one module per paper table/figure (DESIGN.md §6).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

| module            | paper artifact                                   |
|-------------------|--------------------------------------------------|
| fused_loss        | hot-path: fused GIPO loss vs unfused reference   |
| throughput        | Table 1, Fig. 3 (+ eq. 1 batching window)        |
| task_success      | Table 2 (RL vs supervised, four suites)          |
| gipo_ablation     | Fig. 8, Table 9 (GIPO vs PPO under staleness)    |
| value_recompute   | Fig. 7, App. C.1 (fused JIT-GAE, ~30% speedup)   |
| sync_overhead     | Table 8 (weight-sync transports, policy lag)     |
| sample_efficiency | Fig. 4b (WM vs model-free) + real/imagined diets |
| backpressure      | channel policies under saturation (perf-gated)   |
| roofline_report   | deliverable (g): dry-run roofline table          |
"""
from __future__ import annotations

import argparse
import time
import traceback

MODULES = ("fused_loss", "value_recompute", "gipo_ablation",
           "sync_overhead", "throughput", "task_success",
           "sample_efficiency", "backpressure", "roofline_report")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="long runs (default: quick)")
    ap.add_argument("--only", choices=MODULES)
    args = ap.parse_args()

    mods = [args.only] if args.only else list(MODULES)
    failures = []
    for name in mods:
        print(f"\n=== {name} " + "=" * max(60 - len(name), 0), flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            mod.run(quick=not args.full)
            print(f"--- {name} done in {time.time() - t0:.1f}s")
        except Exception:  # noqa: BLE001 — keep the suite going
            traceback.print_exc()
            failures.append(name)
    print(f"\n{len(mods) - len(failures)}/{len(mods)} benchmarks OK"
          + (f"; FAILED: {failures}" if failures else ""))
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
