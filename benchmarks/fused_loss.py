"""Fused GIPO loss path vs the unfused reference (hot-path perf start).

Measures ``jax.value_and_grad`` wall time of the trainer's policy-loss tail
(action head + GIPO surrogate + entropy + KL) two ways:

  * reference — materializes the [N, V] logits and their log-softmax and
    walks them per term (what ``loss_fn`` did before ``rl.fused_loss``);
  * fused     — ``repro.kernels.dispatch.policy_head_loss``: token blocks
    streamed through the custom-VJP Pallas kernel on TPU, the checkpointed
    jnp block-scan twin elsewhere. No [N, V] intermediate in HBM.

The peak-memory proxy is the largest live loss-path intermediate in bytes:
N·V·4 for the reference log-softmax vs block_n·V·4 for the fused block.
Emits ``experiments/bench/BENCH_fused_loss.json``.
"""
from __future__ import annotations

import argparse
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, timeit
from repro.kernels import dispatch, ref

SIGMA = 0.2

# (N tokens, action vocab V, hidden width d)
QUICK_SHAPES = ((4_096, 64, 128), (8_192, 64, 128))
FULL_SHAPES = ((16_384, 256, 256), (65_536, 256, 256), (16_384, 1_024, 256))


def _data(n: int, v: int, d: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((n, d)), jnp.float32),
            jnp.asarray(rng.standard_normal((d, v)) * 0.2, jnp.float32),
            jnp.asarray(rng.integers(0, v, n), jnp.int32),
            jnp.asarray(rng.standard_normal(n) * 0.3, jnp.float32),
            jnp.asarray(rng.standard_normal(n), jnp.float32),
            jnp.asarray((rng.random(n) > 0.1).astype(np.float32)))


def _combine(pg, ent, kl, _metrics):
    return pg + 0.1 * kl - 0.01 * ent


def bench_shape(n: int, v: int, d: int, iters: int) -> Dict:
    hidden, w, targets, logp_old, adv, mask = _data(n, v, d)
    block_n = dispatch.loss_block_n()

    @jax.jit
    def reference(h, w_):
        return jax.value_and_grad(
            lambda h_, w2: _combine(*ref.reference_policy_loss(
                h_, w2, targets, logp_old, adv, mask, SIGMA)),
            argnums=(0, 1))(h, w_)

    @jax.jit
    def fused(h, w_):
        return jax.value_and_grad(
            lambda h_, w2: _combine(*dispatch.policy_head_loss(
                h_, w2, targets, logp_old, adv, mask, sigma=SIGMA)),
            argnums=(0, 1))(h, w_)

    (l_ref, _), (l_fused, _) = reference(hidden, w), fused(hidden, w)
    assert abs(float(l_ref) - float(l_fused)) < 1e-3 * max(
        1.0, abs(float(l_ref))), (float(l_ref), float(l_fused))

    t_ref = timeit(reference, hidden, w, iters=iters)
    t_fused = timeit(fused, hidden, w, iters=iters)
    return {
        "n": n, "v": v, "d": d, "block_n": block_n,
        "t_reference_s": t_ref, "t_fused_s": t_fused,
        "speedup": t_ref / max(t_fused, 1e-12),
        # largest live loss-path intermediate (f32 log-softmax vs one block)
        "ref_peak_intermediate_bytes": n * v * 4,
        "fused_peak_intermediate_bytes": block_n * v * 4,
        "loss_abs_diff": abs(float(l_ref) - float(l_fused)),
    }


def run(quick: bool = True, iters: int = 5) -> Dict:
    shapes = QUICK_SHAPES if quick else QUICK_SHAPES + FULL_SHAPES
    result = {
        "backend": jax.default_backend(),
        "dispatch_mode": dispatch.resolve_mode(),
        "uses_pallas": dispatch.use_pallas(),
        "shapes": [],
    }
    for n, v, d in shapes:
        r = bench_shape(n, v, d, iters)
        result["shapes"].append(r)
        print(f"  N={n:>6} V={v:>5} d={d:>4}: ref {r['t_reference_s']*1e3:8.2f} ms"
              f"  fused {r['t_fused_s']*1e3:8.2f} ms  "
              f"({r['speedup']:.2f}x; peak {r['ref_peak_intermediate_bytes']/2**20:.1f} MiB"
              f" -> {r['fused_peak_intermediate_bytes']/2**20:.2f} MiB)",
              flush=True)
    save("BENCH_fused_loss", result)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="two small shapes, smoke-level iters")
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args()
    run(quick=args.quick, iters=args.iters or (3 if args.quick else 5))
