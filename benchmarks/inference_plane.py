"""Shared-tier vs per-worker-colocated inference (ISSUE 7 acceptance).

The disaggregated inference plane's economic claim: one pool that
continuously batches across EVERY worker's action requests fills its
batch buckets better than N per-worker pools, each of which only ever
sees its own ``ENVS`` outstanding requests:

  * **colocated** — PR 4's shape: one ``InferenceService`` per worker,
    submitted to in-process. A per-worker pool can never batch beyond
    its own envs, so every window pads ``ENVS`` up to the next bucket
    and the padded slots are pure wasted accelerator work.
  * **shared** — the inference plane: every worker is a
    ``RemoteInferenceClient`` dialing one ``InferenceBroker`` +
    ``InferenceService`` behind a real ``TransportServer`` — the wire
    overhead is deliberately IN the measurement; the aggregated queue
    lets the tier trigger windows at a bucket boundary, so padding
    collapses while per-forward work amortizes across more real rows.

Sweeps 1/2/4 workers for a fixed wall duration each. Emits
``BENCH_inference.json`` (registered with the perf gate: the committed
baseline under ``experiments/bench`` is compared by CI; the
fixed-duration ``t_wall_s`` keys are the gated stability signal).
Structural asserts: at 4 workers the shared tier's padded-slot fraction
is strictly lower, and (on ≥2-CPU hosts — aggregation throughput is a
parallelism claim) its served-actions/s at least matches colocated.
"""
from __future__ import annotations

import multiprocessing
import threading
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import save, tiny_cfg

ENVS = 3               # concurrent in-flight requests per rollout worker
OBS_TOKENS = 12
T_MAX_S = 0.004        # eq.-1 window wait, both sides


def _pool(cfg, store, *, batch: int, workers: int = 1):
    from repro.configs.base import RuntimeConfig
    from repro.runtime import InferenceService
    rt = RuntimeConfig(num_inference_workers=workers,
                       inference_batch=batch,
                       inference_max_wait_s=T_MAX_S)
    return InferenceService(cfg, store, rt)


def _warm(pool, params, buckets) -> None:
    """Pre-trace every bucket shape a run can hit, so jit compiles land
    outside the timed window (and cannot land on only one side)."""
    import jax
    key = jax.random.PRNGKey(0)
    for nb in buckets:
        obs = np.zeros((nb, OBS_TOKENS), np.int32)
        steps = np.zeros(nb, np.int32)
        jax.block_until_ready(pool._fn(params, key, obs, steps, None))


def _drive(submit_fns: List, *, duration_s: float) -> Dict:
    """One timed run: each worker keeps ``ENVS`` requests in flight
    (submit a burst, wait for all, repeat) against its ``submit`` fn."""
    stop = threading.Event()
    counts = [0] * len(submit_fns)

    def worker(idx: int) -> None:
        rng = np.random.default_rng(idx)
        while not stop.is_set():
            obs = rng.integers(0, 100, (ENVS, OBS_TOKENS)).astype(np.int32)
            futs = [submit_fns[idx](obs[e], None, 0) for e in range(ENVS)]
            for f in futs:
                f.result(timeout=120.0)
            counts[idx] += ENVS

    threads = [threading.Thread(target=worker, args=(i,), daemon=True)
               for i in range(len(submit_fns))]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join(timeout=30.0)
    wall = time.monotonic() - t0
    return {"served": int(sum(counts)), "t_wall_s": round(wall, 3)}


def _pool_stats(pools) -> Dict:
    served = sum(p.requests_served for p in pools)
    padded = sum(p.padded_slots for p in pools)
    batches = sum(p.batches_run for p in pools)
    return {
        "batches": int(batches),
        "mean_window": round(served / max(batches, 1), 2),
        "padded_slots": int(padded),
        "padded_frac": round(padded / max(served + padded, 1), 4),
    }


def _drive_colocated(cfg, params, version, n_workers: int,
                     duration_s: float) -> Dict:
    from repro.runtime import VersionedWeightStore
    pools = []
    for _ in range(n_workers):
        store = VersionedWeightStore()
        store.publish(params, version)
        pools.append(_pool(cfg, store, batch=ENVS))
    for p in pools:
        # a per-worker pool only ever sees its own envs: windows of
        # ENVS (padded up) plus straggler shapes at the edges
        _warm(p, params, (1, 2, 4))
        p.start()
    try:
        rec = _drive([p.submit for p in pools], duration_s=duration_s)
    finally:
        for p in pools:
            p.stop()
    rec.update(_pool_stats(pools))
    rec["mode"] = "colocated"
    rec["actions_per_s"] = round(rec["served"] / rec["t_wall_s"], 1)
    return rec


def _bucket_window(n_outstanding: int, buckets) -> int:
    """The shared tier's eq.-1 trigger B: the largest bucket the
    aggregate demand can FILL — windows then carve at a bucket boundary
    and padding collapses (the whole point of aggregation)."""
    fit = [b for b in buckets if b <= n_outstanding]
    return fit[-1] if fit else buckets[0]


def _drive_shared(cfg, params, version, n_workers: int,
                  duration_s: float) -> Dict:
    from repro.runtime import VersionedWeightStore
    from repro.runtime.transport import (InferenceBroker,
                                         RemoteInferenceClient,
                                         TransportServer)
    store = VersionedWeightStore()
    store.publish(params, version)
    rt_buckets = _pool(cfg, store, batch=1).rt.batch_buckets
    batch = _bucket_window(n_workers * ENVS, rt_buckets)
    pool = _pool(cfg, store, batch=batch)
    # demand up to n*ENVS outstanding: warm every bucket through the
    # largest window plus straggler shapes below it
    _warm(pool, params,
          tuple(b for b in rt_buckets if b <= max(batch, 4)))
    pool.start()
    server = TransportServer()
    server.set_inference(InferenceBroker(pool))
    server.start()
    clients = [RemoteInferenceClient(server.address, client_id=f"w{i}")
               for i in range(n_workers)]
    try:
        rec = _drive([c.submit for c in clients], duration_s=duration_s)
    finally:
        for c in clients:
            c.close()
        server.stop()
        server.join(timeout=10.0)
        pool.stop()
    rec.update(_pool_stats([pool]))
    rec["mode"] = "shared"
    rec["window_batch"] = batch
    rec["actions_per_s"] = round(rec["served"] / rec["t_wall_s"], 1)
    return rec


def run(quick: bool = True) -> Dict:
    import jax
    from repro.models.policy import init_policy_params
    duration = 2.0 if quick else 6.0
    cfg = tiny_cfg(d_model=64)
    params = init_policy_params(cfg, jax.random.PRNGKey(0))
    result: Dict = {"duration_s_requested": duration, "envs_per_worker": ENVS,
                    "sweep": []}
    for n in (1, 2, 4):
        shared = _drive_shared(cfg, params, 0, n, duration)
        colocated = _drive_colocated(cfg, params, 0, n, duration)
        rec = {"workers": n, "shared": shared, "colocated": colocated,
               "shared_over_colocated_throughput": round(
                   shared["actions_per_s"]
                   / max(colocated["actions_per_s"], 1e-9), 2)}
        result["sweep"].append(rec)
        print(f"  workers={n}: shared {shared['actions_per_s']:8.1f} act/s "
              f"(window {shared['window_batch']}, mean batch "
              f"{shared['mean_window']:.1f}, pad {shared['padded_frac']:.1%})"
              f"  vs colocated {colocated['actions_per_s']:8.1f} act/s "
              f"(mean batch {colocated['mean_window']:.1f}, "
              f"pad {colocated['padded_frac']:.1%})  "
              f"x{rec['shared_over_colocated_throughput']}")

    at4 = next(r for r in result["sweep"] if r["workers"] == 4)
    # structural claim, any host: aggregating 4 workers' demand lets the
    # tier carve bucket-aligned windows — padding must be STRICTLY lower
    # than per-worker pools that pad ENVS up to a bucket every window
    assert (at4["shared"]["padded_frac"]
            < at4["colocated"]["padded_frac"]), \
        "shared tier must waste strictly fewer padded slots at 4 workers"
    assert at4["shared"]["mean_window"] > at4["colocated"]["mean_window"], \
        "shared tier must form larger windows than per-worker pools"
    # throughput is a parallelism claim (the tier's bigger forwards must
    # amortize while N colocated pools compete for the same cores) — on a
    # single CPU there is nothing to arbitrate, reported data only there
    if (multiprocessing.cpu_count() or 1) >= 2:
        assert (at4["shared"]["actions_per_s"]
                >= at4["colocated"]["actions_per_s"]), \
            "shared tier fell below per-worker colocated pools at 4 workers"
    else:
        print("  inference_plane: single CPU — throughput assert skipped")

    save("BENCH_inference", result)
    return result


if __name__ == "__main__":
    run()
