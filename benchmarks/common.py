"""Shared benchmark utilities: tiny configs, BC pre-training (the
OpenVLA-OFT supervised stand-in), timing helpers, and result I/O."""
from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ModelConfig, RLConfig, RuntimeConfig
from repro.envs.toy_manipulation import ManipulationEnv
from repro.models.policy import init_policy_params, policy_forward
from repro.models.transformer import FRONTEND_DIM
from repro.optim import adamw

# REPRO_BENCH_OUT redirects result JSONs (CI writes fresh numbers to a
# scratch dir and gates them against the committed experiments/bench
# baselines via benchmarks.perf_gate)
OUT_DIR = pathlib.Path(os.environ.get(
    "REPRO_BENCH_OUT",
    pathlib.Path(__file__).resolve().parent.parent / "experiments" / "bench"))


def tiny_cfg(arch: str = "deepseek-7b", layers: int = 2,
             d_model: int = 128) -> ModelConfig:
    import dataclasses
    cfg = reduced(get_config(arch), layers=layers, d_model=d_model)
    return dataclasses.replace(cfg, num_prefix_tokens=1)


def save(name: str, result: Dict) -> None:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{name}.json").write_text(json.dumps(result, indent=1,
                                                     default=str))


def frames_to_prefix(frames: np.ndarray) -> np.ndarray:
    """[..., F_env] -> [..., 1, FRONTEND_DIM]."""
    out = np.zeros(frames.shape[:-1] + (1, FRONTEND_DIM), np.float32)
    out[..., 0, :frames.shape[-1]] = frames
    return out


# ---------------------------------------------------------------------------
# Behavior cloning on oracle demonstrations — the supervised (OpenVLA-OFT)
# baseline / the "suboptimal checkpoint" initialisation of Fig. 4b.
# ---------------------------------------------------------------------------

def collect_demos(suite: str, cfg: ModelConfig, *, episodes: int,
                  max_steps: int = 14, seed: int = 0,
                  noise: float = 0.05) -> List[Dict]:
    env = ManipulationEnv(suite=suite, action_vocab=cfg.action_vocab_size,
                          action_dim=cfg.action_dim, max_steps=max_steps,
                          seed=seed)
    env._rng = np.random.default_rng(seed)      # oracle noise source
    rng = np.random.default_rng(seed + 1)
    demos = []
    for ep in range(episodes):
        obs = env.reset(int(rng.integers(0, 10)))
        done = False
        while not done:
            a = env.oracle_action()
            demos.append({"tokens": obs["tokens"], "frame": obs["frame"],
                          "step": obs["step"], "actions": a})
            obs, _, done, _ = env.step(a)
    return demos


def bc_train(cfg: ModelConfig, demos: List[Dict], *, steps: int = 150,
             batch: int = 32, lr: float = 3e-4, seed: int = 0):
    """Supervised fine-tuning baseline: CE on oracle action tokens."""
    params = init_policy_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw.init(params)
    rng = np.random.default_rng(seed)

    def loss_fn(p, tokens, prefix, step_t, actions):
        out = policy_forward(cfg, p, tokens, actions, step_t,
                             prefix_embeds=prefix)
        logp = jax.nn.log_softmax(out.logits, axis=-1)
        tgt = jnp.take_along_axis(logp, actions[..., None], axis=-1)
        return -tgt.mean()

    @jax.jit
    def step(p, o, tokens, prefix, step_t, actions):
        l, g = jax.value_and_grad(loss_fn)(p, tokens, prefix, step_t,
                                           actions)
        p, o, _ = adamw.update(g, o, p, jnp.asarray(lr))
        return p, o, l

    losses = []
    n = len(demos)
    for it in range(steps):
        idx = rng.integers(0, n, batch)
        tokens = np.stack([demos[i]["tokens"] for i in idx])
        prefix = frames_to_prefix(
            np.stack([demos[i]["frame"] for i in idx]))
        step_t = np.array([demos[i]["step"] for i in idx], np.int32)
        actions = np.stack([demos[i]["actions"] for i in idx])
        params, opt, l = step(params, opt, tokens, prefix, step_t, actions)
        losses.append(float(l))
    return params, losses


def eval_policy(cfg: ModelConfig, params, suite: str, *, episodes: int = 20,
                max_steps: int = 14, temperature: float = 0.3,
                seed: int = 321) -> Dict:
    from repro.models.policy import make_inference_fn
    fn = make_inference_fn(cfg, temperature=temperature)
    env = ManipulationEnv(suite=suite, action_vocab=cfg.action_vocab_size,
                          action_dim=cfg.action_dim, max_steps=max_steps,
                          seed=seed)
    key = jax.random.PRNGKey(seed)
    succ, rets = 0, []
    for ep in range(episodes):
        obs = env.reset(ep % 10)
        done, ep_ret = False, 0.0
        while not done:
            key, sub = jax.random.split(key)
            toks, _, _ = fn(params, sub, obs["tokens"][None],
                            np.array([obs["step"]], np.int32),
                            frames_to_prefix(obs["frame"][None]))
            obs, r, done, info = env.step(np.asarray(toks[0]))
            ep_ret += r
        succ += int(info["success"])
        rets.append(ep_ret)
    return {"success_rate": succ / episodes,
            "mean_return": float(np.mean(rets))}


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall seconds per call (blocks on jax results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))
