"""Deliverable (g): render the roofline table from the dry-run artifacts.

Reads experiments/dryrun/<mesh>/*.json (produced by
``python -m repro.launch.dryrun --all [--multi-pod]``) and prints/writes the
per-(arch × shape) three-term roofline with the dominant bottleneck,
MODEL_FLOPS ratio, and per-device HBM, plus a one-line "what would move the
dominant term" note derived from the collective mix.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List

OUT = pathlib.Path(__file__).resolve().parent.parent / "experiments"


def _advice(rec: Dict) -> str:
    r = rec["roofline"]
    dom = r["dominant"]
    coll = {k: v for k, v in r["coll_by_kind"].items() if k != "counts"}
    top_coll = max(coll, key=coll.get) if any(coll.values()) else "none"
    if dom == "collective":
        return (f"cut {top_coll} volume (resharding of "
                f"{'experts/FSDP params' if rec.get('fsdp') else 'activations/KV'})")
    if dom == "memory":
        return "reduce bytes: fuse/bf16 more intermediates, larger blocks"
    return "already compute-bound: raise MFU via layout/fusion"


def load(mesh: str = "16x16") -> List[Dict]:
    d = OUT / "dryrun" / mesh
    recs = []
    for f in sorted(d.glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def render(mesh: str = "16x16") -> str:
    recs = load(mesh)
    lines = [
        f"### Roofline — mesh {mesh}",
        "",
        "| arch | shape | variant | compute s | memory s | collective s | "
        "dominant | MODEL/HLO | HBM GiB/dev (state) | fix |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    ok = fail = 0
    for rec in recs:
        if "error" in rec:
            lines.append(f"| {rec['arch']} | {rec['shape']} | "
                         f"{rec.get('variant', '?')} | — | — | — | "
                         f"FAILED: {rec['error'][:60]} | — | — | — |")
            fail += 1
            continue
        r = rec["roofline"]
        hbm = rec["memory"]["total_hbm_bytes"] / 2**30
        state = rec.get("state_bytes_per_dev")
        hbm_s = f"{hbm:.2f}" + (f" ({state/2**30:.2f})" if state else "")
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec.get('variant', '')} | "
            f"{r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | {hbm_s} | "
            f"{_advice(rec)} |")
        ok += 1
    lines.append("")
    lines.append(f"{ok} ok / {fail} failed of {ok + fail} pairs.")
    return "\n".join(lines)


def run(quick: bool = True) -> Dict:
    out = {}
    for mesh in ("16x16", "2x16x16"):
        if (OUT / "dryrun" / mesh).exists():
            text = render(mesh)
            print(text)
            out[mesh] = text
    (OUT / "roofline_report.md").write_text(
        "\n\n".join(out.values()) if out else "no dry-run artifacts yet\n")
    return {"meshes": list(out)}


if __name__ == "__main__":
    run()
