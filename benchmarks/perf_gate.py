"""Perf-regression gate: fresh ``BENCH_*.json`` vs committed baselines.

CI reruns the benchmark suite into a scratch dir (``REPRO_BENCH_OUT``) and
this gate compares every ``BENCH_*.json`` present in BOTH dirs against the
baselines committed under ``experiments/bench``:

  * wall-time keys (``t_*_s`` / ``*_ms``) may regress up to ``--tolerance``×
    the baseline (shared CI runners are noisy — the band is wide by design;
    the gate catches step-function regressions, not 5% drift);
  * memory-proxy keys (``*_bytes``) are exact: an increase fails — peak
    intermediates are deterministic, so any growth is a real regression;
  * parity keys (``*_abs_diff``) must stay within ``--parity-slack``× of
    the baseline (floor 1e-3) — a blown-up diff means a kernel broke;
  * a gated key present in the baseline but missing from the fresh output
    fails — renaming a metric must not silently un-gate it.

Records inside a JSON list are aligned by their shape signature (the
subset of ``n/v/d/b/t/h/p/workers`` keys) when present, else by index;
shapes only one side ran (quick vs full) are skipped.

    PYTHONPATH=src python -m benchmarks.perf_gate \
        --baseline experiments/bench --fresh /tmp/bench [--tolerance 2.0]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Tuple

SHAPE_KEYS = ("n", "v", "d", "b", "t", "h", "p", "workers")


def _is_time_key(key: str) -> bool:
    return key.endswith("_s") or key.endswith("_ms")


def _is_bytes_key(key: str) -> bool:
    return key.endswith("_bytes")


def _is_parity_key(key: str) -> bool:
    return key.endswith("_abs_diff")


def _signature(rec: Dict) -> Tuple:
    return tuple((k, rec[k]) for k in SHAPE_KEYS if k in rec)


def _gated_key(key: str) -> bool:
    return _is_time_key(key) or _is_bytes_key(key) or _is_parity_key(key)


def _contains_gated(obj) -> bool:
    """Whether any gated metric lives anywhere inside ``obj``."""
    if isinstance(obj, dict):
        return any((_gated_key(k) and isinstance(v, (int, float))
                    and not isinstance(v, bool)) or _contains_gated(v)
                   for k, v in obj.items())
    if isinstance(obj, list):
        return any(_contains_gated(v) for v in obj)
    return False


def _walk(path: str, base, fresh, tol: float, parity_slack: float,
          failures: List[str]) -> None:
    if isinstance(base, dict) and isinstance(fresh, dict):
        for key in sorted(base):
            sub = f"{path}.{key}" if path else key
            if key not in fresh:
                # anything gated vanishing from the fresh output must not
                # pass silently — a rename (of the key OR of a container
                # holding gated keys) would hide a real regression
                gated_scalar = (_gated_key(key)
                                and isinstance(base[key], (int, float))
                                and not isinstance(base[key], bool))
                if gated_scalar or _contains_gated(base[key]):
                    failures.append(
                        f"{sub}: gated metric(s) missing from fresh output")
                continue
            _walk(sub, base[key], fresh[key], tol, parity_slack, failures)
        return
    if isinstance(base, list) and isinstance(fresh, list):
        if base and isinstance(base[0], dict) and _signature(base[0]):
            by_sig = {_signature(r): r for r in fresh
                      if isinstance(r, dict)}
            for rec in base:
                sig = _signature(rec)
                if sig in by_sig:
                    label = ",".join(f"{k}={v}" for k, v in sig)
                    _walk(f"{path}[{label}]", rec, by_sig[sig], tol,
                          parity_slack, failures)
        else:
            for i, (b, f) in enumerate(zip(base, fresh)):
                _walk(f"{path}[{i}]", b, f, tol, parity_slack, failures)
        return
    if not isinstance(base, (int, float)) or isinstance(base, bool):
        return
    key = path.rsplit(".", 1)[-1]
    if _is_time_key(key):
        if fresh > base * tol:
            failures.append(
                f"{path}: {fresh:.6g} > {tol:g}x baseline {base:.6g}")
    elif _is_bytes_key(key):
        if fresh > base:
            failures.append(
                f"{path}: memory proxy grew {base:.0f} -> {fresh:.0f} bytes")
    elif _is_parity_key(key):
        bound = max(base * parity_slack, 1e-3)
        if fresh > bound:
            failures.append(
                f"{path}: parity diff {fresh:.6g} > bound {bound:.6g}")


def gate(baseline_dir: pathlib.Path, fresh_dir: pathlib.Path, *,
         tolerance: float = 2.0, parity_slack: float = 10.0
         ) -> Tuple[List[str], List[str]]:
    """Returns (checked file names, failure messages)."""
    checked, failures = [], []
    for base_path in sorted(baseline_dir.glob("BENCH_*.json")):
        fresh_path = fresh_dir / base_path.name
        if not fresh_path.exists():
            failures.append(f"{base_path.name}: fresh run missing "
                            f"(benchmark did not produce it)")
            continue
        checked.append(base_path.name)
        _walk("", json.loads(base_path.read_text()),
              json.loads(fresh_path.read_text()), tolerance, parity_slack,
              failures)
    return checked, failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="experiments/bench",
                    help="committed baseline dir")
    ap.add_argument("--fresh", required=True,
                    help="dir holding this run's BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="wall-time regression band (fresh <= tol * base)")
    ap.add_argument("--parity-slack", type=float, default=10.0,
                    help="allowed growth of *_abs_diff parity keys")
    args = ap.parse_args()

    checked, failures = gate(pathlib.Path(args.baseline),
                             pathlib.Path(args.fresh),
                             tolerance=args.tolerance,
                             parity_slack=args.parity_slack)
    if not checked and not failures:
        print("perf gate: no BENCH_*.json baselines found — nothing gated")
        return
    for name in checked:
        print(f"perf gate: checked {name}")
    if failures:
        print(f"perf gate: {len(failures)} regression(s)")
        for f in failures:
            print(f"  FAIL {f}")
        sys.exit(1)
    print(f"perf gate: OK ({len(checked)} file(s) within "
          f"{args.tolerance:g}x band)")


if __name__ == "__main__":
    main()
