"""AcceRL-WM example: offline world-model pre-training + imagination-driven
policy fine-tuning (paper §4, Fig. 4b).

    PYTHONPATH=src python examples/wm_imagination.py --trajectories 100
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

sys.path.insert(0, "benchmarks")

from repro.configs import get_config, reduced
from repro.configs.base import RLConfig, RuntimeConfig, WMConfig
from repro.wm import AcceRLWMSystem
from repro.wm.wm_system import pretrain_world_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="spatial")
    ap.add_argument("--trajectories", type=int, default=100,
                    help="offline oracle trajectories for WM pretraining "
                         "(paper: 1,000)")
    ap.add_argument("--steps", type=int, default=20,
                    help="policy updates on imagined data")
    ap.add_argument("--horizon", type=int, default=2,
                    help="imagination horizon H (paper Table 5: 2)")
    ap.add_argument("--wall-minutes", type=float, default=8.0)
    args = ap.parse_args()

    from common import bc_train, collect_demos  # benchmarks/

    cfg = reduced(get_config("deepseek-7b"), layers=2, d_model=64)
    cfg = dataclasses.replace(cfg, num_prefix_tokens=1)
    wm = WMConfig(imagine_horizon=args.horizon, history_frames=2,
                  diffusion_steps=4, obs_train_interval=3,
                  reward_train_interval=10, reward_scale=5.0)

    print(f"[1/3] offline WM pretraining on {args.trajectories} oracle "
          f"trajectories (OOD, eq. 4 potential source)")
    pre = pretrain_world_model(args.suite, wm,
                               trajectories=args.trajectories,
                               train_steps=200,
                               action_vocab=cfg.action_vocab_size,
                               action_dim=cfg.action_dim)
    print(f"      denoiser loss {pre['losses']['obs'][0]:.3f}->"
          f"{pre['losses']['obs'][-1]:.3f}; "
          f"reward loss {pre['losses']['reward'][0]:.3f}->"
          f"{pre['losses']['reward'][-1]:.3f} "
          f"({pre['transitions']} transitions)")

    print("[2/3] suboptimal policy init (weak BC)")
    demos = collect_demos(args.suite, cfg, episodes=8)
    init_params, _ = bc_train(cfg, demos, steps=30)

    rl = RLConfig(grad_accum=1, lr_policy=5e-5, lr_value=5e-4,
                  gipo_sigma=0.5)
    rt = RuntimeConfig(num_rollout_workers=2, inference_batch=4)
    sys_ = AcceRLWMSystem(cfg, rl, rt, wm, wm_params=pre, suite=args.suite,
                          segment_horizon=4, max_episode_steps=12,
                          imagination_batch=8)
    sys_.img_trainer.state = sys_.img_trainer.state._replace(
        params=init_params)

    print(f"[3/3] AcceRL-WM: alternating real rollout + imagination, "
          f"{args.steps} policy updates on B_img")
    m = sys_.run_wm(train_steps=args.steps,
                    wall_timeout_s=args.wall_minutes * 60)
    print(f"      real env steps: {m['real_env_steps']} | "
          f"imagined steps: {m['imagined_steps']} | "
          f"policy updates: {m['img_train_steps']} | "
          f"WM updates: {m['wm_updates']}")
    ratio = m["imagined_steps"] / max(m["real_env_steps"], 1)
    print(f"      imagined/real sample ratio: {ratio:.1f}x — the WM "
          f"substitutes physical interaction (paper: up to 200x)")


if __name__ == "__main__":
    main()
