"""Quickstart: build any assigned architecture, run one forward pass, one
prefill+decode, and one GIPO train step.

    PYTHONPATH=src python examples/quickstart.py --arch mamba2-2.7b
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.configs.base import RLConfig
from repro.core.train_step import init_train_state, make_train_step
from repro.data.trajectory import dummy_batch
from repro.models import transformer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--full-size", action="store_true",
                    help="instantiate the FULL config (needs lots of RAM; "
                         "default is the reduced smoke variant)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    print(f"arch={cfg.name} type={cfg.arch_type} layers={cfg.num_layers} "
          f"d_model={cfg.d_model} params≈{cfg.param_count()/1e6:.1f}M "
          f"(active {cfg.active_param_count()/1e6:.1f}M)")

    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)

    # --- forward ------------------------------------------------------------
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 16)), jnp.int32)
    out = transformer.forward(cfg, params, tokens)
    print(f"forward: logits {out['logits'].shape} "
          f"(action vocab = {cfg.action_vocab_size}, slimmed head)")

    # --- prefill + decode (the serve path) -----------------------------------
    res, cache = transformer.prefill(cfg, params, tokens, cache_len=24)
    dec, cache = transformer.decode(
        cfg, params, jnp.argmax(res["logits"][:, -1], -1), cache)
    print(f"decode: next-token logits {dec['logits'].shape}")

    # --- one GIPO train step --------------------------------------------------
    rl = RLConfig(grad_accum=2)
    state = init_train_state(cfg, key)
    batch = dummy_batch(4, 3, 12, cfg.action_dim, cfg.vocab_size,
                        cfg.action_vocab_size,
                        num_prefix=min(cfg.num_prefix_tokens, 4) or 0)
    step = make_train_step(cfg, rl, donate=False)
    state, metrics = step(state, batch)
    print("train step:", {k: round(float(v), 4) for k, v in metrics.items()
                          if k in ("loss", "pg_loss", "value_loss", "kl",
                                   "grad_norm")})


if __name__ == "__main__":
    main()
