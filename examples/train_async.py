"""End-to-end driver (deliverable (b)): supervised warm-start + fully
asynchronous GIPO fine-tuning on the built-in manipulation suite.

    PYTHONPATH=src python examples/train_async.py \
        --arch deepseek-7b --suite spatial --steps 200

``--preset tiny`` (default) runs in minutes on CPU; ``--preset 100m``
builds a ~100M-parameter backbone (same code path — expect hours on CPU,
it is meant for real accelerators).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

sys.path.insert(0, "benchmarks")

import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.configs.base import RLConfig, RuntimeConfig
from repro.envs.toy_manipulation import SUITES, lognormal_latency
from repro.runtime import AcceRLSystem


def build_cfg(arch: str, preset: str):
    cfg = get_config(arch)
    if preset == "tiny":
        cfg = reduced(cfg, layers=2, d_model=128)
    elif preset == "100m":
        cfg = reduced(cfg, layers=8, d_model=1024, vocab=8192)
        cfg = dataclasses.replace(cfg, head_dim_override=None, num_heads=16,
                                  num_kv_heads=4 if cfg.num_kv_heads else 0,
                                  d_ff=4096 if cfg.d_ff else 0)
    if cfg.num_prefix_tokens == 0:
        cfg = dataclasses.replace(cfg, num_prefix_tokens=1)
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=ASSIGNED_ARCHS)
    ap.add_argument("--suite", default="spatial", choices=SUITES)
    ap.add_argument("--preset", default="tiny", choices=("tiny", "100m"))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=6)
    ap.add_argument("--wall-minutes", type=float, default=15.0)
    ap.add_argument("--bc-episodes", type=int, default=40)
    ap.add_argument("--algo", default="gipo", choices=("gipo", "ppo"))
    ap.add_argument("--sync", action="store_true",
                    help="run the synchronous BASELINE instead (Fig. 1 left)")
    ap.add_argument("--backpressure", default="drop_oldest",
                    choices=("drop_oldest", "drop_newest", "block"),
                    help="experience-channel policy when B is full")
    args = ap.parse_args()

    from common import bc_train, collect_demos, eval_policy  # benchmarks/

    cfg = build_cfg(args.arch, args.preset)
    print(f"[1/3] BC warm-start on {args.bc_episodes} oracle episodes "
          f"({cfg.param_count()/1e6:.1f}M params)")
    demos = collect_demos(args.suite, cfg, episodes=args.bc_episodes)
    bc_params, losses = bc_train(cfg, demos, steps=150)
    sft = eval_policy(cfg, bc_params, args.suite, episodes=10)
    print(f"      BC loss {losses[0]:.3f}->{losses[-1]:.3f}; "
          f"SFT success {sft['success_rate']:.2f}")

    rl = RLConfig(algo=args.algo, grad_accum=1, lr_policy=5e-5,
                  lr_value=5e-4, gipo_sigma=0.5, kl_coef=0.05)
    rt = RuntimeConfig(num_rollout_workers=args.workers, inference_batch=8,
                       replay_backpressure=args.backpressure)
    sys_ = AcceRLSystem(cfg, rl, rt, suite=args.suite, segment_horizon=6,
                        max_episode_steps=14, batch_episodes=8,
                        latency=lognormal_latency(2.0, sigma=1.0))
    sys_.trainer.state = sys_.trainer.state._replace(params=bc_params)

    mode = "SYNC baseline" if args.sync else "ASYNC AcceRL"
    print(f"[2/3] {mode}: {args.steps} trainer steps, "
          f"{args.workers} rollout workers")
    # same services either way — only the scheduler differs
    runner = sys_.run_sync if args.sync else sys_.run_async
    m = runner(train_steps=args.steps,
               wall_timeout_s=args.wall_minutes * 60)
    print(f"      wall {m['wall_s']:.1f}s | env SPS {m['sps_env']:.1f} | "
          f"trainer util {m['trainer_util']:.2f} | "
          f"policy lag {m['mean_policy_lag']:.2f} | "
          f"rollout success {m['success_rate']:.2f}")
    unhealthy = {k: h for k, h in sys_.health().items() if not h["healthy"]}
    if unhealthy:
        print(f"      WARNING unhealthy services: {unhealthy}")

    print("[3/3] final evaluation")
    final = sys_.evaluate(episodes=20)
    print(f"      success {final['success_rate']:.2f} "
          f"(SFT was {sft['success_rate']:.2f}) | "
          f"return {final['mean_return']:.2f}")


if __name__ == "__main__":
    main()
