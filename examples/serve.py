"""Batched serving example: the Inference-as-a-Service pool answering
concurrent requests with eq.-1 dynamic-window batching, plus a live weight
swap mid-serving via the drain protocol.

    PYTHONPATH=src python examples/serve.py --arch internlm2-1.8b --requests 64
"""
from __future__ import annotations

import argparse
import dataclasses
import threading
import time

import jax
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.configs.base import RuntimeConfig
from repro.envs.toy_manipulation import T_OBS, FRAME_DIM
from repro.models.policy import init_policy_params
from repro.runtime import InferenceService, VersionedWeightStore


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=ASSIGNED_ARCHS)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8, help="B in eq. 1")
    ap.add_argument("--max-wait-ms", type=float, default=10.0,
                    help="T_max in eq. 1")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), layers=2, d_model=128)
    cfg = dataclasses.replace(cfg, num_prefix_tokens=1)
    rt = RuntimeConfig(num_inference_workers=1, inference_batch=args.batch,
                       inference_max_wait_s=args.max_wait_ms / 1e3)
    store = VersionedWeightStore()
    params = init_policy_params(cfg, jax.random.PRNGKey(0))
    store.publish(params, 0)
    service = InferenceService(cfg, store, rt).start()

    rng = np.random.default_rng(0)
    futures = []
    t0 = time.perf_counter()

    def client(i):
        # staggered arrivals — the step-level long-tail regime
        time.sleep(float(rng.random()) * 0.05)
        fut = service.submit(
            rng.integers(0, cfg.vocab_size, T_OBS).astype(np.int32),
            rng.random(FRAME_DIM).astype(np.float32), int(i % 30))
        futures.append((i, fut))

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(args.requests)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    # mid-serving weight swap with drain (App. D.6)
    store.begin_publish()
    params2 = init_policy_params(cfg, jax.random.PRNGKey(1))
    store.publish(params2, 1)

    done = 0
    for i, fut in futures:
        res = fut.result(timeout=120.0)
        done += 1
        if i < 3:
            print(f"  req {i}: actions {res['actions']} "
                  f"value {res['value']:.3f} policy v{res['policy_version']}")
    wall = time.perf_counter() - t0
    print(f"\nserved {done}/{args.requests} requests in {wall:.2f}s "
          f"({done/wall:.1f} req/s)")
    print(f"batches run: {service.batches_run} "
          f"(mean batch {done/max(service.batches_run,1):.1f}, "
          f"padded slots {service.padded_slots}) | "
          f"weight swaps seen: {service.weight_swaps}")
    service.stop()


if __name__ == "__main__":
    main()
