"""Chunked Mamba2 SSD scan as a Pallas TPU kernel (DESIGN.md §7).

The SSD dual form splits the recurrence into MXU-friendly intra-chunk
matmuls and a tiny inter-chunk state recurrence. TPU mapping:

  * grid = (batch, heads, chunks); chunks are the LAST (sequential) axis so
    the running state S [P, N] persists in VMEM scratch across chunk steps;
  * per chunk, the [q, q] decay-masked attention-like matrix and the
    [q, P/N] tiles are dense dots on the MXU;
  * everything is fp32 inside the kernel (the state recurrence is
    numerically delicate); inputs may be bf16.

Matches ``ref.reference_ssd`` (the stepwise linear-form oracle) — the SSD
"duality" is exactly what the allclose test asserts.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import _vmem


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_final_ref,
                state_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)            # [q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)             # [q]
    a = a_ref[0]                                         # scalar (negative)
    bm = b_ref[0, :, :].astype(jnp.float32)              # [q, N]
    cm = c_ref[0, :, :].astype(jnp.float32)              # [q, N]

    dA = dt * a                                          # [q] (<= 0)
    cum = jnp.cumsum(dA)                                 # inclusive
    cum_total = cum[-1]

    # intra-chunk: y[i] = Σ_{j<=i} (C_i·B_j) exp(cum_i − cum_j) dt_j x_j
    q = chunk
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    g = jnp.where(jj <= ii, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)   # [q, q]
    w = cb * g * dt[None, :]
    y = jnp.dot(w, x, preferred_element_type=jnp.float32)        # [q, P]

    # inter-chunk: y[i] += exp(cum_i) · C_i · S_enterᵀ
    state = state_scr[...]                                       # [P, N]
    y += jnp.exp(cum)[:, None] * jnp.dot(
        cm, state.T, preferred_element_type=jnp.float32)

    # state update: S ← exp(cum_total)·S + Σ_j exp(cum_total−cum_j) dt_j x_j B_jᵀ
    decay_in = jnp.exp(cum_total - cum) * dt                     # [q]
    s_new = jnp.exp(cum_total) * state + jnp.dot(
        (x * decay_in[:, None]).T, bm, preferred_element_type=jnp.float32)
    state_scr[...] = s_new

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        s_final_ref[0, 0, :, :] = s_new.astype(s_final_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 128,
             interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B,T,H,P]; dt: [B,T,H]; A: [H]; Bm/Cm: [B,T,N] (single group).

    Returns (y [B,T,H,P] f32, final_state [B,H,P,N] f32); T % chunk == 0.
    """
    b, t, h, p = x.shape
    n = Bm.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    grid = (b, h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, s_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)
    return y, s_final
