"""Chunked Mamba2 SSD scan as a Pallas TPU kernel (DESIGN.md §7).

The SSD dual form splits the recurrence into MXU-friendly intra-chunk
matmuls and a tiny inter-chunk state recurrence. TPU mapping:

  * grid = (batch, heads, chunks); chunks are the LAST (sequential) axis so
    the running state S [P, N] persists in VMEM scratch across chunk steps;
  * per chunk, the [q, q] decay-masked attention-like matrix and the
    [q, P/N] tiles are dense dots on the MXU;
  * everything is fp32 inside the kernel (the state recurrence is
    numerically delicate); inputs may be bf16.

Matches ``ref.reference_ssd`` (the stepwise linear-form oracle) — the SSD
"duality" is exactly what the allclose test asserts.

The BACKWARD is a real Pallas kernel as well: the forward optionally saves
each chunk's *entering* state (``return_states``), and ``ssd_scan_bwd``
walks the chunks in REVERSE (index map ``nc - 1 - ci``) carrying the
state cotangent dS in VMEM scratch, with heads innermost so the
head-summed dB/dC output blocks are revisited consecutively. dA comes out
as per-(batch, chunk, head) partials summed by the wrapper.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import _vmem


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_final_ref,
                *refs, chunk: int, save_states: bool):
    if save_states:
        s_all_ref, state_scr = refs
    else:
        (state_scr,) = refs
        s_all_ref = None
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)            # [q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)             # [q]
    a = a_ref[0]                                         # scalar (negative)
    bm = b_ref[0, :, :].astype(jnp.float32)              # [q, N]
    cm = c_ref[0, :, :].astype(jnp.float32)              # [q, N]

    dA = dt * a                                          # [q] (<= 0)
    cum = jnp.cumsum(dA)                                 # inclusive
    cum_total = cum[-1]

    # intra-chunk: y[i] = Σ_{j<=i} (C_i·B_j) exp(cum_i − cum_j) dt_j x_j
    q = chunk
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    g = jnp.where(jj <= ii, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)   # [q, q]
    w = cb * g * dt[None, :]
    y = jnp.dot(w, x, preferred_element_type=jnp.float32)        # [q, P]

    # inter-chunk: y[i] += exp(cum_i) · C_i · S_enterᵀ
    state = state_scr[...]                                       # [P, N]
    if save_states:
        # the chunk's ENTERING state — the residual the backward kernel
        # replays this chunk's forward from
        s_all_ref[0, 0, 0] = state
    y += jnp.exp(cum)[:, None] * jnp.dot(
        cm, state.T, preferred_element_type=jnp.float32)

    # state update: S ← exp(cum_total)·S + Σ_j exp(cum_total−cum_j) dt_j x_j B_jᵀ
    decay_in = jnp.exp(cum_total - cum) * dt                     # [q]
    s_new = jnp.exp(cum_total) * state + jnp.dot(
        (x * decay_in[:, None]).T, bm, preferred_element_type=jnp.float32)
    state_scr[...] = s_new

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        s_final_ref[0, 0, :, :] = s_new.astype(s_final_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 128,
             interpret: bool = False, return_states: bool = False):
    """x: [B,T,H,P]; dt: [B,T,H]; A: [H]; Bm/Cm: [B,T,N] (single group).

    Returns (y [B,T,H,P] f32, final_state [B,H,P,N] f32); T % chunk == 0.
    ``return_states`` additionally returns every chunk's entering state
    [B, NC, H, P, N] f32 — the residual ``ssd_scan_bwd`` needs.
    """
    b, t, h, p = x.shape
    n = Bm.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk

    grid = (b, h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk,
                               save_states=return_states)
    out_specs = [
        pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
        pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, t, h, p), jnp.float32),
        jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
    ]
    if return_states:
        out_specs.append(pl.BlockSpec(
            (1, 1, 1, p, n), lambda bi, hi, ci: (bi, ci, hi, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((b, nc, h, p, n),
                                              jnp.float32))
    got = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[_vmem((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)
    return tuple(got) if return_states else (got[0], got[1])


# ---------------------------------------------------------------------------
# Backward: reverse-chunk kernel carrying the state cotangent in scratch
# ---------------------------------------------------------------------------

def _ssd_bwd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, senter_ref, dy_ref,
                    dsfin_ref, dx_ref, ddt_ref, da_ref, db_ref, dc_ref,
                    ds_scr, *, chunk: int):
    """One (batch, chunk, head) step of the reverse sweep.

    Grid = (b, nc, h) with heads INNERMOST: dB/dC accumulate across heads,
    so their (batch, chunk) output block must be revisited on consecutive
    sequential steps. Chunks run reversed via the ``nc - 1 - ci`` index
    maps; the per-head state cotangent dS lives in ``ds_scr[h]`` across
    chunk steps. All forward intra-chunk quantities are recomputed in f32
    from the saved inputs + the chunk's entering state.
    """
    ci = pl.program_id(1)
    hi = pl.program_id(2)

    @pl.when(ci == 0)
    def _seed():
        # reverse sweep starts at the LAST chunk: seed with the final
        # state's cotangent
        ds_scr[hi] = dsfin_ref[0, 0]

    x = x_ref[0, :, 0, :].astype(jnp.float32)            # [q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)             # [q]
    a = a_ref[0]
    bm = b_ref[0].astype(jnp.float32)                    # [q, N]
    cm = c_ref[0].astype(jnp.float32)                    # [q, N]
    S = senter_ref[0, 0, 0]                              # [P, N] entering
    dy = dy_ref[0, :, 0, :].astype(jnp.float32)          # [q, P]
    M = ds_scr[hi]                                       # [P, N] dS_out

    q = chunk
    dAv = dt * a
    cum = jnp.cumsum(dAv)
    ct = cum[-1]
    e = jnp.exp(cum)                                     # [q]
    decay_out = jnp.exp(ct - cum)                        # [q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    g = jnp.where(jj <= ii, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)
    w = cb * g * dt[None, :]

    # --- intra-chunk path: y = W·x -------------------------------------------
    dw = jnp.dot(dy, x.T, preferred_element_type=jnp.float32)    # [q, q]
    dx = jnp.dot(w.T, dy, preferred_element_type=jnp.float32)    # [q, P]
    dcb = dw * g * dt[None, :]
    dcm = jnp.dot(dcb, bm, preferred_element_type=jnp.float32)
    dbm = jnp.dot(dcb.T, cm, preferred_element_type=jnp.float32)
    ddt = (dw * cb * g).sum(0)                                   # [q]

    # --- state-output path: S_out = e^ct·S + (x ∘ decay_out·dt)ᵀ·B ----------
    xm = jnp.dot(x, M, preferred_element_type=jnp.float32)       # [q, N]
    dx += (decay_out * dt)[:, None] * jnp.dot(
        bm, M.T, preferred_element_type=jnp.float32)
    dbm += (decay_out * dt)[:, None] * xm
    di = (xm * bm).sum(-1)                       # [q] d(decay_in = e^{ct-c}dt)
    ddt += di * decay_out

    # --- inter-chunk y path: y += e ∘ (C·S_enterᵀ) ---------------------------
    cs = jnp.dot(cm, S.T, preferred_element_type=jnp.float32)    # [q, P]
    dcm += e[:, None] * jnp.dot(dy, S, preferred_element_type=jnp.float32)

    # --- cum / ct cotangents -------------------------------------------------
    gg = dw * cb * dt[None, :] * g               # dG ∘ G (i, j)
    dcum = gg.sum(1) - gg.sum(0)                 # +row(i), −col(j)
    dcum += (dy * cs).sum(-1) * e                # e_i = exp(cum_i)
    dcum -= di * decay_out * dt                  # exp(ct − cum_j) direct
    dct = (di * decay_out * dt).sum()
    dct += jnp.exp(ct) * (M * S).sum()           # e^ct·S in S_out
    last = jax.lax.broadcasted_iota(jnp.int32, (q,), 0) == q - 1
    dcum += jnp.where(last, dct, 0.0)            # ct = cum[q-1]
    # cum = cumsum(dA)  ⇒  ddA_j = Σ_{i≥j} dcum_i (reverse cumsum)
    dda = dcum.sum() - jnp.cumsum(dcum) + dcum
    ddt += dda * a
    da = (dda * dt).sum()

    # --- carry to the previous chunk ----------------------------------------
    ds_scr[hi] = jnp.exp(ct) * M + jnp.dot(
        (dy * e[:, None]).T, cm, preferred_element_type=jnp.float32)

    dx_ref[0, :, 0, :] = dx
    ddt_ref[0, :, 0] = ddt
    da_ref[0, 0, 0] = da

    @pl.when(hi == 0)
    def _first_head():
        db_ref[0] = dbm
        dc_ref[0] = dcm

    @pl.when(hi != 0)
    def _other_heads():
        db_ref[0] += dbm
        dc_ref[0] += dcm


def ssd_scan_bwd(x, dt, A, Bm, Cm, s_enter, dy, ds_final, *,
                 chunk: int = 128, interpret: bool = False):
    """Gradients (dx, ddt, dA, dBm, dCm) of ``ssd_scan``.

    Inputs as the forward, plus ``s_enter`` [B,NC,H,P,N] from
    ``ssd_scan(..., return_states=True)`` and the output cotangents
    (dy [B,T,H,P], ds_final [B,H,P,N]). One reverse pallas sweep — no
    forward recompute.
    """
    b, t, h, p = x.shape
    n = Bm.shape[-1]
    nc = t // chunk
    rev = lambda ci: nc - 1 - ci     # noqa: E731 - reversed chunk order

    grid = (b, nc, h)
    dx, ddt, da_part, dbm, dcm = pl.pallas_call(
        functools.partial(_ssd_bwd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, ci, hi: (bi, rev(ci), hi, 0)),
            pl.BlockSpec((1, chunk, 1),
                         lambda bi, ci, hi: (bi, rev(ci), hi)),
            pl.BlockSpec((1,), lambda bi, ci, hi: (hi,)),
            pl.BlockSpec((1, chunk, n),
                         lambda bi, ci, hi: (bi, rev(ci), 0)),
            pl.BlockSpec((1, chunk, n),
                         lambda bi, ci, hi: (bi, rev(ci), 0)),
            pl.BlockSpec((1, 1, 1, p, n),
                         lambda bi, ci, hi: (bi, rev(ci), hi, 0, 0)),
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, ci, hi: (bi, rev(ci), hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, ci, hi: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, ci, hi: (bi, rev(ci), hi, 0)),
            pl.BlockSpec((1, chunk, 1),
                         lambda bi, ci, hi: (bi, rev(ci), hi)),
            pl.BlockSpec((1, 1, 1), lambda bi, ci, hi: (bi, rev(ci), hi)),
            pl.BlockSpec((1, chunk, n),
                         lambda bi, ci, hi: (bi, rev(ci), 0)),
            pl.BlockSpec((1, chunk, n),
                         lambda bi, ci, hi: (bi, rev(ci), 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, t, h), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h), jnp.float32),
            jax.ShapeDtypeStruct((b, t, n), jnp.float32),
            jax.ShapeDtypeStruct((b, t, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((h, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm, s_enter,
      dy.astype(jnp.float32), ds_final.astype(jnp.float32))
    # per-(b, chunk, head) dA partials fold to [H] outside the kernel
    da = da_part.sum(axis=(0, 1))
    return (dx.astype(x.dtype), ddt.astype(dt.dtype), da.astype(A.dtype),
            dbm.astype(Bm.dtype), dcm.astype(Cm.dtype))
