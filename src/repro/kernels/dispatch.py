"""Kernel dispatch: route hot ops to Pallas on TPU, to jnp twins elsewhere.

Every hot op in the stack has two implementations with identical semantics:
a Pallas kernel (``flash_attention``, ``gipo_loss``, ``fused_policy_loss``)
that lowers to Mosaic on TPU, and a streaming pure-jnp twin that XLA
compiles well on CPU/GPU. This module picks between them at trace time.

Mode resolution (first match wins):

  1. ``set_mode(...)`` / the ``forced(...)`` context manager (tests),
  2. the ``REPRO_KERNELS`` environment variable,
  3. the ``mode`` argument threaded from config (``RLConfig.kernel_dispatch``),
  4. ``"auto"``: Pallas iff ``jax.default_backend() == "tpu"`` — the same
     rule as ``ops._auto_interpret``.

Modes: ``"auto"`` | ``"pallas"`` | ``"jnp"``. Forcing ``"pallas"`` off-TPU
runs the kernels in interpret mode (slow — correctness testing only).

Note the decision is taken at *trace* time: flipping the env var does not
retrigger tracing of an already-jitted train step.
"""
from __future__ import annotations

import contextlib
import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import gipo_loss as _gl
from repro.kernels.flash_attention import flash_attention

_MODE_ENV = "REPRO_KERNELS"
_MODES = ("auto", "pallas", "jnp")
_override: Optional[str] = None


def set_mode(mode: Optional[str]) -> None:
    """Process-wide override; ``None`` restores env/auto resolution."""
    global _override
    if mode is not None and mode not in _MODES:
        raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
    _override = mode


@contextlib.contextmanager
def forced(mode: str):
    """Temporarily force a dispatch mode (tests)."""
    prev = _override
    set_mode(mode)
    try:
        yield
    finally:
        set_mode(prev)


def resolve_mode(mode: Optional[str] = None) -> str:
    if _override is not None:
        return _override
    env = os.environ.get(_MODE_ENV)
    if env:
        if env not in _MODES:
            raise ValueError(f"{_MODE_ENV} must be one of {_MODES}, "
                             f"got {env!r}")
        return env
    if mode is not None:
        if mode not in _MODES:
            raise ValueError(f"dispatch mode must be one of {_MODES}, "
                             f"got {mode!r}")
        return mode
    return "auto"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_pallas(mode: Optional[str] = None) -> bool:
    m = resolve_mode(mode)
    return m == "pallas" or (m == "auto" and _on_tpu())


def interpret_mode() -> bool:
    """Whether a dispatched ``pallas_call`` should run in interpret mode
    (mirrors ``ops._auto_interpret(None)``)."""
    return not _on_tpu()


# ---------------------------------------------------------------------------
# Streaming jnp twins (share the block math with the Pallas kernels)
# ---------------------------------------------------------------------------

def _scan_blocks(body, operands, block_n: int):
    """Pad leading axes to ``block_n``, reshape to [nb, block_n, ...] and
    scan ``body`` over blocks accumulating the 8-column partial sums. The
    body is checkpointed so the backward re-streams blocks instead of
    saving per-block softmax residuals."""
    padded = _gl._pad_rows(block_n, *operands)
    nb = padded[0].shape[0] // block_n
    blocks = tuple(a.reshape((nb, block_n) + a.shape[1:]) for a in padded)

    def step(acc, blk):
        return acc + body(*blk), None

    sums, _ = jax.lax.scan(jax.checkpoint(step),
                           jnp.zeros((_gl.N_COLS,), jnp.float32), blocks)
    return sums


def _jnp_gipo_loss(logits, targets, logp_old, advantages, mask, sigma,
                   block_n):
    def body(lg, tg, lo, ad, mk):
        return _gl._fwd_partials(lg.astype(jnp.float32), tg, lo, ad, mk,
                                 sigma, sg=jax.lax.stop_gradient)
    sums = _scan_blocks(body, (logits, targets, logp_old, advantages, mask),
                        block_n)
    return _gl._finalize(sums)


def _jnp_policy_loss(hidden, w, targets, logp_old, advantages, mask, sigma,
                     block_n):
    def body(h, tg, lo, ad, mk):
        logits = jnp.dot(h, w, preferred_element_type=jnp.float32)
        return _gl._fwd_partials(logits, tg, lo, ad, mk, sigma,
                                 sg=jax.lax.stop_gradient)
    sums = _scan_blocks(body, (hidden, targets, logp_old, advantages, mask),
                        block_n)
    return _gl._finalize(sums)


# ---------------------------------------------------------------------------
# Dispatched ops
# ---------------------------------------------------------------------------

PALLAS_BLOCK_N = 256    # VMEM-sized token block for the TPU kernels
TWIN_BLOCK_N = 1024     # larger blocks amortize scan overhead on CPU/GPU


def loss_block_n(mode: Optional[str] = None) -> int:
    return PALLAS_BLOCK_N if use_pallas(mode) else TWIN_BLOCK_N


def gipo_loss(logits, targets, logp_old, advantages, mask, *, sigma: float,
              block_n: Optional[int] = None, mode: Optional[str] = None):
    """Logits-level fused GIPO/entropy/KL -> (pg, entropy, kl, metrics)."""
    block_n = block_n or loss_block_n(mode)
    if use_pallas(mode):
        return _gl.gipo_head_loss(logits, targets, logp_old, advantages,
                                  mask, sigma, block_n, interpret_mode())
    return _jnp_gipo_loss(logits, targets, logp_old, advantages, mask,
                          sigma, block_n)


def policy_head_loss(hidden, w, targets, logp_old, advantages, mask, *,
                     sigma: float, block_n: Optional[int] = None,
                     mode: Optional[str] = None):
    """Hidden-level fused action head + GIPO/entropy/KL loss.

    hidden: [N, d]; w: [d, Va]; rest [N]. Both routes stream token blocks
    and never materialize an [N, Va] softmax intermediate — the Pallas path
    via the custom-VJP kernels, the jnp path via a checkpointed block scan.
    """
    block_n = block_n or loss_block_n(mode)
    if use_pallas(mode):
        return _gl.fused_policy_loss(hidden, w, targets, logp_old,
                                     advantages, mask, sigma, block_n,
                                     interpret_mode())
    return _jnp_policy_loss(hidden, w, targets, logp_old, advantages, mask,
                            sigma, block_n)


# ---------------------------------------------------------------------------
# Attention: Pallas flash forward + Pallas flash backward (LSE residual)
# ---------------------------------------------------------------------------

def _attn_pallas_ok(head_dim: int) -> bool:
    """On a real TPU the flash kernel wants MXU-aligned head dims; the jnp
    twin handles the rest. Interpret mode (CPU) takes any shape."""
    if interpret_mode():
        return True
    return head_dim % 128 == 0


def _twin_attention(q, k, v, window, block, unroll=False):
    from repro.models.attention import _blockwise_attn
    scale = q.shape[-1] ** -0.5
    return _blockwise_attn(q, k, v, scale, window=window, block=block,
                           unroll=unroll).astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_with_twin_bwd(q, k, v, window, block_q, block_k, interpret):
    return flash_attention(q, k, v, causal=True, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)


def _flash_fwd(q, k, v, window, block_q, block_k, interpret):
    # differentiated forward saves the online-softmax LSE so the backward
    # kernels replay p = exp(s - LSE) instead of recomputing the softmax
    out, lse = flash_attention(q, k, v, causal=True, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret, return_lse=True)
    return out, (q, k, v, out, lse)


def _flash_bwd(window, block_q, block_k, interpret, res, g):
    # Backward = the real Pallas dq and dk/dv kernels over the saved LSE
    # (recompute-free; see kernels/flash_attention.py).
    from repro.kernels.flash_attention import flash_attention_bwd
    q, k, v, out, lse = res
    return flash_attention_bwd(q, k, v, out, lse, g, causal=True,
                               window=window, block_q=block_q,
                               block_k=block_k, interpret=interpret)


_flash_with_twin_bwd.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# SSD scan (Mamba2): Pallas chunked forward + Pallas reverse-sweep backward
# ---------------------------------------------------------------------------

def _twin_ssd(x, dt, A, Bm, Cm, chunk):
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, Bm, Cm, chunk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd_with_twin_bwd(x, dt, A, Bm, Cm, chunk, interpret):
    from repro.kernels.ssd_scan import ssd_scan as _pallas_ssd
    return _pallas_ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


def _ssd_fwd(x, dt, A, Bm, Cm, chunk, interpret):
    # differentiated forward saves every chunk's ENTERING state so the
    # backward sweep replays each chunk without rerunning the recurrence
    from repro.kernels.ssd_scan import ssd_scan as _pallas_ssd
    y, s_final, s_enter = _pallas_ssd(x, dt, A, Bm, Cm, chunk=chunk,
                                      interpret=interpret,
                                      return_states=True)
    return (y, s_final), (x, dt, A, Bm, Cm, s_enter)


def _ssd_bwd(chunk, interpret, res, g):
    # Backward = the real Pallas reverse-chunk kernel carrying the state
    # cotangent in scratch (see kernels/ssd_scan.py).
    from repro.kernels.ssd_scan import ssd_scan_bwd
    x, dt, A, Bm, Cm, s_enter = res
    dy, ds_final = g
    return ssd_scan_bwd(x, dt, A, Bm, Cm, s_enter, dy, ds_final,
                        chunk=chunk, interpret=interpret)


_ssd_with_twin_bwd.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128,
             mode: Optional[str] = None):
    """Chunked Mamba2 SSD scan. x: [B,T,H,P]; dt: [B,T,H] (f32,
    post-softplus); A: [H] (negative); Bm/Cm: [B,T,N] (single group).
    Returns (y [B,T,H,P] f32, final_state [B,H,P,N] f32).

    Routes to the Pallas kernel when enabled and shape-eligible (the
    kernel wants T an exact multiple of ``chunk``; ragged lengths and
    decode-time carried state stay on the jnp path). Backward on the
    Pallas route is the reverse-chunk Pallas kernel replaying saved
    entering states (``ssd_scan_bwd``); the jnp path uses its own VJP.
    """
    t = x.shape[1]
    if use_pallas(mode) and t >= chunk and t % chunk == 0:
        return _ssd_with_twin_bwd(x, dt, A, Bm, Cm, chunk, interpret_mode())
    return _twin_ssd(x, dt, A, Bm, Cm, chunk)


# ---------------------------------------------------------------------------
# Attention routing
# ---------------------------------------------------------------------------

def attention(q, k, v, *, window: Optional[int] = None, block: int = 128,
              unroll: bool = False, mode: Optional[str] = None):
    """Causal (optionally sliding-window) blockwise attention on projected
    q/k/v. q: [B,T,H,D]; k/v: [B,S,KV,D] -> [B,T,H,D] in q.dtype.

    Routes to the Pallas flash kernel when enabled and shape-eligible;
    its backward is the pair of Pallas dq and dk/dv kernels over the
    saved online-softmax LSE (recompute-free — no O(T²) score tensor
    either way). Otherwise the jnp twin runs both ways.
    """
    if use_pallas(mode) and _attn_pallas_ok(q.shape[-1]):
        return _flash_with_twin_bwd(q, k, v, window, block, block,
                                    interpret_mode())
    return _twin_attention(q, k, v, window, block, unroll)


# ---------------------------------------------------------------------------
# Decode-path routing: single-token decode + the dense small-T fallback
# ---------------------------------------------------------------------------

def _twin_dense(q, k, v, window):
    """Dense causal attention — the exact math of the historical inline
    small-T path in ``models.attention`` (f32 scores + additive causal
    mask + softmax cast to q.dtype before the value combine)."""
    from repro.models.attention import (_gqa_combine, _gqa_scores,
                                        causal_mask)
    t = q.shape[1]
    scale = q.shape[-1] ** -0.5
    scores = _gqa_scores(q, k) * scale
    scores = scores + causal_mask(t, window)[None, None]
    weights = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_combine(weights, v)


def dense_attention(q, k, v, *, window: Optional[int] = None,
                    block: int = 128, mode: Optional[str] = None):
    """Dense small-T causal attention (T == S, no KV cache): the fallback
    the blockwise path skips when the whole sequence fits one block.
    q: [B,T,H,D]; k/v: [B,T,KV,D] -> [B,T,H,D] in q.dtype.

    Pallas route: the flash kernel (it pads T up to one block tile
    internally, so a 17-token prompt still runs as a single MXU tile);
    differentiable through the twin-VJP wrapper like ``attention``.
    """
    if use_pallas(mode) and _attn_pallas_ok(q.shape[-1]):
        return _flash_with_twin_bwd(q, k, v, window, block, block,
                                    interpret_mode())
    return _twin_dense(q, k, v, window)


def _twin_decode(q, k, v, valid):
    """Single-token decode over a (possibly ring-layout) KV cache — the
    exact math of the historical inline path in ``attention_decode``."""
    from repro.models.attention import NEG_INF, _gqa_combine, _gqa_scores
    scale = q.shape[-1] ** -0.5
    scores = _gqa_scores(q, k) * scale                    # [B,H,1,S]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_combine(weights, v)


def decode_attention(q, k, v, valid, *, mode: Optional[str] = None):
    """Single-token attention decode. q: [B,1,H,D]; k/v: [B,S,KV,D];
    valid: [B,S] bool (cache slots this token may attend to — empty ring
    slots, out-of-window and future positions already excluded)
    -> [B,1,H,D] in q.dtype.

    Validity is data-dependent (ring caches overwrite slots out of
    order), so the Pallas route carries it as an additive bias instead of
    deriving a mask from grid positions. Inference-only — no VJP wrapper.
    """
    if use_pallas(mode) and _attn_pallas_ok(q.shape[-1]):
        from repro.kernels.decode_attention import (
            decode_attention as _pallas_decode)
        from repro.models.attention import NEG_INF
        bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
        return _pallas_decode(q, k, v, bias, interpret=interpret_mode())
    return _twin_decode(q, k, v, valid)
