"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def reference_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jnp.ndarray:
    """Dense GQA attention. q: [B,T,H,D]; k/v: [B,S,KV,D] -> [B,T,H,D]."""
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    group = h // kv
    qg = q.reshape(b, t, kv, group, d).astype(jnp.float32)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg,
                        k.astype(jnp.float32)) * (d ** -0.5)
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", w, v.astype(jnp.float32))
    return out.reshape(b, t, h, d).astype(q.dtype)


def reference_gipo_loss(logits: jnp.ndarray, targets: jnp.ndarray,
                        logp_old: jnp.ndarray, advantages: jnp.ndarray,
                        mask: jnp.ndarray, sigma: float
                        ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Unfused token-level GIPO (eqs. 5–6). logits: [N, V]; rest [N]."""
    logp_all = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    logp_new = jnp.take_along_axis(logp_all, targets[:, None],
                                   axis=-1)[:, 0]
    log_ratio = logp_new - logp_old
    ratio = jnp.exp(log_ratio)
    omega = jnp.exp(-0.5 * jnp.square(
        jax.lax.stop_gradient(log_ratio) / sigma))
    per_token = -(omega * ratio * advantages)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = jnp.sum(per_token * mask) / denom
    metrics = {
        "ratio_mean": jnp.sum(ratio * mask) / denom,
        "omega_mean": jnp.sum(omega * mask) / denom,
    }
    return loss, metrics


def reference_policy_loss(hidden: jnp.ndarray, w: jnp.ndarray,
                          targets: jnp.ndarray, logp_old: jnp.ndarray,
                          advantages: jnp.ndarray, mask: jnp.ndarray,
                          sigma: float):
    """Unfused action head + GIPO/entropy/KL oracle for the fused kernels.

    hidden: [N, d]; w: [d, Va]; rest [N]. Materializes the full [N, Va]
    log-softmax (the thing the fused path avoids). Returns
    ``(pg, entropy, kl, metrics)`` and is differentiable by plain autodiff
    — the grad-parity target for the custom-VJP kernels.
    """
    logits = (hidden @ w).astype(jnp.float32)
    logp_all = jax.nn.log_softmax(logits, axis=-1)
    logp_new = jnp.take_along_axis(logp_all, targets[:, None], axis=-1)[:, 0]
    log_ratio = logp_new - logp_old
    ratio = jnp.exp(log_ratio)
    lr_sg = jax.lax.stop_gradient(log_ratio)
    omega = jnp.exp(-0.5 * jnp.square(lr_sg / sigma))
    pg_tok = -(omega * ratio * advantages)
    ent_tok = -jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1)
    k3_tok = jnp.expm1(-log_ratio) + log_ratio
    denom = jnp.maximum(mask.sum(), 1.0)
    pg = jnp.sum(pg_tok * mask) / denom
    ent = jnp.sum(ent_tok * mask) / denom
    kl = jnp.sum(k3_tok * mask) / denom
    metrics = {
        "ratio_mean": jnp.sum(ratio * mask) / denom,
        "omega_mean": jnp.sum(omega * mask) / denom,
        "stale_frac": jnp.sum((jnp.abs(lr_sg) > 2 * sigma) * mask) / denom,
    }
    return pg, ent, kl, metrics


def reference_ssd(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                  Bm: jnp.ndarray, Cm: jnp.ndarray,
                  init_state: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stepwise SSD recurrence oracle (the "linear form" of SSD duality).

    x: [B,T,H,P]; dt: [B,T,H] (post-softplus); A: [H] (negative);
    Bm/Cm: [B,T,N]. Returns (y [B,T,H,P] f32, final state [B,H,P,N] f32).
    """
    b, t, h, p = x.shape
    n = Bm.shape[-1]
    state = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
             else init_state.astype(jnp.float32))

    def step(state, inputs):
        x_t, dt_t, b_t, c_t = inputs
        dA = jnp.exp(dt_t * A[None, :])                     # [B,H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t,
                         x_t.astype(jnp.float32), b_t.astype(jnp.float32))
        state = dA[:, :, None, None] * state + upd
        y = jnp.einsum("bhpn,bn->bhp", state, c_t.astype(jnp.float32))
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state
