"""Single-token decode attention as a Pallas TPU kernel.

The inference plane's hot loop is the autoregressive decode inside
``sample_action_sequence``: one new query token per sequence attending
over the KV cache. Unlike prefill, validity is *data-dependent* — ring
slots may be empty (position -1), out of the sliding window, or ahead of
the sequence (cache rows written by longer sequences in the batch) — so
the mask arrives as a precomputed additive bias instead of being derived
from grid positions:

  * grid = (batch, q-heads, kv-blocks); the LAST axis is sequential on
    TPU, so the online-softmax state (m, l, acc) lives in VMEM scratch
    across kv-block steps and is finalized on the last step (same shape
    as ``flash_attention``, with a 1-row query tile);
  * GQA maps each q-head grid index to its kv head (h // group) in the
    K/V index maps — no KV duplication in HBM;
  * ``bias``: [B, S] f32, 0 where the cache slot is attendable and
    ``NEG_INF`` where it is not; cache padding to the block multiple is
    masked the same way.

Validated in interpret mode against the dense jnp decode path; on real
TPUs the same ``pl.pallas_call`` lowers to Mosaic.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import _vmem

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float):
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]                              # [1, D]
    k = k_ref[0, :, 0, :]                              # [bk, D]
    v = v_ref[0, :, 0, :]                              # [bk, D]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    s = s + bias_ref[...]                              # [1, bk]

    m_prev = m_scr[...]                                # [1, 1]
    m_new = jnp.maximum(m_prev[:, 0], s.max(axis=-1))[:, None]
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                             # [1, bk]
    l_new = l_scr[...] * corr + p.sum(axis=-1)[:, None]
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _final():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     bias: jnp.ndarray, *, block_k: int = 128,
                     interpret: bool = False) -> jnp.ndarray:
    """q: [B, 1, H, D]; k/v: [B, S, KV, D]; bias: [B, S] f32 additive
    (0 attendable / NEG_INF masked) → [B, 1, H, D] in q.dtype."""
    b, t, h, d = q.shape
    assert t == 1, f"decode kernel wants one query token, got T={t}"
    s, kv = k.shape[1], k.shape[2]
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = d ** -0.5

    sp = math.ceil(s / block_k) * block_k
    if sp != s:
        k = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, sp - s)),
                       constant_values=NEG_INF)
    bias = bias.astype(jnp.float32)

    grid = (b, h, sp // block_k)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bi, hi, kj: (bi, 0, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, kj, g=group: (bi, kj, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, kj, g=group: (bi, kj, hi // g, 0)),
            pl.BlockSpec((1, block_k), lambda bi, hi, kj: (bi, kj)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda bi, hi, kj: (bi, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1, h, d), q.dtype),
        scratch_shapes=[
            _vmem((1, 1), jnp.float32),        # running max m
            _vmem((1, 1), jnp.float32),        # running sum l
            _vmem((1, d), jnp.float32),        # accumulator
        ],
        interpret=interpret,
    )(q, k, v, bias)
    return out
