"""Fused token-level GIPO loss as Pallas TPU kernels (DESIGN.md §7).

The naive objective touches the [N, V_action] logit tensor three times
(log-softmax, gather, ratio product) and twice more for the entropy bonus
and KL penalty. The kernels stream token blocks through VMEM once: per
block they fuse row-max → log-sum-exp → target gather → Gaussian trust
weight (eq. 5) → surrogate (eq. 6) → entropy → k3-KL → partial reductions,
emitting one 8-column partial row per block. The host-side wrapper sums
the partials — no [N, V] intermediate ever returns to HBM.

Two fusion levels:

  * ``gipo_head_loss``   — logits-level: consumes [N, V] logits. Custom
    VJP: an analytic backward kernel re-streams the same blocks and emits
    ``d_logits`` directly, so the backward never materializes a second
    [N, V] softmax intermediate (the block softmax lives only in VMEM).
  * ``fused_policy_loss`` — hidden-level: consumes [N, d] hidden states
    plus the slimmed action-head weight [d, Va] and computes the logits
    block *inside* the kernel. Forward and backward never write an
    [N, Va] tensor to HBM at all: the backward emits ``d_hidden`` per
    block and accumulates ``d_w`` across the sequential grid.

Gradients are defined w.r.t. logits (resp. hidden + head weight) only;
``targets``/``logp_old``/``advantages``/``mask`` are treated as constants,
matching the trainer where advantages are stop-gradient and the rest is
rollout data. Metric outputs are stop-gradiented explicitly.

The per-block math lives in plain-jnp helpers (``_fwd_partials``,
``_block_dlogits``) shared verbatim by the Pallas kernel bodies and by the
streaming jnp twins in ``repro.kernels.dispatch`` — one source of truth.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Column layout of the per-block partial sums (padded to 8 for layout):
#   0: Σ pg        1: Σ ratio   2: Σ omega   3: Σ mask (token count)
#   4: Σ entropy   5: Σ k3-KL   6: Σ stale   7: unused
N_COLS = 8


# ---------------------------------------------------------------------------
# Shared block math (pure jnp — used by kernels AND the jnp twins)
# ---------------------------------------------------------------------------

def _softmax_rows(logits32: jnp.ndarray, targets: jnp.ndarray):
    """Row-streamed log-softmax pieces. logits32: [bn, V] f32; targets [bn]."""
    row_max = jnp.max(logits32, axis=-1, keepdims=True)
    shifted = logits32 - row_max
    expsh = jnp.exp(shifted)
    sumexp = jnp.sum(expsh, axis=-1)
    lse = jnp.log(sumexp)
    bn, v = logits32.shape
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (bn, v), 1)
              == targets[:, None])
    tgt_shifted = jnp.sum(jnp.where(onehot, shifted, 0.0), axis=-1)
    logp_new = tgt_shifted - lse                       # [bn]
    p = expsh / sumexp[:, None]                        # [bn, V]
    logp = shifted - lse[:, None]                      # [bn, V]
    ent = -jnp.sum(p * logp, axis=-1)                  # [bn]
    return p, logp, onehot, logp_new, ent


def _fwd_partials(logits32, targets, logp_old, adv, mask, sigma: float,
                  sg=lambda x: x):
    """One block's 8-column partial sums (see N_COLS layout).

    ``sg``: stop-gradient hook for the trust weight's log-ratio (eq. 5).
    The Pallas kernels leave it as identity — their backward is analytic
    and already treats ω as constant; the autodiffed jnp twin must pass
    ``jax.lax.stop_gradient`` to get the same semantics.
    """
    _, _, _, logp_new, ent = _softmax_rows(logits32, targets)
    lr = logp_new - logp_old
    ratio = jnp.exp(lr)
    omega = jnp.exp(-0.5 * jnp.square(sg(lr) / sigma))  # eq. 5
    pg = -(omega * ratio * adv)                        # eq. 6
    k3 = jnp.expm1(-lr) + lr                           # k3 KL estimator
    stale = (jnp.abs(sg(lr)) > 2.0 * sigma).astype(jnp.float32)
    m = mask
    return jnp.stack([
        jnp.sum(pg * m), jnp.sum(ratio * m), jnp.sum(omega * m), jnp.sum(m),
        jnp.sum(ent * m), jnp.sum(k3 * m), jnp.sum(stale * m),
        jnp.zeros((), jnp.float32),
    ])


def _block_dlogits(logits32, targets, logp_old, adv, mask, sigma: float,
                   c_pg, c_kl, c_ent):
    """Analytic d_logits for one block, f32 [bn, V].

    c_* are upstream cotangents already divided by the global denominator.
    Derivation (per valid row, ∂logp_new/∂z_v = onehot_v − p_v):
      pg:  ∂(−ω ρ Â)/∂logp_new = −ω ρ Â        (ω is stop-gradient)
      kl:  ∂k3/∂logp_new       = 1 − e^{−log ρ}
      ent: ∂H/∂z_v             = −p_v (log p_v + H)
    """
    p, logp, onehot, logp_new, ent = _softmax_rows(logits32, targets)
    lr = logp_new - logp_old
    ratio = jnp.exp(lr)
    omega = jnp.exp(-0.5 * jnp.square(lr / sigma))
    g = (c_pg * (-(omega * ratio * adv))
         + c_kl * (1.0 - jnp.exp(-lr))) * mask         # [bn]
    d = g[:, None] * (onehot.astype(jnp.float32) - p)
    d += (c_ent * mask)[:, None] * (-(p * (logp + ent[:, None])))
    return d


def _finalize(sums: jnp.ndarray):
    """Partial-sum vector [8] -> (pg, entropy, kl, metrics).

    Metrics are diagnostics, not loss terms — stop-gradient them here so
    the autodiffed jnp twins match the custom-VJP kernels (whose backward
    ignores the metrics cotangents by construction)."""
    denom = jnp.maximum(sums[3], 1.0)
    pg = sums[0] / denom
    metrics = {"ratio_mean": sums[1] / denom,
               "omega_mean": sums[2] / denom,
               "stale_frac": sums[6] / denom}
    return (pg, sums[4] / denom, sums[5] / denom,
            jax.tree.map(jax.lax.stop_gradient, metrics))


def _pad_rows(block_n: int, *arrays):
    """Pad every array's leading axis to a multiple of ``block_n``."""
    n = arrays[0].shape[0]
    np_ = math.ceil(n / block_n) * block_n
    if np_ == n:
        return arrays
    pad = np_ - n
    return tuple(jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
                 for a in arrays)


def _row_spec(block_n: int, *trailing):
    return pl.BlockSpec((block_n,) + trailing, lambda i: (i,) + (0,) * len(trailing))


def _zero_mask_pad(i, block_n: int, valid_n: int, mask):
    rows = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    return jnp.where(rows < valid_n, mask, 0.0)


# ---------------------------------------------------------------------------
# Logits-level kernels
# ---------------------------------------------------------------------------

def _gipo_fwd_kernel(logits_ref, targets_ref, logp_old_ref, adv_ref, mask_ref,
                     out_ref, *, sigma: float, block_n: int, valid_n: int):
    i = pl.program_id(0)
    mask = _zero_mask_pad(i, block_n, valid_n, mask_ref[...])
    out_ref[0, :] = _fwd_partials(logits_ref[...].astype(jnp.float32),
                                  targets_ref[...], logp_old_ref[...],
                                  adv_ref[...], mask, sigma)


def _gipo_bwd_kernel(logits_ref, targets_ref, logp_old_ref, adv_ref, mask_ref,
                     coef_ref, dlogits_ref, *, sigma: float, block_n: int,
                     valid_n: int):
    i = pl.program_id(0)
    mask = _zero_mask_pad(i, block_n, valid_n, mask_ref[...])
    c = coef_ref[...]
    d = _block_dlogits(logits_ref[...].astype(jnp.float32), targets_ref[...],
                       logp_old_ref[...], adv_ref[...], mask, sigma,
                       c[0, 0], c[0, 1], c[0, 2])
    dlogits_ref[...] = d.astype(dlogits_ref.dtype)


def _gipo_fwd_call(logits, targets, logp_old, advantages, mask, sigma,
                   block_n, interpret):
    n, v = logits.shape
    logits, targets, logp_old, advantages, mask = _pad_rows(
        block_n, logits, targets, logp_old, advantages, mask)
    grid = (logits.shape[0] // block_n,)
    kernel = functools.partial(_gipo_fwd_kernel, sigma=sigma,
                               block_n=block_n, valid_n=n)
    partials = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, v), lambda i: (i, 0)),
            _row_spec(block_n), _row_spec(block_n), _row_spec(block_n),
            _row_spec(block_n),
        ],
        out_specs=pl.BlockSpec((1, N_COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], N_COLS), jnp.float32),
        interpret=interpret,
    )(logits, targets, logp_old, advantages, mask)
    return _finalize(partials.sum(axis=0))


def _gipo_bwd_call(logits, targets, logp_old, advantages, mask, sigma,
                   block_n, interpret, coefs):
    n, v = logits.shape
    dtype = logits.dtype
    logits, targets, logp_old, advantages, mask = _pad_rows(
        block_n, logits, targets, logp_old, advantages, mask)
    grid = (logits.shape[0] // block_n,)
    kernel = functools.partial(_gipo_bwd_kernel, sigma=sigma,
                               block_n=block_n, valid_n=n)
    d = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, v), lambda i: (i, 0)),
            _row_spec(block_n), _row_spec(block_n), _row_spec(block_n),
            _row_spec(block_n),
            pl.BlockSpec((1, N_COLS), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((logits.shape[0], v), dtype),
        interpret=interpret,
    )(logits, targets, logp_old, advantages, mask, coefs)
    return d[:n]


def _loss_coefs(mask, cts) -> jnp.ndarray:
    """Fold the (pg, ent, kl) cotangents and 1/denom into a (1, 8) row."""
    ct_pg, ct_ent, ct_kl, _ = cts
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    row = jnp.stack([ct_pg / denom, ct_kl / denom, ct_ent / denom,
                     *([jnp.zeros(())] * (N_COLS - 3))])
    return row[None, :].astype(jnp.float32)


def _int_zero(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _gipo_head_loss_vjp(logits, targets, logp_old, advantages, mask,
                        sigma, block_n, interpret):
    return _gipo_fwd_call(logits, targets, logp_old, advantages, mask,
                          sigma, block_n, interpret)


def _gipo_head_fwd(logits, targets, logp_old, advantages, mask,
                   sigma, block_n, interpret):
    out = _gipo_fwd_call(logits, targets, logp_old, advantages, mask,
                         sigma, block_n, interpret)
    return out, (logits, targets, logp_old, advantages, mask)


def _gipo_head_bwd(sigma, block_n, interpret, res, cts):
    logits, targets, logp_old, advantages, mask = res
    d = _gipo_bwd_call(logits, targets, logp_old, advantages, mask,
                       sigma, block_n, interpret, _loss_coefs(mask, cts))
    return (d, _int_zero(targets), jnp.zeros_like(logp_old),
            jnp.zeros_like(advantages), jnp.zeros_like(mask))


_gipo_head_loss_vjp.defvjp(_gipo_head_fwd, _gipo_head_bwd)


def gipo_head_loss(logits, targets, logp_old, advantages, mask,
                   sigma: float, block_n: int = 256,
                   interpret: bool = False):
    """Fused GIPO surrogate + entropy + k3-KL over [N, V] logits.

    Returns ``(pg_loss, entropy, kl, metrics)`` — all masked means over the
    N token rows. Differentiable w.r.t. ``logits`` via an analytic backward
    Pallas kernel (see module docstring for the constant-input convention).
    The metrics are explicitly stop-gradiented — the custom VJP only
    propagates the (pg, entropy, kl) cotangents.
    """
    pg, ent, kl, metrics = _gipo_head_loss_vjp(
        logits, targets, logp_old, advantages, mask, sigma, block_n,
        interpret)
    return pg, ent, kl, jax.tree.map(jax.lax.stop_gradient, metrics)


def gipo_loss_fused(logits: jnp.ndarray, targets: jnp.ndarray,
                    logp_old: jnp.ndarray, advantages: jnp.ndarray,
                    mask: jnp.ndarray, sigma: float, *,
                    block_n: int = 256,
                    interpret: bool = False
                    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """logits: [N, V]; targets/logp_old/advantages/mask: [N].

    Returns (scalar pg loss, metrics) matching ``ref.reference_gipo_loss``;
    differentiable w.r.t. ``logits`` (custom VJP, analytic backward kernel).
    """
    pg, ent, kl, metrics = gipo_head_loss(logits, targets, logp_old,
                                          advantages, mask, sigma, block_n,
                                          interpret)
    metrics = dict(metrics, entropy=ent, kl=kl)
    return pg, jax.tree.map(jax.lax.stop_gradient, metrics)


# ---------------------------------------------------------------------------
# Hidden-level kernels: the action-head matmul fused into the loss
# ---------------------------------------------------------------------------

def _policy_fwd_kernel(hidden_ref, w_ref, targets_ref, logp_old_ref, adv_ref,
                       mask_ref, out_ref, *, sigma: float, block_n: int,
                       valid_n: int):
    i = pl.program_id(0)
    logits = jnp.dot(hidden_ref[...], w_ref[...],
                     preferred_element_type=jnp.float32)   # [bn, Va] f32
    mask = _zero_mask_pad(i, block_n, valid_n, mask_ref[...])
    out_ref[0, :] = _fwd_partials(logits, targets_ref[...], logp_old_ref[...],
                                  adv_ref[...], mask, sigma)


def _policy_bwd_kernel(hidden_ref, w_ref, targets_ref, logp_old_ref, adv_ref,
                       mask_ref, coef_ref, dh_ref, dw_ref, *, sigma: float,
                       block_n: int, valid_n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    h = hidden_ref[...]
    w32 = w_ref[...].astype(jnp.float32)
    logits = jnp.dot(h, w_ref[...], preferred_element_type=jnp.float32)
    mask = _zero_mask_pad(i, block_n, valid_n, mask_ref[...])
    c = coef_ref[...]
    d = _block_dlogits(logits, targets_ref[...], logp_old_ref[...],
                       adv_ref[...], mask, sigma, c[0, 0], c[0, 1], c[0, 2])
    dh_ref[...] = jnp.dot(d, w32.T,
                          preferred_element_type=jnp.float32
                          ).astype(dh_ref.dtype)
    # d_w accumulates across the sequential grid (constant index map)
    dw_ref[...] += jnp.dot(h.astype(jnp.float32).T, d,
                           preferred_element_type=jnp.float32)


def _policy_fwd_call(hidden, w, targets, logp_old, advantages, mask,
                     sigma, block_n, interpret):
    n, d = hidden.shape
    v = w.shape[1]
    hidden, targets, logp_old, advantages, mask = _pad_rows(
        block_n, hidden, targets, logp_old, advantages, mask)
    grid = (hidden.shape[0] // block_n,)
    kernel = functools.partial(_policy_fwd_kernel, sigma=sigma,
                               block_n=block_n, valid_n=n)
    partials = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, v), lambda i: (0, 0)),
            _row_spec(block_n), _row_spec(block_n), _row_spec(block_n),
            _row_spec(block_n),
        ],
        out_specs=pl.BlockSpec((1, N_COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], N_COLS), jnp.float32),
        interpret=interpret,
    )(hidden, w, targets, logp_old, advantages, mask)
    return _finalize(partials.sum(axis=0))


def _policy_bwd_call(hidden, w, targets, logp_old, advantages, mask,
                     sigma, block_n, interpret, coefs):
    n, d = hidden.shape
    v = w.shape[1]
    hidden_p, targets, logp_old, advantages, mask = _pad_rows(
        block_n, hidden, targets, logp_old, advantages, mask)
    grid = (hidden_p.shape[0] // block_n,)
    kernel = functools.partial(_policy_bwd_kernel, sigma=sigma,
                               block_n=block_n, valid_n=n)
    dh, dw = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, v), lambda i: (0, 0)),
            _row_spec(block_n), _row_spec(block_n), _row_spec(block_n),
            _row_spec(block_n),
            pl.BlockSpec((1, N_COLS), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, v), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((hidden_p.shape[0], d), hidden.dtype),
            jax.ShapeDtypeStruct((d, v), jnp.float32),
        ],
        interpret=interpret,
    )(hidden_p, w, targets, logp_old, advantages, mask, coefs)
    return dh[:n], dw.astype(w.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _fused_policy_loss_vjp(hidden, w, targets, logp_old, advantages, mask,
                           sigma, block_n, interpret):
    return _policy_fwd_call(hidden, w, targets, logp_old, advantages, mask,
                            sigma, block_n, interpret)


def _policy_fwd(hidden, w, targets, logp_old, advantages, mask,
                sigma, block_n, interpret):
    out = _policy_fwd_call(hidden, w, targets, logp_old, advantages, mask,
                           sigma, block_n, interpret)
    return out, (hidden, w, targets, logp_old, advantages, mask)


def _policy_bwd(sigma, block_n, interpret, res, cts):
    hidden, w, targets, logp_old, advantages, mask = res
    dh, dw = _policy_bwd_call(hidden, w, targets, logp_old, advantages, mask,
                              sigma, block_n, interpret,
                              _loss_coefs(mask, cts))
    return (dh, dw, _int_zero(targets), jnp.zeros_like(logp_old),
            jnp.zeros_like(advantages), jnp.zeros_like(mask))


_fused_policy_loss_vjp.defvjp(_policy_fwd, _policy_bwd)


def fused_policy_loss(hidden, w, targets, logp_old, advantages, mask,
                      sigma: float, block_n: int = 256,
                      interpret: bool = False):
    """Action head + GIPO/entropy/KL fused over [N, d] hidden states.

    ``hidden @ w`` is computed blockwise inside the kernel; neither forward
    nor backward ever writes an [N, Va] logit/softmax tensor to HBM. Returns
    ``(pg_loss, entropy, kl, metrics)``; differentiable w.r.t. ``hidden``
    and ``w`` (analytic backward kernel, ``d_w`` accumulated across the
    sequential grid). The metrics are explicitly stop-gradiented — the
    custom VJP only propagates the (pg, entropy, kl) cotangents.
    """
    pg, ent, kl, metrics = _fused_policy_loss_vjp(
        hidden, w, targets, logp_old, advantages, mask, sigma, block_n,
        interpret)
    return pg, ent, kl, jax.tree.map(jax.lax.stop_gradient, metrics)
