"""Fused token-level GIPO loss as a Pallas TPU kernel (DESIGN.md §7).

The naive objective touches the [N, V_action] logit tensor three times
(log-softmax, gather, ratio product). The kernel streams token blocks
through VMEM once: per block it fuses row-max → log-sum-exp → target
gather → Gaussian trust weight (eq. 5) → surrogate (eq. 6) → partial
reductions, emitting one (loss, ratio, omega, count) quadruple per block.
The host-side wrapper sums the partials — no [N, V] intermediate ever
returns to HBM.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import _vmem


def _gipo_kernel(logits_ref, targets_ref, logp_old_ref, adv_ref, mask_ref,
                 out_ref, *, sigma: float, block_n: int, valid_n: int):
    i = pl.program_id(0)
    logits = logits_ref[...].astype(jnp.float32)        # [bn, V]
    targets = targets_ref[...]                          # [bn]
    logp_old = logp_old_ref[...]
    adv = adv_ref[...]
    mask = mask_ref[...]

    # mask out padded rows
    rows = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (block_n,), 0)
    mask = jnp.where(rows < valid_n, mask, 0.0)

    # fused log-softmax + gather
    row_max = logits.max(axis=-1, keepdims=True)
    shifted = logits - row_max
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))   # [bn]
    v = logits.shape[-1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (block_n, v), 1)
              == targets[:, None])
    tgt_logit = jnp.sum(jnp.where(onehot, shifted, 0.0), axis=-1)
    logp_new = tgt_logit - lse                          # [bn]

    log_ratio = logp_new - logp_old
    ratio = jnp.exp(log_ratio)
    omega = jnp.exp(-0.5 * jnp.square(log_ratio / sigma))   # eq. 5
    per_token = -(omega * ratio * adv)                       # eq. 6

    out_ref[0, 0] = jnp.sum(per_token * mask)
    out_ref[0, 1] = jnp.sum(ratio * mask)
    out_ref[0, 2] = jnp.sum(omega * mask)
    out_ref[0, 3] = jnp.sum(mask)


def gipo_loss_fused(logits: jnp.ndarray, targets: jnp.ndarray,
                    logp_old: jnp.ndarray, advantages: jnp.ndarray,
                    mask: jnp.ndarray, sigma: float, *,
                    block_n: int = 256,
                    interpret: bool = False
                    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """logits: [N, V]; targets/logp_old/advantages/mask: [N].

    Returns (scalar loss, metrics) matching ``ref.reference_gipo_loss``.
    """
    n, v = logits.shape
    np_ = math.ceil(n / block_n) * block_n
    if np_ != n:
        pad = np_ - n
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
        targets = jnp.pad(targets, (0, pad))
        logp_old = jnp.pad(logp_old, (0, pad))
        advantages = jnp.pad(advantages, (0, pad))
        mask = jnp.pad(mask, (0, pad))

    grid = (np_ // block_n,)
    kernel = functools.partial(_gipo_kernel, sigma=sigma, block_n=block_n,
                               valid_n=n)
    partials = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, v), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_ // block_n, 4), jnp.float32),
        interpret=interpret,
    )(logits, targets, logp_old, advantages, mask)

    sums = partials.sum(axis=0)
    denom = jnp.maximum(sums[3], 1.0)
    loss = sums[0] / denom
    return loss, {"ratio_mean": sums[1] / denom,
                  "omega_mean": sums[2] / denom}
