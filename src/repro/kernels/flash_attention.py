"""Flash attention as a Pallas TPU kernel (DESIGN.md §7).

Inference-worker prefill/decode dominates rollout latency (paper §3.2) —
this is the hot spot the framework optimizes. TPU adaptation of the
flash-attention algorithm:

  * grid = (batch, q-heads, q-blocks, kv-blocks); the LAST grid axis is
    iterated sequentially on TPU ("arbitrary" dimension semantics), so the
    online-softmax state (m, l, acc) lives in VMEM scratch across kv-block
    steps and is finalized on the last step;
  * BlockSpecs tile Q/K/V into MXU-aligned [block, head_dim] tiles resident
    in VMEM; ``head_dim`` and the default blocks are multiples of 128;
  * GQA is handled by mapping each q-head grid index to its kv head
    (h // group) in the K/V index maps — no KV duplication in HBM;
  * causal + sliding-window masking from absolute positions.

Validated in interpret mode against ``ref.reference_attention`` (CPU); on
real TPUs the same ``pl.pallas_call`` lowers to Mosaic.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, block_q: int, block_k: int, seq_k: int,
                 causal: bool, window: Optional[int]):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]                              # [bq, D]
    k = k_ref[0, :, 0, :]                              # [bk, D]
    v = v_ref[0, :, 0, :]                              # [bk, D]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                # [bq, 1]
    m_new = jnp.maximum(m_prev[:, 0], s.max(axis=-1))[:, None]
    corr = jnp.exp(m_prev - m_new)                     # [bq, 1]
    p = jnp.exp(s - m_new)                             # [bq, bk]
    l_new = l_scr[...] * corr + p.sum(axis=-1)[:, None]
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _final():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [B, T, H, D]; k/v: [B, S, KV, D] with H % KV == 0 → [B, T, H, D].

    T and S are padded to block multiples internally; the causal mask uses
    unpadded absolute positions, and key padding is masked out.
    """
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = d ** -0.5

    tp = math.ceil(t / block_q) * block_q
    sp = math.ceil(s / block_k) * block_k
    if tp != t:
        q = jnp.pad(q, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    if sp != s:
        k = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))

    grid = (b, h, tp // block_q, sp // block_k)
    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_k=s, causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda bi, hi, qi, kj: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, qi, kj, g=group: (bi, kj, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, qi, kj, g=group: (bi, kj, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda bi, hi, qi, kj: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, tp, h, d), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1), jnp.float32),      # running max m
            _vmem((block_q, 1), jnp.float32),      # running sum l
            _vmem((block_q, d), jnp.float32),      # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :t]


def _vmem(shape, dtype):
    """VMEM scratch allocation (TPU); plain scratch in interpret mode."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:   # pragma: no cover — interpret-only environments
        import jax
        return jax.ShapeDtypeStruct(shape, dtype)
