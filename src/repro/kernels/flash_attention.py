"""Flash attention as a Pallas TPU kernel (DESIGN.md §7).

Inference-worker prefill/decode dominates rollout latency (paper §3.2) —
this is the hot spot the framework optimizes. TPU adaptation of the
flash-attention algorithm:

  * grid = (batch, q-heads, q-blocks, kv-blocks); the LAST grid axis is
    iterated sequentially on TPU ("arbitrary" dimension semantics), so the
    online-softmax state (m, l, acc) lives in VMEM scratch across kv-block
    steps and is finalized on the last step;
  * BlockSpecs tile Q/K/V into MXU-aligned [block, head_dim] tiles resident
    in VMEM; ``head_dim`` and the default blocks are multiples of 128;
  * GQA is handled by mapping each q-head grid index to its kv head
    (h // group) in the K/V index maps — no KV duplication in HBM;
  * causal + sliding-window masking from absolute positions.

The BACKWARD is a pair of real Pallas kernels too (no twin recompute):
the forward optionally saves the per-row log-sum-exp (``return_lse``), and
``flash_attention_bwd`` replays the online softmax from (q, k, v, LSE) —
``p = exp(s - LSE)`` directly, no second max/sum pass — accumulating dq
over kv blocks in one kernel and dk/dv over q blocks in the other. GQA
dk/dv come out per q-head and are summed over the group outside.

Validated in interpret mode against ``ref.reference_attention`` (CPU); on
real TPUs the same ``pl.pallas_call`` lowers to Mosaic.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *refs,
                 scale: float, block_q: int, block_k: int, seq_k: int,
                 causal: bool, window: Optional[int], save_lse: bool):
    if save_lse:
        lse_ref, m_scr, l_scr, acc_scr = refs
    else:
        m_scr, l_scr, acc_scr = refs
        lse_ref = None
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]                              # [bq, D]
    k = k_ref[0, :, 0, :]                              # [bk, D]
    v = v_ref[0, :, 0, :]                              # [bk, D]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                # [bq, 1]
    m_new = jnp.maximum(m_prev[:, 0], s.max(axis=-1))[:, None]
    corr = jnp.exp(m_prev - m_new)                     # [bq, 1]
    p = jnp.exp(s - m_new)                             # [bq, bk]
    l_new = l_scr[...] * corr + p.sum(axis=-1)[:, None]
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _final():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)
        if save_lse:
            # per-row log-sum-exp: the softmax residual the backward
            # kernels replay p = exp(s - LSE) from (no second pass)
            lse_ref[0, :, 0] = m_scr[...][:, 0] + jnp.log(denom[:, 0])


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False, return_lse: bool = False):
    """q: [B, T, H, D]; k/v: [B, S, KV, D] with H % KV == 0 → [B, T, H, D].

    T and S are padded to block multiples internally; the causal mask uses
    unpadded absolute positions, and key padding is masked out.
    ``return_lse`` additionally returns the per-row log-sum-exp
    [B, T, H] f32 — the residual ``flash_attention_bwd`` needs.
    """
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = d ** -0.5

    tp = math.ceil(t / block_q) * block_q
    sp = math.ceil(s / block_k) * block_k
    if tp != t:
        q = jnp.pad(q, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    if sp != s:
        k = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))

    grid = (b, h, tp // block_q, sp // block_k)
    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_k=s, causal=causal, window=window, save_lse=return_lse)
    out_specs = [pl.BlockSpec((1, block_q, 1, d),
                              lambda bi, hi, qi, kj: (bi, qi, hi, 0))]
    out_shape = [jax.ShapeDtypeStruct((b, tp, h, d), q.dtype)]
    if return_lse:
        out_specs.append(pl.BlockSpec((1, block_q, 1),
                                      lambda bi, hi, qi, kj: (bi, qi, hi)))
        out_shape.append(jax.ShapeDtypeStruct((b, tp, h), jnp.float32))
    got = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda bi, hi, qi, kj: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, qi, kj, g=group: (bi, kj, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, qi, kj, g=group: (bi, kj, hi // g, 0)),
        ],
        out_specs=out_specs if return_lse else out_specs[0],
        out_shape=out_shape if return_lse else out_shape[0],
        scratch_shapes=[
            _vmem((block_q, 1), jnp.float32),      # running max m
            _vmem((block_q, 1), jnp.float32),      # running sum l
            _vmem((block_q, d), jnp.float32),      # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    if return_lse:
        out, lse = got
        return out[:, :t], lse[:, :t]
    return got[:, :t]


# ---------------------------------------------------------------------------
# Backward: two Pallas kernels replaying the online softmax from the LSE
# ---------------------------------------------------------------------------

def _bwd_mask(qi, kj, block_q, block_k, seq_k, causal, window, transposed):
    """Same absolute-position mask as the forward; ``transposed`` gives it
    in [block_k, block_q] layout for the dk/dv kernel."""
    shape = (block_k, block_q) if transposed else (block_q, block_k)
    qax, kax = (1, 0) if transposed else (0, 1)
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, shape, qax)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, shape, kax)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    return mask


def _attn_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dd_ref,
                        dq_ref, dq_scr, *, scale, block_q, block_k, seq_k,
                        causal, window):
    """dq accumulated over kv blocks (last grid axis sequential):
    p = exp(s - LSE); ds = p ∘ (dO·Vᵀ − D); dq += ds·K·scale."""
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    do = do_ref[0, :, 0, :].astype(jnp.float32)
    lse = lse_ref[0, :, 0]                                 # [bq]
    dd = dd_ref[0, :, 0]                                   # [bq] rowsum(dO∘O)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    mask = _bwd_mask(qi, kj, block_q, block_k, seq_k, causal, window, False)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)    # [bq, bk]
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - dd[:, None])
    dq_scr[...] += jnp.dot(ds, k,
                           preferred_element_type=jnp.float32) * scale

    @pl.when(kj == nk - 1)
    def _final():
        dq_ref[0, :, 0, :] = dq_scr[...]


def _attn_bwd_dkv_kernel(k_ref, v_ref, q_ref, do_ref, lse_ref, dd_ref,
                         dk_ref, dv_ref, dk_scr, dv_scr, *, scale, block_q,
                         block_k, seq_k, causal, window):
    """dk/dv for one k-block accumulated over q blocks (last grid axis):
    dv += pᵀ·dO; dk += (p ∘ (V·dOᵀ − D))ᵀ-form·Q·scale. Emitted per
    q-head; the wrapper sums heads over each GQA group."""
    ki = pl.program_id(2)
    qj = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(qj == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    q = q_ref[0, :, 0, :].astype(jnp.float32)
    do = do_ref[0, :, 0, :].astype(jnp.float32)
    lse = lse_ref[0, :, 0]                                 # [bq]
    dd = dd_ref[0, :, 0]                                   # [bq]

    st = jnp.dot(k, q.T, preferred_element_type=jnp.float32) * scale
    mask = _bwd_mask(qj, ki, block_q, block_k, seq_k, causal, window, True)
    pt = jnp.where(mask, jnp.exp(st - lse[None, :]), 0.0)  # [bk, bq]
    dv_scr[...] += jnp.dot(pt, do, preferred_element_type=jnp.float32)
    dpt = jnp.dot(v, do.T, preferred_element_type=jnp.float32)
    dst = pt * (dpt - dd[None, :])
    dk_scr[...] += jnp.dot(dst, q,
                           preferred_element_type=jnp.float32) * scale

    @pl.when(qj == nq - 1)
    def _final():
        dk_ref[0, :, 0, :] = dk_scr[...]
        dv_ref[0, :, 0, :] = dv_scr[...]


# padded q rows carry dO = 0 and D = 0, so their p·(…) products vanish;
# padding the LSE with this pushes p itself to exp(s − big) ≈ 0 as well,
# keeping every padded contribution exactly zero
_LSE_PAD = 1e30


def flash_attention_bwd(q, k, v, out, lse, do, *, causal: bool = True,
                        window: Optional[int] = None, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """Gradients (dq, dk, dv) from the saved forward residuals.

    q: [B,T,H,D]; k/v: [B,S,KV,D]; out/do: like q; lse: [B,T,H] f32 from
    ``flash_attention(..., return_lse=True)``. Recompute-free: the online
    softmax is replayed as ``p = exp(s − LSE)`` — one pass per kernel.
    """
    b, t, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    group = h // kv
    scale = d ** -0.5
    # D = rowsum(dO ∘ O): tiny elementwise reduce, cheaper outside
    dd = (do.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)

    tp = math.ceil(t / block_q) * block_q
    sp = math.ceil(s / block_k) * block_k
    if tp != t:
        pad4 = ((0, 0), (0, tp - t), (0, 0), (0, 0))
        q = jnp.pad(q, pad4)
        do = jnp.pad(do, pad4)
        lse = jnp.pad(lse, ((0, 0), (0, tp - t), (0, 0)),
                      constant_values=_LSE_PAD)
        dd = jnp.pad(dd, ((0, 0), (0, tp - t), (0, 0)))
    if sp != s:
        pad4 = ((0, 0), (0, sp - s), (0, 0), (0, 0))
        k = jnp.pad(k, pad4)
        v = jnp.pad(v, pad4)

    # index-map helpers: in the dq kernel the q-block index is grid axis 2
    # and the kv-block axis 3; the dkv kernel swaps them
    kq_spec = lambda qax: pl.BlockSpec(
        (1, block_q, 1, d),
        (lambda bi, hi, i, j: (bi, i, hi, 0)) if qax == 2 else
        (lambda bi, hi, i, j: (bi, j, hi, 0)))
    kk_spec = lambda kax: pl.BlockSpec(
        (1, block_k, 1, d),
        (lambda bi, hi, i, j, g=group: (bi, j, hi // g, 0)) if kax == 3 else
        (lambda bi, hi, i, j, g=group: (bi, i, hi // g, 0)))
    row_spec = lambda qax: pl.BlockSpec(
        (1, block_q, 1),
        (lambda bi, hi, i, j: (bi, i, hi)) if qax == 2 else
        (lambda bi, hi, i, j: (bi, j, hi)))

    kernel_kw = dict(scale=scale, block_q=block_q, block_k=block_k,
                     seq_k=s, causal=causal, window=window)
    dq = pl.pallas_call(
        functools.partial(_attn_bwd_dq_kernel, **kernel_kw),
        grid=(b, h, tp // block_q, sp // block_k),
        in_specs=[kq_spec(2), kk_spec(3), kk_spec(3), kq_spec(2),
                  row_spec(2), row_spec(2)],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda bi, hi, i, j: (bi, i, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, tp, h, d), jnp.float32),
        scratch_shapes=[_vmem((block_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, dd)

    dkh, dvh = pl.pallas_call(
        functools.partial(_attn_bwd_dkv_kernel, **kernel_kw),
        grid=(b, h, sp // block_k, tp // block_q),
        in_specs=[kk_spec(2), kk_spec(2), kq_spec(3), kq_spec(3),
                  row_spec(3), row_spec(3)],
        out_specs=[
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, i, j: (bi, i, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, i, j: (bi, i, hi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, sp, h, d), jnp.float32),
            jax.ShapeDtypeStruct((b, sp, h, d), jnp.float32),
        ],
        scratch_shapes=[_vmem((block_k, d), jnp.float32),
                        _vmem((block_k, d), jnp.float32)],
        interpret=interpret,
    )(k, v, q, do, lse, dd)

    # GQA: per-q-head dk/dv fold back onto their kv head
    dk = dkh[:, :s].reshape(b, s, kv, group, d).sum(3)
    dv = dvh[:, :s].reshape(b, s, kv, group, d).sum(3)
    return (dq[:, :t].astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


def _vmem(shape, dtype):
    """VMEM scratch allocation (TPU); plain scratch in interpret mode."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, dtype)
    except Exception:   # pragma: no cover — interpret-only environments
        import jax
        return jax.ShapeDtypeStruct(shape, dtype)
