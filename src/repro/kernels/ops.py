"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python for correctness validation; on TPU backends the same
``pl.pallas_call`` lowers to Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention import flash_attention
from repro.kernels.gipo_loss import (
    fused_policy_loss,
    gipo_head_loss,
    gipo_loss_fused,
)
from repro.kernels.ssd_scan import ssd_scan


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True,
                       window: Optional[int] = None, block_q: int = 128,
                       block_k: int = 128,
                       interpret: Optional[bool] = None):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("sigma", "block_n", "interpret"))
def gipo_loss_op(logits, targets, logp_old, advantages, mask, *,
                 sigma: float = 0.2, block_n: int = 256,
                 interpret: Optional[bool] = None):
    return gipo_loss_fused(logits, targets, logp_old, advantages, mask,
                           sigma, block_n=block_n,
                           interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("sigma", "block_n", "interpret"))
def gipo_head_loss_op(logits, targets, logp_old, advantages, mask, *,
                      sigma: float = 0.2, block_n: int = 256,
                      interpret: Optional[bool] = None):
    """Custom-VJP fused GIPO + entropy + KL -> (pg, ent, kl, metrics)."""
    return gipo_head_loss(logits, targets, logp_old, advantages, mask,
                          sigma, block_n, _auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("sigma", "block_n", "interpret"))
def fused_policy_loss_op(hidden, w, targets, logp_old, advantages, mask, *,
                         sigma: float = 0.2, block_n: int = 256,
                         interpret: Optional[bool] = None):
    """Hidden-level fused action head + loss -> (pg, ent, kl, metrics)."""
    return fused_policy_loss(hidden, w, targets, logp_old, advantages, mask,
                             sigma, block_n, _auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_op(x, dt, A, Bm, Cm, *, chunk: int = 128,
                interpret: Optional[bool] = None):
    return ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                    interpret=_auto_interpret(interpret))
