"""Pallas TPU kernels for the framework's compute hot-spots (DESIGN.md §7):
flash attention (prefill/decode), the custom-VJP fused token-level GIPO
loss (logits- and hidden-level), and the Mamba2 SSD chunked scan. Each
ships a jit'd wrapper (``ops``), a pure-jnp oracle (``ref``), and the
``dispatch`` layer routes call sites to Pallas on TPU / jnp twins
elsewhere; interpret-mode tests sweep shapes and dtypes."""
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.gipo_loss import (  # noqa: F401
    fused_policy_loss,
    gipo_head_loss,
    gipo_loss_fused,
)
from repro.kernels.ssd_scan import ssd_scan  # noqa: F401
from repro.kernels import dispatch, ops, ref  # noqa: F401
