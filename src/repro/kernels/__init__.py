"""Pallas TPU kernels for the framework's compute hot-spots (DESIGN.md §7):
flash attention (prefill/decode), the fused token-level GIPO loss, and the
Mamba2 SSD chunked scan. Each ships a jit'd wrapper (``ops``) and a
pure-jnp oracle (``ref``); interpret-mode tests sweep shapes and dtypes."""
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.gipo_loss import gipo_loss_fused  # noqa: F401
from repro.kernels.ssd_scan import ssd_scan  # noqa: F401
from repro.kernels import ops, ref  # noqa: F401
