"""Built-in multi-task manipulation suite (the LIBERO stand-in).

A 2-D tabletop: the agent moves, grips an object, and delivers it to a goal.
Four task suites mirror LIBERO's axes of variation:

  * ``spatial`` — goal position varies per task
  * ``object``  — object position varies
  * ``goal``    — both vary
  * ``long``    — two objects must be delivered sequentially (long horizon)

Design choices matched to the paper's experimental structure:
  * observations are a *pixel-interface frame* (coarse 8×8×3 render,
    flattened) consumed by the policy as a prefix embedding and by the world
    model as its native space, plus static instruction tokens — so
    imagination rollouts close the loop without a simulator;
  * rewards are sparse success by default (the regime where the WM's dense
    potential-based rewards matter);
  * per-instance step latency is configurable (lognormal long tails) to
    reproduce the step-level / episode-level stragglers of §3.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

SUITES = ("spatial", "object", "goal", "long")
T_OBS = 12              # instruction token length
GRID = 8                # frame resolution
FRAME_DIM = GRID * GRID * 3
TASKS_PER_SUITE = 10


def _render(agent, obj, goal, obj2=None, goal2=None) -> np.ndarray:
    """Gaussian-blob render to [GRID, GRID, 3] -> flat float32."""
    xs = np.linspace(0, 1, GRID)
    gx, gy = np.meshgrid(xs, xs, indexing="ij")

    def blob(p):
        return np.exp(-(((gx - p[0]) ** 2 + (gy - p[1]) ** 2) / 0.02))
    frame = np.stack([
        blob(agent),
        blob(obj) + (blob(obj2) if obj2 is not None else 0.0),
        blob(goal) + (blob(goal2) if goal2 is not None else 0.0),
    ], axis=-1)
    return np.clip(frame, 0, 1).astype(np.float32).reshape(-1)


class ManipulationEnv:
    """Single (non-vectorized) env instance — the paper's 'no natural
    batchability' regime."""

    def __init__(self, suite: str = "spatial", task_id: int = 0,
                 max_steps: int = 30, action_vocab: int = 64,
                 action_dim: int = 7, dense_reward: bool = False,
                 latency: Optional[Callable[[], float]] = None,
                 seed: int = 0):
        assert suite in SUITES, suite
        self.suite = suite
        self.task_id = task_id
        self.max_steps = max_steps
        self.action_vocab = action_vocab
        self.action_dim = action_dim
        self.dense_reward = dense_reward
        self.latency = latency
        self._rng = np.random.default_rng(seed)
        self.tol = 0.22
        self.reset(task_id)

    # -- task layout ---------------------------------------------------------
    def _layout(self, task_id: int):
        # zlib.crc32, NOT hash(): python salts str hashes per process, which
        # would make task layouts nondeterministic across runs
        import zlib
        seed = zlib.crc32(f"{self.suite}/{task_id}".encode()) % (2 ** 31)
        r = np.random.default_rng(seed)
        agent = np.array([0.5, 0.5])
        obj = np.array([0.25, 0.25])
        goal = np.array([0.75, 0.75])

        def apart(anchor, min_d=None):
            # resample until the point is a real task (not pre-solved)
            min_d = min_d if min_d is not None else 1.5 * self.tol
            for _ in range(100):
                p = r.uniform(0.15, 0.85, 2)
                if np.linalg.norm(p - anchor) >= min_d:
                    return p
            return p

        if self.suite == "spatial":
            goal = apart(obj)
        elif self.suite == "object":
            obj = apart(goal)
        elif self.suite == "goal":
            obj = r.uniform(0.15, 0.85, 2)
            goal = apart(obj)
        obj2 = goal2 = None
        if self.suite == "long":
            obj = r.uniform(0.15, 0.85, 2)
            goal = apart(obj)
            obj2 = r.uniform(0.15, 0.85, 2)
            goal2 = apart(obj2)
        return agent, obj, goal, obj2, goal2

    def reset(self, task_id: Optional[int] = None) -> Dict:
        if task_id is not None:
            self.task_id = task_id
        (self.agent, self.obj, self.goal,
         self.obj2, self.goal2) = self._layout(self.task_id)
        self.holding = 0          # 0 none, 1 obj, 2 obj2
        self.delivered = 0        # for the long suite
        self.t = 0
        return self._obs()

    def _instruction_tokens(self) -> np.ndarray:
        toks = np.zeros(T_OBS, np.int32)
        toks[0] = SUITES.index(self.suite) + 1
        toks[1] = 10 + (self.task_id % TASKS_PER_SUITE)
        toks[2] = 30 + self.delivered
        return toks

    def _obs(self) -> Dict:
        if self.suite == "long" and self.delivered >= 1:
            frame = _render(self.agent,
                            self.obj2, self.goal2)
        else:
            frame = _render(self.agent, self.obj, self.goal,
                            self.obj2, self.goal2)
        return {"tokens": self._instruction_tokens(),
                "frame": frame, "step": self.t}

    def _decode(self, action_tokens: np.ndarray) -> np.ndarray:
        a = np.asarray(action_tokens, np.float64)
        return (a / (self.action_vocab - 1)) * 2.0 - 1.0

    def _active_target(self) -> Tuple[np.ndarray, np.ndarray]:
        if self.suite == "long" and self.delivered >= 1:
            return self.obj2, self.goal2
        return self.obj, self.goal

    def step(self, action_tokens: np.ndarray):
        if self.latency is not None:
            time.sleep(self.latency())
        a = self._decode(action_tokens)
        obj, goal = self._active_target()

        prev_potential = self._potential()
        self.agent = np.clip(self.agent + 0.18 * a[:2], 0, 1)
        grip = a[2] > 0
        if grip and np.linalg.norm(self.agent - obj) < self.tol:
            self.holding = 2 if (self.suite == "long"
                                 and self.delivered >= 1) else 1
        if not grip:
            self.holding = 0
        if self.holding:
            if self.holding == 1:
                self.obj = self.agent.copy()
            else:
                self.obj2 = self.agent.copy()

        obj, goal = self._active_target()
        success_now = np.linalg.norm(obj - goal) < self.tol
        reward, done, success = 0.0, False, False
        if success_now:
            if self.suite == "long" and self.delivered == 0:
                self.delivered = 1
                self.holding = 0
                reward = 0.5
            else:
                reward, done, success = 1.0, True, True
        if self.dense_reward:
            reward += self._potential() - prev_potential
        self.t += 1
        if self.t >= self.max_steps:
            done = True          # truncation: NOT a natural termination
        obs = self._obs()
        info = {"success": success,
                "truncated": self.t >= self.max_steps and not success}
        return obs, float(reward), bool(done), info

    def _potential(self) -> float:
        """Dense shaping potential (optional): progress toward subgoal."""
        obj, goal = self._active_target()
        d_ag = np.linalg.norm(self.agent - obj)
        d_og = np.linalg.norm(obj - goal)
        return -0.5 * d_ag - 1.0 * d_og

    def oracle_action(self) -> np.ndarray:
        """Scripted expert (for imitation baselines / WM pretraining data)."""
        obj, goal = self._active_target()
        if self.holding:
            target, grip = goal, 1.0
        elif np.linalg.norm(self.agent - obj) < self.tol * 0.8:
            target, grip = obj, 1.0      # close the gripper BEFORE moving
        else:
            target, grip = obj, -1.0
        delta = np.clip((target - self.agent) / 0.18, -1, 1)
        a = np.zeros(self.action_dim)
        a[:2] = delta
        a[2] = grip
        noise = self._rng.normal(0, 0.05, self.action_dim)
        tokens = np.round(((a + noise + 1) / 2) * (self.action_vocab - 1))
        return np.clip(tokens, 0, self.action_vocab - 1).astype(np.int32)


def lognormal_latency(mean_ms: float = 2.0, sigma: float = 1.0,
                      seed: int = 0) -> Callable[[], float]:
    """Long-tailed physics-step latency generator (§3 step-level tail)."""
    rng = np.random.default_rng(seed)
    mu = np.log(mean_ms / 1000.0)
    return lambda: float(rng.lognormal(mu, sigma))
