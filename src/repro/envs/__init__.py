from repro.envs.toy_manipulation import (  # noqa: F401
    FRAME_DIM,
    GRID,
    SUITES,
    T_OBS,
    TASKS_PER_SUITE,
    ManipulationEnv,
    lognormal_latency,
)
