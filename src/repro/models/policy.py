"""The VLA policy: backbone + slimmed action head + value head.

An env step consumes an observation embedding (stub frontend) plus the
instruction tokens, and emits ``action_dim`` discrete action tokens
(token-level optimization, paper App. D.3). ``score_trajectory`` is the
teacher-forced pass used by the trainer — it returns per-token log-probs
and per-step values in one forward (the JIT value-recomputation input).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.models.layers import Params
from repro.models.value_head import value_head, value_head_init


class PolicyOutput(NamedTuple):
    logits: jnp.ndarray        # [B, A, Va] f32 — per action-token logits
    value: jnp.ndarray         # [B]
    hidden: jnp.ndarray        # [B, S, d]
    aux: Dict[str, jnp.ndarray]  # MoE load-balance / router-z terms


def init_policy_params(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    params = transformer.init_params(cfg, k1)
    params["value_head"] = value_head_init(
        k2, cfg.d_model, cfg.max_episode_steps)
    return params


def _teacher_forced(cfg: ModelConfig, params: Params,
                    obs_tokens: jnp.ndarray, action_tokens: jnp.ndarray,
                    step_t: jnp.ndarray,
                    prefix_embeds: Optional[jnp.ndarray], *,
                    remat: bool, head: bool):
    """Shared teacher-forced pass. Returns (transformer out, pred slice,
    value). ``pred`` selects the position that predicts action token k —
    prefix_len + T_obs + k - 1, the standard next-token factorization —
    in ONE place so the logits and fused-hidden paths cannot drift."""
    a = action_tokens.shape[1]
    tokens = jnp.concatenate([obs_tokens, action_tokens], axis=1)
    out = transformer.forward(cfg, params, tokens,
                              prefix_embeds=prefix_embeds, remat=remat,
                              head=head)
    t_total = out["hidden"].shape[1]
    pred = slice(t_total - a - 1, t_total - 1)
    act_hidden = out["hidden"][:, t_total - a:]                  # [B, A, d]
    value = value_head(params["value_head"], act_hidden, step_t)
    return out, pred, value


def policy_forward(cfg: ModelConfig, params: Params, obs_tokens: jnp.ndarray,
                   action_tokens: jnp.ndarray, step_t: jnp.ndarray,
                   prefix_embeds: Optional[jnp.ndarray] = None, *,
                   remat: bool = False) -> PolicyOutput:
    """Teacher-forced scoring of one env step.

    obs_tokens: [B, T_obs] instruction/context tokens
    action_tokens: [B, A] the action tokens taken
    step_t: [B] episode step index (value-head step embedding)

    Logits for action token k are read at the position *preceding* it
    (standard next-token factorization).
    """
    out, pred, value = _teacher_forced(cfg, params, obs_tokens,
                                       action_tokens, step_t, prefix_embeds,
                                       remat=remat, head=True)
    return PolicyOutput(logits=out["logits"][:, pred], value=value,
                        hidden=out["hidden"], aux=out["aux"])


class PolicyHidden(NamedTuple):
    pred_hidden: jnp.ndarray   # [B, A, d] — hidden at the position that
    #                            predicts each action token (pre action-head)
    value: jnp.ndarray         # [B]
    aux: Dict[str, jnp.ndarray]


def policy_forward_hidden(cfg: ModelConfig, params: Params,
                          obs_tokens: jnp.ndarray,
                          action_tokens: jnp.ndarray, step_t: jnp.ndarray,
                          prefix_embeds: Optional[jnp.ndarray] = None, *,
                          remat: bool = False) -> PolicyHidden:
    """Teacher-forced scoring that stops before the action head.

    The fused-loss trainer path consumes these hidden states directly: the
    action-head matmul and the GIPO/entropy/KL loss run block-fused in
    ``repro.kernels.dispatch.policy_head_loss``, so the [B, A, Va] logit
    tensor is never materialized.
    """
    out, pred, value = _teacher_forced(cfg, params, obs_tokens,
                                       action_tokens, step_t, prefix_embeds,
                                       remat=remat, head=False)
    return PolicyHidden(pred_hidden=out["hidden"][:, pred], value=value,
                        aux=out["aux"])


def action_log_prob(logits: jnp.ndarray,
                    action_tokens: jnp.ndarray) -> jnp.ndarray:
    """Token-level log-probs. logits: [B, A, Va]; actions: [B, A] -> [B, A]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(
        logp, action_tokens[..., None], axis=-1)[..., 0]


def sample_actions(key, logits: jnp.ndarray,
                   temperature: float = 1.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sample action tokens; returns (tokens [B, A], log_probs [B, A])."""
    if temperature != 1.0:
        logits = logits / temperature
    tokens = jax.random.categorical(key, logits, axis=-1)
    return tokens, action_log_prob(logits, tokens)


def sample_action_sequence(cfg: ModelConfig, params: Params, key,
                           obs_tokens: jnp.ndarray, step_t: jnp.ndarray,
                           prefix_embeds: Optional[jnp.ndarray] = None,
                           temperature: float = 1.0
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Autoregressive action sampling for one env step (inference worker).

    Prefills the observation context, then decodes ``cfg.action_dim``
    action tokens against the KV/state cache. Returns
    (action_tokens [B, A], behavior_logp μ [B, A], value V(o_t) [B]).
    """
    a = cfg.action_dim
    prefix_len = prefix_embeds.shape[1] if prefix_embeds is not None else 0
    cache_len = prefix_len + obs_tokens.shape[1] + a
    out, cache = transformer.prefill(cfg, params, obs_tokens, prefix_embeds,
                                     cache_len=cache_len)
    first_logits = out["logits"][:, -1]                  # [B, Va]

    def body(carry, key_i):
        logits, cache = carry
        if temperature != 1.0:
            logits = logits / temperature
        tok = jax.random.categorical(key_i, logits, axis=-1)     # [B]
        logp = jnp.take_along_axis(
            jax.nn.log_softmax(logits, axis=-1), tok[:, None], axis=-1)[:, 0]
        dec, cache = transformer.decode(cfg, params, tok, cache)
        hidden = dec["hidden"][:, 0]                     # [B, d]
        return (dec["logits"][:, -1], cache), (tok, logp, hidden)

    keys = jax.random.split(key, a)
    _, (tokens, logps, hiddens) = jax.lax.scan(
        body, (first_logits, cache), keys)
    tokens = tokens.T                                    # [B, A]
    logps = logps.T
    act_hidden = jnp.moveaxis(hiddens, 0, 1)             # [B, A, d]
    value = value_head(params["value_head"], act_hidden, step_t)
    return tokens, logps, value


def make_inference_fn(cfg: ModelConfig, temperature: float = 1.0):
    """jit-compiled batched inference entry point for the service pool."""
    def fn(params, key, obs_tokens, step_t, prefix_embeds=None):
        return sample_action_sequence(cfg, params, key, obs_tokens, step_t,
                                      prefix_embeds, temperature)
    return jax.jit(fn)
