from repro.models import attention, layers, moe, policy, ssm, transformer, value_head  # noqa: F401
