"""Grouped-query attention: training, prefill, and single-token decode paths.

Decode supports both a full KV cache (decode_32k) and a ring-buffer
sliding-window cache (the ``long_500k`` sub-quadratic fallback for dense
architectures — see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.models.layers import Params, apply_rope, dense_init

NEG_INF = -1e30


class KVCache(NamedTuple):
    """KV cache; ``positions`` carries absolute positions (ring buffers
    overwrite slots out of order). ``length`` = tokens generated so far."""

    k: jnp.ndarray            # [B, S_cache, KV, D]
    v: jnp.ndarray            # [B, S_cache, KV, D]
    positions: jnp.ndarray    # [B, S_cache] int32, -1 = empty
    length: jnp.ndarray       # [B] int32


def attention_init(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d_model, num_heads, head_dim), dtype),
        "wk": dense_init(kk, (d_model, num_kv_heads, head_dim), dtype),
        "wv": dense_init(kv, (d_model, num_kv_heads, head_dim), dtype),
        "wo": dense_init(ko, (num_heads, head_dim, d_model), dtype),
    }


def _project_qkv(params: Params, x: jnp.ndarray, positions: jnp.ndarray,
                 rope_theta: float):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q: [B,T,H,D], k: [B,S,KV,D] -> scores [B,H,T,S] f32 (head-grouped).

    f32 accumulation is requested via ``preferred_element_type`` — a post
    hoc ``.astype`` would let XLA materialize f32 COPIES of the (possibly
    cache-sized) operands instead of widening inside the dot.
    """
    b, t, h, d = q.shape
    kv = k.shape[2]
    group = h // kv
    qg = q.reshape(b, t, kv, group, d)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k,
                        preferred_element_type=jnp.float32)
    return scores.reshape(b, h, t, k.shape[1])


def _gqa_combine(weights: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """weights: [B,H,T,S], v: [B,S,KV,D] -> [B,T,H,D]."""
    b, h, t, s = weights.shape
    kv = v.shape[2]
    group = h // kv
    wg = weights.reshape(b, kv, group, t, s)
    out = jnp.einsum("bkgts,bskd->btkgd", wg, v)
    return out.reshape(b, t, h, v.shape[3])


def causal_mask(t: int, window: Optional[int] = None) -> jnp.ndarray:
    """[T, T] additive mask; optional sliding window."""
    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    ok = j <= i
    if window is not None:
        ok &= (i - j) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention_forward(params: Params, x: jnp.ndarray, *, rope_theta: float,
                      window: Optional[int] = None,
                      positions: Optional[jnp.ndarray] = None,
                      block: Optional[int] = None,
                      unroll: bool = False) -> jnp.ndarray:
    """Full causal self-attention for training / teacher-forced scoring.

    ``block`` switches to the blockwise path (O(T·block) score memory
    instead of O(T²)) — required for the 4k/32k production shapes. The
    blockwise path is routed through ``repro.kernels.dispatch``: the Pallas
    flash kernel on TPU, the jnp online-softmax twin elsewhere; identical
    numerics (tests assert allclose vs the dense path).
    """
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.arange(t)[None, :]
    q, k, v = _project_qkv(params, x, positions, rope_theta)
    if block is not None and t > block:
        out = dispatch.attention(q, k, v, window=window, block=block,
                                 unroll=unroll)
        out = out.astype(x.dtype)
    else:
        out = dispatch.dense_attention(q, k, v, window=window)
    return jnp.einsum("bthk,hkd->btd", out, params["wo"])


def _blockwise_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    scale: float, *, window: Optional[int],
                    block: int, unroll: bool = False) -> jnp.ndarray:
    """Online-softmax attention scanned over KV blocks (flash-attention
    algorithm in pure jnp — the jnp twin of ``repro.kernels.flash_attention``).

    q: [B,T,H,D]; k/v: [B,S,KV,D]. Causal over absolute positions 0..T-1
    (q) vs 0..S-1 (k); requires S % block == 0.
    """
    b, t, h, d = q.shape
    s = k.shape[1]
    if s % block:                      # prefix tokens make S ragged — pad
        pad = block - s % block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = k.shape[1] // block
    qpos = jnp.arange(t)[:, None]                       # [T, 1]

    def body(carry, j):
        m, l, acc = carry
        kj = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=1)
        kpos = j * block + jnp.arange(block)[None, :]   # [1, block]
        ok = (kpos <= qpos) & (kpos < s)
        if window is not None:
            ok &= (qpos - kpos) < window
        scores = _gqa_scores(q, kj) * scale
        scores = jnp.where(ok[None, None], scores, NEG_INF)  # [B,H,T,blk]
        m_new = jnp.maximum(m, scores.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        pv = _gqa_combine(p.astype(v.dtype), vj).astype(jnp.float32)
        # pv: [B,T,H,D] -> match acc layout [B,H,T,D]
        acc_new = acc * corr[..., None] + pv.transpose(0, 2, 1, 3)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, h, t), NEG_INF, jnp.float32),
            jnp.zeros((b, h, t), jnp.float32),
            jnp.zeros((b, h, t, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), init, jnp.arange(nb),
                                  unroll=nb if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]        # [B,H,T,D]
    return out.transpose(0, 2, 1, 3)                    # [B,T,H,D]


def init_cache(batch: int, cache_len: int, num_kv_heads: int, head_dim: int,
               dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype=dtype),
        v=jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype=dtype),
        positions=jnp.full((batch, cache_len), -1, dtype=jnp.int32),
        length=jnp.zeros((batch,), dtype=jnp.int32),
    )


def attention_prefill(params: Params, x: jnp.ndarray, *, rope_theta: float,
                      cache_len: int,
                      window: Optional[int] = None,
                      block: Optional[int] = None,
                      unroll: bool = False
                      ) -> Tuple[jnp.ndarray, KVCache]:
    """Causal attention over the prompt; emits the populated KV cache."""
    b, t, _ = x.shape
    positions = jnp.arange(t)[None, :]
    q, k, v = _project_qkv(params, x, positions, rope_theta)
    if block is not None and t > block:
        out = dispatch.attention(q, k, v, window=window, block=block,
                                 unroll=unroll).astype(x.dtype)
    else:
        out = dispatch.dense_attention(q, k, v, window=window)
    out = jnp.einsum("bthk,hkd->btd", out, params["wo"])

    if cache_len >= t:
        pad = cache_len - t
        k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_c = jnp.pad(jnp.broadcast_to(positions, (b, t)),
                        ((0, 0), (0, pad)), constant_values=-1)
    else:  # ring buffer keeps the last ``cache_len`` tokens
        k_c = k[:, t - cache_len:]
        v_c = v[:, t - cache_len:]
        pos_c = jnp.broadcast_to(positions[:, t - cache_len:], (b, cache_len))
        # ring layout: slot = pos % cache_len
        slots = pos_c[0] % cache_len
        inv = jnp.argsort(slots)
        k_c, v_c = k_c[:, inv], v_c[:, inv]
        pos_c = pos_c[:, inv]
    cache = KVCache(k=k_c, v=v_c, positions=pos_c.astype(jnp.int32),
                    length=jnp.full((b,), t, dtype=jnp.int32))
    return out, cache


def attention_decode(params: Params, x: jnp.ndarray, cache: KVCache, *,
                     rope_theta: float,
                     window: Optional[int] = None,
                     uniform: bool = False
                     ) -> Tuple[jnp.ndarray, KVCache]:
    """One new token per sequence. x: [B, 1, d].

    ``uniform=True`` (§Perf hillclimb): when every sequence in the batch is
    at the SAME position (lockstep serving), the cache update is a single
    dynamic-update-slice at a scalar slot instead of a batched scatter —
    GSPMD keeps the batch-sharded cache in place (a scatter with per-row
    indices forces replication)."""
    b = x.shape[0]
    cache_len = cache.k.shape[1]
    pos = cache.length                                     # [B]
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k_new = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v_new = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    q = apply_rope(q, pos[:, None], rope_theta)
    k_new = apply_rope(k_new, pos[:, None], rope_theta)

    slot = (pos % cache_len).astype(jnp.int32)   # ring layout (== pos when S_cache > pos)
    if uniform:
        s0 = slot[0]
        k_c = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), s0, axis=1)
        v_c = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), s0, axis=1)
        pos_c = jax.lax.dynamic_update_slice(
            cache.positions, pos[:, None], (jnp.int32(0), s0))
    else:
        b_idx = jnp.arange(b)
        k_c = cache.k.at[b_idx, slot].set(k_new[:, 0].astype(cache.k.dtype))
        v_c = cache.v.at[b_idx, slot].set(v_new[:, 0].astype(cache.v.dtype))
        pos_c = cache.positions.at[b_idx, slot].set(pos)

    valid = pos_c >= 0                                        # [B,S]
    if window is not None:
        valid &= (pos[:, None] - pos_c) < window
    valid &= pos_c <= pos[:, None]
    out = dispatch.decode_attention(q, k_c, v_c, valid)
    out = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    new_cache = KVCache(k=k_c, v=v_c, positions=pos_c, length=pos + 1)
    return out, new_cache
