"""Policy-backbone assembly for every assigned architecture family.

Layers are *stacked* (leading ``L`` axis) and iterated with ``lax.scan`` so
the lowered HLO stays compact for 30–64-layer models; ``remat=True`` wraps
the scan body in ``jax.checkpoint`` (per-layer activation checkpointing —
the memory/compute trade recorded in the roofline's MODEL_FLOPS ratio).

Three entry points per family:
  * ``forward``  — teacher-forced scoring (training / value recomputation)
  * ``prefill``  — prompt pass that also emits the decode cache
  * ``decode``   — one token against the cache (``serve_step``)

Hybrid (zamba2) note: the *shared* attention block is applied before every
``shared_every``-th Mamba2 layer; its KV cache has one slot per application
(not per layer) so a 32k/500k-context decode cache stays proportional to the
number of applications (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import KVCache
from repro.models.layers import (
    Params,
    action_head,
    action_head_init,
    dense_init,
    embed,
    embedding_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.models.ssm import SSMState

FRONTEND_DIM = 1024  # stub modality-frontend embedding width (ViT/EnCodec)


class DecodeCache(NamedTuple):
    """Family-polymorphic decode cache."""

    attn: Optional[KVCache]      # stacked [L or n_shared, ...] or None
    ssm: Optional[SSMState]      # stacked [L, ...] or None


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def _attn_block_init(key, cfg: ModelConfig, d_ff: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_lib.attention_init(
            k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.head_dim, dtype),
        "mlp_norm": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k2, cfg.d_model, d_ff, dtype),
    }


def _moe_block_init(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_lib.attention_init(
            k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.head_dim, dtype),
        "mlp_norm": rmsnorm_init(cfg.d_model, dtype),
        "moe": moe_lib.moe_init(k2, cfg.d_model, cfg.moe, dtype),
    }


def _ssm_block_init(key, cfg: ModelConfig, dtype) -> Params:
    return {
        "norm": rmsnorm_init(cfg.d_model, dtype),
        "ssm": ssm_lib.ssm_init(key, cfg.d_model, cfg.ssm, dtype),
    }


def _stacked_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def hybrid_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_macro, group, remainder): shared attn fires n_macro (+1 if rem)
    times, before each macro group of ``group`` Mamba2 layers."""
    g = cfg.hybrid.shared_every
    n_macro = cfg.num_layers // g
    rem = cfg.num_layers % g
    return n_macro, g, rem


def num_shared_applications(cfg: ModelConfig) -> int:
    n_macro, _, rem = hybrid_layout(cfg)
    return n_macro + (1 if rem else 0)


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "action_head": action_head_init(
            ks[1], cfg.d_model, cfg.action_vocab_size, dtype),
    }
    if cfg.num_prefix_tokens:
        params["prefix_proj"] = {
            "w": dense_init(ks[2], (FRONTEND_DIM, cfg.d_model), dtype)}

    if cfg.arch_type in ("dense", "audio", "vlm"):
        params["layers"] = _stacked_init(
            lambda k: _attn_block_init(k, cfg, cfg.d_ff, dtype),
            ks[3], cfg.num_layers)
    elif cfg.arch_type == "moe":
        params["layers"] = _stacked_init(
            lambda k: _moe_block_init(k, cfg, dtype), ks[3], cfg.num_layers)
    elif cfg.arch_type == "ssm":
        params["layers"] = _stacked_init(
            lambda k: _ssm_block_init(k, cfg, dtype), ks[3], cfg.num_layers)
    elif cfg.arch_type == "hybrid":
        n_macro, g, rem = hybrid_layout(cfg)
        params["layers"] = _stacked_init(
            lambda k: _ssm_block_init(k, cfg, dtype), ks[3], n_macro * g)
        if rem:
            params["layers_rem"] = _stacked_init(
                lambda k: _ssm_block_init(k, cfg, dtype), ks[4], rem)
        params["shared_attn"] = _attn_block_init(
            ks[5], cfg, cfg.hybrid.shared_d_ff, dtype)
    else:
        raise ValueError(f"unknown arch_type {cfg.arch_type}")
    return params


# ---------------------------------------------------------------------------
# Embedding of (prefix, tokens)
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                 prefix_embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
    x = embed(params["embed"], tokens)
    if prefix_embeds is not None:
        proj = prefix_embeds.astype(x.dtype) @ params["prefix_proj"]["w"]
        x = jnp.concatenate([proj, x], axis=1)
    return x.astype(jnp.dtype(cfg.compute_dtype))


# ---------------------------------------------------------------------------
# Blocks (single layer, unstacked params)
# ---------------------------------------------------------------------------

def _attn_block_forward(p: Params, x, cfg: ModelConfig, window,
                        block=None, unroll=False):
    h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    x = x + attn_lib.attention_forward(
        p["attn"], h, rope_theta=cfg.rope_theta, window=window, block=block,
        unroll=unroll)
    h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], h)


def _moe_block_forward(p: Params, x, cfg: ModelConfig, window, block=None,
                       unroll=False):
    h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
    x = x + attn_lib.attention_forward(
        p["attn"], h, rope_theta=cfg.rope_theta, window=window, block=block,
        unroll=unroll)
    h = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
    out, aux = moe_lib.moe_forward(p["moe"], h, cfg.moe)
    return x + out, aux


def _ssm_block_forward(p: Params, x, cfg: ModelConfig):
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    return x + ssm_lib.ssm_forward(p["ssm"], h, cfg.d_model, cfg.ssm)


_ZERO_AUX = {"load_balance": 0.0, "router_z": 0.0, "dropped_frac": 0.0}


# ---------------------------------------------------------------------------
# Forward (teacher-forced scoring)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
            prefix_embeds: Optional[jnp.ndarray] = None, *,
            window: Optional[int] = None,
            remat: bool = False,
            block: Optional[int] = None,
            unroll: bool = False,
            act_sharding=None,
            head: bool = True) -> Dict[str, jnp.ndarray]:
    """Returns {"hidden": [B,S,d], "logits": [B,S,Va] (f32), "aux": {...}}.

    ``head=False`` skips the action-head projection (``logits`` is None) —
    the fused-loss path applies the head blockwise inside the loss kernel
    instead of materializing [B, S, Va] logits here.

    ``act_sharding`` (a NamedSharding over [B, S, d]) pins the layer-scan
    carry — i.e. the remat-saved residual stream — to an explicit layout
    (batch on data, d_model on model). Without it GSPMD may save carries
    with the batch axis replicated, blowing up the remat stack 16x on
    large models (EXPERIMENTS.md §Perf).
    """
    x = embed_inputs(cfg, params, tokens, prefix_embeds)

    def _pin(h):
        if act_sharding is not None:
            return jax.lax.with_sharding_constraint(h, act_sharding)
        return h

    x = _pin(x)

    if cfg.arch_type in ("dense", "audio", "vlm"):
        ur = cfg.num_layers if unroll else 1

        def body(carry, layer_p):
            return _pin(_attn_block_forward(layer_p, carry, cfg, window,
                                            block, unroll)), None
        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"], unroll=ur)
        aux = dict(_ZERO_AUX)
    elif cfg.arch_type == "moe":
        ur = cfg.num_layers if unroll else 1

        def body(carry, layer_p):
            out, aux = _moe_block_forward(layer_p, carry, cfg, window, block,
                                          unroll)
            return _pin(out), aux
        if remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, params["layers"], unroll=ur)
        aux = jax.tree.map(jnp.sum, auxs)
        aux["dropped_frac"] = aux["dropped_frac"] / cfg.num_layers
    elif cfg.arch_type == "ssm":
        ur = cfg.num_layers if unroll else 1

        def body(carry, layer_p):
            return _pin(_ssm_block_forward(layer_p, carry, cfg)), None
        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"], unroll=ur)
        aux = dict(_ZERO_AUX)
    elif cfg.arch_type == "hybrid":
        n_macro, g, rem = hybrid_layout(cfg)
        stacked = jax.tree.map(
            lambda a: a.reshape((n_macro, g) + a.shape[1:]), params["layers"])

        def inner(carry, layer_p):
            return _ssm_block_forward(layer_p, carry, cfg), None

        def macro(carry, macro_p):
            h = _attn_block_forward(params["shared_attn"], carry, cfg,
                                    window, block, unroll)
            h, _ = jax.lax.scan(inner, h, macro_p, unroll=g if unroll else 1)
            return _pin(h), None
        if remat:
            macro = jax.checkpoint(macro)
        x, _ = jax.lax.scan(macro, x, stacked,
                            unroll=n_macro if unroll else 1)
        if rem:
            x = _attn_block_forward(params["shared_attn"], x, cfg, window,
                                    block, unroll)
            x, _ = jax.lax.scan(inner, x, params["layers_rem"],
                                unroll=rem if unroll else 1)
        aux = dict(_ZERO_AUX)
    else:
        raise ValueError(cfg.arch_type)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = action_head(params["action_head"], x) if head else None
    return {"hidden": x, "logits": logits, "aux": aux}


# ---------------------------------------------------------------------------
# Decode cache init
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, cache_len: int, *,
                      window: Optional[int] = None) -> DecodeCache:
    dtype = jnp.dtype(cfg.compute_dtype)
    eff_len = min(cache_len, window) if window else cache_len
    attn_cache = None
    ssm_cache = None
    if cfg.arch_type in ("dense", "audio", "vlm", "moe"):
        def one(_):
            return attn_lib.init_cache(batch, eff_len, cfg.num_kv_heads,
                                       cfg.head_dim, dtype)
        attn_cache = jax.vmap(one)(jnp.arange(cfg.num_layers))
    elif cfg.arch_type == "ssm":
        def one(_):
            return ssm_lib.init_ssm_state(batch, cfg.d_model, cfg.ssm, dtype)
        ssm_cache = jax.vmap(one)(jnp.arange(cfg.num_layers))
    elif cfg.arch_type == "hybrid":
        n_shared = num_shared_applications(cfg)

        def one_a(_):
            return attn_lib.init_cache(batch, eff_len, cfg.num_kv_heads,
                                       cfg.head_dim, dtype)
        attn_cache = jax.vmap(one_a)(jnp.arange(n_shared))

        def one_s(_):
            return ssm_lib.init_ssm_state(batch, cfg.d_model, cfg.ssm, dtype)
        ssm_cache = jax.vmap(one_s)(jnp.arange(cfg.num_layers))
    return DecodeCache(attn=attn_cache, ssm=ssm_cache)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
            prefix_embeds: Optional[jnp.ndarray] = None, *,
            cache_len: Optional[int] = None,
            window: Optional[int] = None,
            block: Optional[int] = None,
            unroll: bool = False
            ) -> Tuple[Dict[str, jnp.ndarray], DecodeCache]:
    x = embed_inputs(cfg, params, tokens, prefix_embeds)
    b, t, _ = x.shape
    cache_len = cache_len or t
    eff_len = min(cache_len, window) if window else cache_len

    def attn_sub(p, h):
        hn = rmsnorm(p["attn_norm"], h, cfg.norm_eps)
        out, cache = attn_lib.attention_prefill(
            p["attn"], hn, rope_theta=cfg.rope_theta, cache_len=eff_len,
            window=window, block=block, unroll=unroll)
        return h + out, cache

    if cfg.arch_type in ("dense", "audio", "vlm", "moe"):
        def body(carry, layer_p):
            h, cache = attn_sub(layer_p, carry)
            hn = rmsnorm(layer_p["mlp_norm"], h, cfg.norm_eps)
            if cfg.arch_type == "moe":
                out, _ = moe_lib.moe_forward(layer_p["moe"], hn, cfg.moe)
            else:
                out = mlp(layer_p["mlp"], hn)
            return h + out, cache
        x, attn_cache = jax.lax.scan(body, x, params["layers"],
                                     unroll=cfg.num_layers if unroll else 1)
        cache = DecodeCache(attn=attn_cache, ssm=None)
    elif cfg.arch_type == "ssm":
        def body(carry, layer_p):
            hn = rmsnorm(layer_p["norm"], carry, cfg.norm_eps)
            out, st = ssm_lib.ssm_forward(layer_p["ssm"], hn, cfg.d_model,
                                          cfg.ssm, return_state=True)
            return carry + out, st
        x, ssm_cache = jax.lax.scan(body, x, params["layers"],
                                    unroll=cfg.num_layers if unroll else 1)
        cache = DecodeCache(attn=None, ssm=ssm_cache)
    elif cfg.arch_type == "hybrid":
        n_macro, g, rem = hybrid_layout(cfg)
        stacked = jax.tree.map(
            lambda a: a.reshape((n_macro, g) + a.shape[1:]), params["layers"])

        def inner(carry, layer_p):
            hn = rmsnorm(layer_p["norm"], carry, cfg.norm_eps)
            out, st = ssm_lib.ssm_forward(layer_p["ssm"], hn, cfg.d_model,
                                          cfg.ssm, return_state=True)
            return carry + out, st

        def macro(carry, macro_p):
            h, kv = attn_sub(params["shared_attn"], carry)
            hn = rmsnorm(params["shared_attn"]["mlp_norm"], h, cfg.norm_eps)
            h = h + mlp(params["shared_attn"]["mlp"], hn)
            h, sts = jax.lax.scan(inner, h, macro_p,
                                  unroll=g if unroll else 1)
            return h, (kv, sts)
        x, (kv_macro, ssm_macro) = jax.lax.scan(
            macro, x, stacked, unroll=n_macro if unroll else 1)
        # flatten [n_macro, g, ...] -> [n_macro*g, ...]
        ssm_flat = jax.tree.map(
            lambda a: a.reshape((n_macro * g,) + a.shape[2:]), ssm_macro)
        kv_all = kv_macro
        if rem:
            h, kv_r = attn_sub(params["shared_attn"], x)
            hn = rmsnorm(params["shared_attn"]["mlp_norm"], h, cfg.norm_eps)
            h = h + mlp(params["shared_attn"]["mlp"], hn)
            x, ssm_rem = jax.lax.scan(inner, h, params["layers_rem"],
                                      unroll=rem if unroll else 1)
            kv_all = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b[None]], axis=0),
                kv_macro, kv_r)
            ssm_flat = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0),
                ssm_flat, ssm_rem)
        cache = DecodeCache(attn=kv_all, ssm=ssm_flat)
    else:
        raise ValueError(cfg.arch_type)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = action_head(params["action_head"], x)
    return {"hidden": x, "logits": logits}, cache


# ---------------------------------------------------------------------------
# Decode (one token)
# ---------------------------------------------------------------------------

def decode(cfg: ModelConfig, params: Params, token: jnp.ndarray,
           cache: DecodeCache, *, window: Optional[int] = None,
           unroll: bool = False, uniform: bool = False
           ) -> Tuple[Dict[str, jnp.ndarray], DecodeCache]:
    """token: [B] or [B,1] int32 -> logits [B, 1, Va]."""
    if token.ndim == 1:
        token = token[:, None]
    x = embed(params["embed"], token).astype(jnp.dtype(cfg.compute_dtype))

    def attn_sub(p, h, kv):
        hn = rmsnorm(p["attn_norm"], h, cfg.norm_eps)
        out, kv = attn_lib.attention_decode(
            p["attn"], hn, kv, rope_theta=cfg.rope_theta, window=window,
            uniform=uniform)
        return h + out, kv

    if cfg.arch_type in ("dense", "audio", "vlm", "moe"):
        def body(carry, scanned):
            layer_p, kv = scanned
            h, kv = attn_sub(layer_p, carry, kv)
            hn = rmsnorm(layer_p["mlp_norm"], h, cfg.norm_eps)
            if cfg.arch_type == "moe":
                out, _ = moe_lib.moe_forward(layer_p["moe"], hn, cfg.moe)
            else:
                out = mlp(layer_p["mlp"], hn)
            return h + out, kv
        x, attn_cache = jax.lax.scan(body, x, (params["layers"], cache.attn),
                                     unroll=cfg.num_layers if unroll else 1)
        new_cache = DecodeCache(attn=attn_cache, ssm=None)
    elif cfg.arch_type == "ssm":
        def body(carry, scanned):
            layer_p, st = scanned
            hn = rmsnorm(layer_p["norm"], carry, cfg.norm_eps)
            out, st = ssm_lib.ssm_decode(layer_p["ssm"], hn, st, cfg.d_model,
                                         cfg.ssm)
            return carry + out, st
        x, ssm_cache = jax.lax.scan(body, x, (params["layers"], cache.ssm),
                                    unroll=cfg.num_layers if unroll else 1)
        new_cache = DecodeCache(attn=None, ssm=ssm_cache)
    elif cfg.arch_type == "hybrid":
        n_macro, g, rem = hybrid_layout(cfg)
        stacked = jax.tree.map(
            lambda a: a.reshape((n_macro, g) + a.shape[1:]), params["layers"])
        ssm_macro = jax.tree.map(
            lambda a: a.reshape((n_macro, g) + a.shape[1:]),
            jax.tree.map(lambda a: a[:n_macro * g], cache.ssm))
        kv_macro = jax.tree.map(lambda a: a[:n_macro], cache.attn)

        def inner(carry, scanned):
            layer_p, st = scanned
            hn = rmsnorm(layer_p["norm"], carry, cfg.norm_eps)
            out, st = ssm_lib.ssm_decode(layer_p["ssm"], hn, st, cfg.d_model,
                                         cfg.ssm)
            return carry + out, st

        def macro(carry, scanned):
            macro_p, kv, sts = scanned
            h, kv = attn_sub(params["shared_attn"], carry, kv)
            hn = rmsnorm(params["shared_attn"]["mlp_norm"], h, cfg.norm_eps)
            h = h + mlp(params["shared_attn"]["mlp"], hn)
            h, sts = jax.lax.scan(inner, h, (macro_p, sts),
                                  unroll=g if unroll else 1)
            return h, (kv, sts)
        x, (kv_new, ssm_new) = jax.lax.scan(
            macro, x, (stacked, kv_macro, ssm_macro),
            unroll=n_macro if unroll else 1)
        ssm_flat = jax.tree.map(
            lambda a: a.reshape((n_macro * g,) + a.shape[2:]), ssm_new)
        kv_all = kv_new
        if rem:
            kv_r = jax.tree.map(lambda a: a[n_macro], cache.attn)
            ssm_r = jax.tree.map(lambda a: a[n_macro * g:], cache.ssm)
            h, kv_r = attn_sub(params["shared_attn"], x, kv_r)
            hn = rmsnorm(params["shared_attn"]["mlp_norm"], h, cfg.norm_eps)
            h = h + mlp(params["shared_attn"]["mlp"], hn)
            x, ssm_r = jax.lax.scan(inner, h, (params["layers_rem"], ssm_r),
                                    unroll=rem if unroll else 1)
            kv_all = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b[None]], axis=0),
                kv_new, kv_r)
            ssm_flat = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), ssm_flat, ssm_r)
        new_cache = DecodeCache(attn=kv_all, ssm=ssm_flat)
    else:
        raise ValueError(cfg.arch_type)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = action_head(params["action_head"], x)
    return {"hidden": x, "logits": logits}, new_cache
