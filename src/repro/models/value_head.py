"""Action-aware attention-pooling value head (paper App. D.2).

Pools the action-token hidden states with a learned attention score, adds a
step embedding (value depends on the remaining horizon), and regresses
V(o_t) with a small MLP. Hidden states are detached (``stop_gradient``) so
value gradients never touch the policy representation — exactly the paper's
``hidden_states.detach()``.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init


def value_head_init(key, hidden_dim: int, max_episode_steps: int) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "attn_proj": dense_init(k1, (hidden_dim, 1), jnp.float32),
        "step_emb": dense_init(k2, (max_episode_steps, hidden_dim),
                               jnp.float32, scale=1.0),
        "mlp_w1": dense_init(k3, (hidden_dim, hidden_dim), jnp.float32),
        "mlp_b1": jnp.zeros((hidden_dim,), jnp.float32),
        "mlp_w2": dense_init(k4, (hidden_dim, 1), jnp.float32),
        "mlp_b2": jnp.zeros((1,), jnp.float32),
    }


def value_head(params: Params, hidden_states: jnp.ndarray,
               step_t: jnp.ndarray) -> jnp.ndarray:
    """hidden_states: [B, S, D] (action-token hiddens); step_t: [B] int32.

    Returns V(s_t): [B].
    """
    h = jax.lax.stop_gradient(hidden_states).astype(jnp.float32)
    e = h @ params["attn_proj"]                       # [B, S, 1]
    alpha = jax.nn.softmax(e, axis=1)
    z_pool = jnp.sum(alpha * h, axis=1)               # [B, D]
    max_steps = params["step_emb"].shape[0]
    e_step = jnp.take(params["step_emb"],
                      jnp.clip(step_t, 0, max_steps - 1), axis=0)
    x = z_pool + e_step
    x = jax.nn.gelu(x @ params["mlp_w1"] + params["mlp_b1"])
    v = x @ params["mlp_w2"] + params["mlp_b2"]
    return v[:, 0]


def value_head_seq(params: Params, hidden_states: jnp.ndarray,
                   steps: jnp.ndarray) -> jnp.ndarray:
    """Per-timestep values over a trajectory.

    hidden_states: [B, T, S, D] — S action-token hiddens per env step;
    steps: [B, T] episode-step indices. Returns [B, T].
    """
    return jax.vmap(value_head, in_axes=(None, 1, 1), out_axes=1)(
        params, hidden_states, steps)
