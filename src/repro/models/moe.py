"""Top-k mixture-of-experts with GShard-style grouped capacity dispatch.

Dispatch is expressed as dense einsums over a per-group
[tokens, experts, capacity] one-hot combine tensor so GSPMD can turn the
expert dimension into an all-to-all when experts are sharded over the
``model`` mesh axis. Tokens are processed in fixed-size groups
(``group_tokens``) to bound the combine-tensor working set — the group size
is a perf knob surfaced in EXPERIMENTS.md §Perf.

The auxiliary load-balance and router-z losses are returned so the RL
train step can fold them into the GIPO objective.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import Params, dense_init

GROUP_TOKENS = 512


def moe_init(key, d_model: int, cfg: MoEConfig, dtype) -> Params:
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, ff = cfg.num_experts, cfg.d_ff
    return {
        "router": dense_init(kr, (d_model, e), jnp.float32),
        "w_gate": dense_init(kg, (e, d_model, ff), dtype),
        "w_up": dense_init(ku, (e, d_model, ff), dtype),
        "w_down": dense_init(kd, (e, ff, d_model), dtype),
    }


def capacity(group_tokens: int, cfg: MoEConfig) -> int:
    cap = int(cfg.capacity_factor * group_tokens * cfg.top_k / cfg.num_experts)
    return max(cap, cfg.top_k)


def _group_dispatch(params: Params, xg: jnp.ndarray, cfg: MoEConfig,
                    cap: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """xg: [n, d] one token group. Returns (out [n, d], logits [n, e], kept)."""
    n, d = xg.shape
    e, k = cfg.num_experts, cfg.top_k

    logits = (xg.astype(jnp.float32) @ params["router"])          # [n, e]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # [n, k]
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)       # [n, k, e]
    # GShard priority: all 1st choices, then 2nd choices, ...
    prio = onehot.transpose(1, 0, 2).reshape(k * n, e)
    pos_prio = jnp.cumsum(prio, axis=0) - prio
    within = (pos_prio.reshape(k, n, e).transpose(1, 0, 2) * onehot).sum(-1)
    keep = within < cap                                           # [n, k]
    gates = (gate_vals * keep).astype(xg.dtype)

    cap_onehot = jax.nn.one_hot(jnp.where(keep, within, cap), cap + 1,
                                dtype=xg.dtype)[..., :cap]        # [n, k, cap]
    combine = jnp.einsum("nk,nke,nkc->nec", gates,
                         onehot.astype(xg.dtype), cap_onehot)     # [n, e, cap]
    dispatch = (combine > 0).astype(xg.dtype)

    expert_in = jnp.einsum("nd,nec->ecd", xg, dispatch)           # [e, cap, d]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # [e, cap, d]
    out = jnp.einsum("ecd,nec->nd", expert_out, combine)
    return out, logits, keep


def moe_forward(params: Params, x: jnp.ndarray, cfg: MoEConfig,
                group_tokens: int = GROUP_TOKENS
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: [B, T, d] -> (out [B, T, d], aux losses)."""
    b, t, d = x.shape
    n = b * t
    g = max(n // group_tokens, 1)
    ng = n // g
    xf = x.reshape(g, ng, d)
    cap = capacity(ng, cfg)

    out, logits, keep = jax.vmap(
        lambda xg: _group_dispatch(params, xg, cfg, cap))(xf)

    e = cfg.num_experts
    logits2 = logits.reshape(n, e)
    probs2 = jax.nn.softmax(logits2, axis=-1)
    top1 = jnp.argmax(probs2, axis=-1)
    me = probs2.mean(axis=0)
    ce = jax.nn.one_hot(top1, e).mean(axis=0)
    load_balance = e * jnp.sum(me * ce)
    router_z = jnp.mean(
        jax.scipy.special.logsumexp(logits2, axis=-1) ** 2)
    aux = {
        "load_balance": cfg.load_balance_coef * load_balance,
        "router_z": cfg.router_z_coef * router_z,
        "dropped_frac": 1.0 - keep.mean(),
    }
    return out.reshape(b, t, d), aux
