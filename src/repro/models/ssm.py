"""Mamba2 (SSD — state-space duality) block: chunked dual form for
training/prefill and O(1)-state recurrent decode.

The chunked algorithm follows arXiv:2405.21060: intra-chunk terms are dense
matmuls (MXU-friendly), inter-chunk terms are a short ``lax.scan`` over chunk
states. A step-equivalent recurrent path backs single-token decode; tests
assert the two paths agree (the SSD "duality").
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import Params, dense_init


class SSMState(NamedTuple):
    conv: jnp.ndarray     # [B, K-1, conv_channels] rolling conv input tail
    ssm: jnp.ndarray      # [B, H, P, N] recurrent state
    length: jnp.ndarray   # [B] int32


def ssm_init(key, d_model: int, cfg: SSMConfig, dtype) -> Params:
    di = cfg.d_inner(d_model)
    nh = cfg.num_heads(d_model)
    g, n, kk = cfg.n_groups, cfg.state_dim, cfg.conv_dim
    conv_ch = di + 2 * g * n
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    dt = jnp.exp(jax.random.uniform(k4, (nh,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    params = {
        "conv_w": dense_init(k2, (kk, conv_ch), dtype, scale=1.0),
        "conv_b": jnp.zeros((conv_ch,), dtype=dtype),
        "A_log": jnp.log(jnp.ones((nh,), jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "norm_scale": jnp.ones((di,), dtype=dtype),
        "out_proj": dense_init(k3, (di, d_model), dtype),
    }
    if cfg.fused_in_proj:
        params["in_proj"] = dense_init(
            k1, (d_model, 2 * di + 2 * g * n + nh), dtype)
    else:
        # shard-aligned split projections (§Perf hillclimb): each output
        # axis is independently divisible by the model-parallel degree
        params["in_proj_z"] = dense_init(k1, (d_model, di), dtype)
        params["in_proj_x"] = dense_init(k5, (d_model, di + 2 * g * n),
                                         dtype)
        params["in_proj_dt"] = dense_init(k6, (d_model, nh), dtype)
    return params


def _split_proj(params: Params, u: jnp.ndarray, d_model: int, cfg: SSMConfig):
    di = cfg.d_inner(d_model)
    g, n = cfg.n_groups, cfg.state_dim
    nh = cfg.num_heads(d_model)
    if cfg.fused_in_proj:
        proj = u @ params["in_proj"]
        z, xbc, dt_raw = jnp.split(proj, [di, 2 * di + 2 * g * n], axis=-1)
    else:
        z = u @ params["in_proj_z"]
        xbc = u @ params["in_proj_x"]
        dt_raw = u @ params["in_proj_dt"]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])            # [..., nh]
    return z, xbc, dt, di, g, n, nh


def _causal_conv(params: Params, xbc: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time. xbc: [B, T, C]."""
    k = params["conv_w"].shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * params["conv_w"][i]
              for i in range(k))
    return jax.nn.silu(out + params["conv_b"])


def _gated_norm(params: Params, y: jnp.ndarray, z: jnp.ndarray,
                eps: float = 1e-5) -> jnp.ndarray:
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + eps)
    return (g * params["norm_scale"].astype(jnp.float32)).astype(y.dtype)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                init_state: jnp.ndarray = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    x: [B,T,H,P]; dt: [B,T,H] (f32, post-softplus); A: [H] (negative);
    Bm/Cm: [B,T,N] (single group, broadcast over heads).
    Returns (y [B,T,H,P], final_state [B,H,P,N]).
    """
    b, t, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, t)
    assert t % q == 0, f"seq {t} not divisible by chunk {q}"
    nc = t // q

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = Bm.reshape(b, nc, q, n)
    Cc = Cm.reshape(b, nc, q, n)

    dA = dtc * A[None, None, None, :]                    # [b,nc,q,h] (<= 0)
    cum = jnp.cumsum(dA, axis=2)                         # inclusive
    cum_total = cum[:, :, -1:, :]                        # [b,nc,1,h]

    # intra-chunk (dense, MXU):
    # y_intra[i] = sum_{j<=i} (C_i·B_j) exp(cum_i - cum_j) dt_j x_j
    mask = jnp.tril(jnp.ones((q, q), bool))
    G = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [b,nc,i,j,h]
    G = jnp.where(mask[None, None, :, :, None], G, 0.0)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    W = CB[..., None] * G * dtc[:, :, None, :, :]        # [b,nc,i,j,h]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xc.astype(jnp.float32))

    # chunk input states: S_c = sum_j exp(cum_q - cum_j) dt_j B_j x_j^T
    decay_in = jnp.exp(cum_total - cum) * dtc            # [b,nc,q,h]
    S_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", decay_in,
                         Bc.astype(jnp.float32), xc.astype(jnp.float32))

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum_total[:, :, 0, :])         # [b,nc,h]
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def body(S, inputs):
        S_c, dec = inputs                                # [b,h,p,n], [b,h]
        S_in = S                                         # state entering chunk
        S = dec[:, :, None, None] * S + S_c
        return S, S_in

    S_cs = jnp.moveaxis(S_chunk, 1, 0)                   # [nc,b,h,p,n]
    decs = jnp.moveaxis(chunk_decay, 1, 0)               # [nc,b,h]
    S_final, S_enter = jax.lax.scan(body, init_state.astype(jnp.float32),
                                    (S_cs, decs))

    # inter contribution: y_inter[i] = exp(cum_i) * C_i · S_enter
    S_enter = jnp.moveaxis(S_enter, 0, 1)                # [b,nc,h,p,n]
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         Cc.astype(jnp.float32), S_enter, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, t, h, p)
    return y, S_final


def ssd_recurrent_step(state: jnp.ndarray, x_t: jnp.ndarray, dt_t: jnp.ndarray,
                       A: jnp.ndarray, B_t: jnp.ndarray, C_t: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One recurrent step. state: [B,H,P,N]; x_t: [B,H,P]; dt_t: [B,H];
    B_t/C_t: [B,N]. Returns (y_t [B,H,P], new_state)."""
    dA = jnp.exp(dt_t * A[None, :])                      # [B,H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t, x_t.astype(jnp.float32),
                     B_t.astype(jnp.float32))
    new_state = dA[:, :, None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t.astype(jnp.float32))
    return y, new_state


def ssm_forward(params: Params, u: jnp.ndarray, d_model: int, cfg: SSMConfig,
                init_state: SSMState = None,
                return_state: bool = False):
    """Full-sequence Mamba2 block. u: [B, T, d_model]."""
    b, t, _ = u.shape
    z, xbc_raw, dt, di, g, n, nh = _split_proj(params, u, d_model, cfg)
    p = cfg.head_dim
    kk = cfg.conv_dim

    if init_state is not None:
        tail = init_state.conv                            # [B, K-1, C]
        padded = jnp.concatenate([tail, xbc_raw], axis=1)
        conv_out = sum(padded[:, i:i + t] * params["conv_w"][i]
                       for i in range(kk))
        xbc = jax.nn.silu(conv_out + params["conv_b"])
        ssm0 = init_state.ssm
    else:
        xbc = _causal_conv(params, xbc_raw)
        ssm0 = None

    xs, Bm, Cm = jnp.split(xbc, [di, di + g * n], axis=-1)
    x = xs.reshape(b, t, nh, p)
    A = -jnp.exp(params["A_log"])
    if ssm0 is None:
        # fresh-sequence scan goes through the kernel dispatch layer
        # (Pallas on TPU, this module's chunked jnp form elsewhere);
        # carried-state prefill keeps the jnp path below
        from repro.kernels import dispatch
        y, S_final = dispatch.ssd_scan(x, dt, A, Bm, Cm, chunk=cfg.chunk)
    else:
        y, S_final = ssd_chunked(x, dt, A, Bm, Cm, cfg.chunk,
                                 init_state=ssm0)
    y = y + params["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, t, di).astype(u.dtype)
    out = _gated_norm(params, y, z) @ params["out_proj"]
    if not return_state:
        return out
    new_tail = jnp.concatenate(
        [jnp.zeros((b, max(kk - 1 - t, 0), xbc_raw.shape[-1]),
                   xbc_raw.dtype), xbc_raw[:, -(kk - 1):]], axis=1) \
        if t < kk - 1 else xbc_raw[:, -(kk - 1):]
    length = (init_state.length if init_state is not None
              else jnp.zeros((b,), jnp.int32)) + t
    return out, SSMState(conv=new_tail, ssm=S_final, length=length)


def ssm_decode(params: Params, u: jnp.ndarray, state: SSMState, d_model: int,
               cfg: SSMConfig) -> Tuple[jnp.ndarray, SSMState]:
    """Single-token recurrent decode. u: [B, 1, d_model]."""
    b = u.shape[0]
    z, xbc_raw, dt, di, g, n, nh = _split_proj(params, u, d_model, cfg)
    kk = cfg.conv_dim
    p = cfg.head_dim

    window = jnp.concatenate([state.conv, xbc_raw], axis=1)   # [B, K, C]
    conv_out = jnp.einsum("bkc,kc->bc", window, params["conv_w"])
    xbc = jax.nn.silu(conv_out + params["conv_b"])[:, None, :]

    xs, Bm, Cm = jnp.split(xbc, [di, di + g * n], axis=-1)
    x_t = xs[:, 0].reshape(b, nh, p)
    A = -jnp.exp(params["A_log"])
    y_t, new_ssm = ssd_recurrent_step(state.ssm, x_t, dt[:, 0], A,
                                      Bm[:, 0], Cm[:, 0])
    y_t = y_t + params["D"][None, :, None] * x_t.astype(jnp.float32)
    y = y_t.reshape(b, 1, di).astype(u.dtype)
    out = _gated_norm(params, y, z) @ params["out_proj"]
    new_state = SSMState(conv=window[:, 1:], ssm=new_ssm,
                         length=state.length + 1)
    return out, new_state


def init_ssm_state(batch: int, d_model: int, cfg: SSMConfig,
                   dtype) -> SSMState:
    di = cfg.d_inner(d_model)
    nh = cfg.num_heads(d_model)
    conv_ch = di + 2 * cfg.n_groups * cfg.state_dim
    return SSMState(
        conv=jnp.zeros((batch, cfg.conv_dim - 1, conv_ch), dtype=dtype),
        ssm=jnp.zeros((batch, nh, cfg.head_dim, cfg.state_dim), jnp.float32),
        length=jnp.zeros((batch,), jnp.int32),
    )
