"""Common model primitives: RMSNorm, RoPE, SwiGLU, embeddings, init utils.

All modules are (init, apply) pairs over plain-dict pytrees — no framework
dependency, so the same code paths run under jit, shard_map, and
``jax.eval_shape`` (the dry-run never allocates real parameters).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


Params = Dict[str, jnp.ndarray]


def _dtype(name: str):
    return jnp.dtype(name)


def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale / (fan_in ** 0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    orig_dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(orig_dtype)


# --------------------------------------------------------------------------
# Rotary position embedding
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., T, H, D]; positions: broadcastable to [..., T]."""
    freqs = rope_frequencies(x.shape[-1], theta)          # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, D/2]
    angles = angles[..., None, :]                         # [..., T, 1, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(x @ params["w_gate"])
    return (gate * (x @ params["w_up"])) @ params["w_down"]


# --------------------------------------------------------------------------
# Embedding + slimmed action head (paper App. D.1)
# --------------------------------------------------------------------------

def embedding_init(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": dense_init(key, (vocab, d_model), dtype, scale=1.0)}


def embed(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], tokens, axis=0)


def action_head_init(key, d_model: int, action_vocab: int, dtype) -> Params:
    return {"w": dense_init(key, (d_model, action_vocab), dtype)}


def action_head(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    # Logits in f32 for a numerically stable softmax/log-softmax downstream.
    return (x @ params["w"]).astype(jnp.float32)


def slim_lm_head(full_head_w: jnp.ndarray, start: int, end: int) -> Params:
    """Paper App. D.1: crop [d_model, vocab] -> [d_model, n_actions] in place.

    ``full_head_w`` is the pretrained lm_head weight; [start, end) is the
    action-token range of the original vocabulary.
    """
    return {"w": full_head_w[:, start:end]}
