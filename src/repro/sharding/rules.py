"""Logical-axis partition rules for every architecture family.

Parameters are plain-dict pytrees; specs are assigned by matching the leaf
*path* (e.g. ``layers/attn/wq``) against a rules table of *candidate*
shardings. Each candidate is ``(axis_index, mesh_axis_or_tuple)``; the
first candidate whose dimension is divisible by the mesh-axis size wins,
so one rules table covers all ten assigned architectures (head counts,
KV-group counts, vocab sizes and expert counts all differ in divisibility).

Baseline layout (DESIGN.md §5):
  * ``model`` — tensor parallel: heads / d_ff / experts / vocab
  * ``data``  — batch; Adam moments additionally ZeRO-2-sharded on it;
    for >30B-param archs the expert/ff axes are *also* sharded on ``data``
    (FSDP-style) so dbrx-132b fits in 16 GB/chip.
  * ``pod``   — outermost data-parallel axis in the multi-pod mesh.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

Axis = Union[str, Tuple[str, ...]]
Candidate = Tuple[int, Axis]

# FSDP threshold: above this parameter count, weight matrices are also
# sharded over ``data`` (granite-20b/starcoder2/dbrx: f32 gradients at
# tensor-parallel-only sharding would alone eat 5-7 GB of the 16 GB HBM).
FSDP_PARAM_THRESHOLD = 12_000_000_000


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _first_fit(shape: Sequence[int], candidates: List[Candidate],
               mesh: Mesh, taken: Optional[Dict[int, Axis]] = None
               ) -> Dict[int, Axis]:
    """Greedy multi-axis assignment: place each candidate mesh axis on the
    first tensor dim that divides, skipping dims already taken."""
    out: Dict[int, Axis] = dict(taken or {})
    used_mesh = {a for ax in out.values()
                 for a in (ax if isinstance(ax, tuple) else (ax,))}
    for idx, axis in candidates:
        names = axis if isinstance(axis, tuple) else (axis,)
        if any(a in used_mesh for a in names):
            continue
        i = idx if idx >= 0 else len(shape) + idx
        if i in out or i < 0 or i >= len(shape):
            continue
        if shape[i] % _axis_size(mesh, axis) == 0 and shape[i] > 1:
            out[i] = axis
            used_mesh.update(names)
    return out


def _to_spec(shape: Sequence[int], assign: Dict[int, Axis]) -> P:
    return P(*[assign.get(i) for i in range(len(shape))])


# ---------------------------------------------------------------------------
# Rules table — matched against the '/'-joined leaf path.
# ``{S}`` marks entries whose axis indices are relative to the *unstacked*
# tensor; a leading layer-stack dim shifts them by +1 automatically.
# ---------------------------------------------------------------------------

# Hillclimb toggle (EXPERIMENTS.md §Perf, musicgen): when head count does
# not divide the model axis, prefer sharding the CONTRACTING d_model axis
# (one all-reduce per layer) over head_dim (an all-reduce per KV block).
ATTN_PREFER_DMODEL = False

# (pattern, tp_candidates, fsdp_candidates)
_RULES: List[Tuple[str, List[Candidate], List[Candidate]]] = [
    # embedding: vocab on model, fallback d_model
    (r"embed/table$", [(0, "model"), (1, "model")], [(1, "data")]),
    (r"action_head/w$", [(1, "model"), (0, "model")], []),
    (r"prefix_proj/w$", [(1, "model")], []),
    # attention
    (r"attn/wq$", [(1, "model"), (2, "model"), (0, "model")], [(0, "data")]),
    (r"attn/wk$", [(1, "model"), (2, "model"), (0, "model")], [(0, "data")]),
    (r"attn/wv$", [(1, "model"), (2, "model"), (0, "model")], [(0, "data")]),
    (r"attn/wo$", [(0, "model"), (1, "model"), (2, "model")], [(2, "data")]),
    # dense MLP: d_ff on model
    (r"mlp/w_gate$", [(1, "model")], [(0, "data")]),
    (r"mlp/w_up$", [(1, "model")], [(0, "data")]),
    (r"mlp/w_down$", [(0, "model")], [(1, "data")]),
    # MoE: experts on model, per-expert ff on data when FSDP
    (r"moe/router$", [], []),
    (r"moe/w_gate$", [(0, "model")], [(2, "data")]),
    (r"moe/w_up$", [(0, "model")], [(2, "data")]),
    (r"moe/w_down$", [(0, "model")], [(1, "data")]),
    # Mamba2 / SSD
    (r"ssm/in_proj$", [(1, "model")], [(0, "data")]),
    (r"ssm/in_proj_z$", [(1, "model")], [(0, "data")]),
    (r"ssm/in_proj_x$", [(1, "model")], [(0, "data")]),
    (r"ssm/in_proj_dt$", [(1, "model")], [(0, "data")]),
    (r"ssm/conv_w$", [(1, "model")], []),
    (r"ssm/conv_b$", [(0, "model")], []),
    (r"ssm/out_proj$", [(0, "model")], [(1, "data")]),
    (r"ssm/norm_scale$", [(0, "model")], []),
    # value head (f32): the hidden MLP is d×d — shard its wide axis
    (r"value_head/mlp_w1$", [(1, "model")], [(0, "data")]),
    (r"value_head/step_emb$", [(1, "model")], []),
    # everything small (norm scales, A_log, D, dt_bias, biases)
    (r".*", [], []),
]

# Tensors whose *unstacked* attention variants appear inside attn/wq etc. —
# the attention head axis of wq is index 1 post-d_model? No: wq is
# [d, H, hd]; candidates above index the unstacked tensor, preferring the
# head axis (1) and falling back to d_model (0 via index 2? see note).
# NOTE: for wq [d, H, hd] the candidate list (1, 'model') = heads,
# (2, 'model') = head_dim, (0, 'model') = d_model; musicgen (H=24) falls
# through to head_dim (64 % 16 == 0).


def _match(path: str) -> Tuple[List[Candidate], List[Candidate]]:
    for pat, tp, fsdp in _RULES:
        if re.search(pat, path):
            if ATTN_PREFER_DMODEL and pat.startswith(r"attn/w"):
                if pat == r"attn/wo$":
                    tp = [(0, "model"), (2, "model"), (1, "model")]
                else:
                    tp = [(1, "model"), (0, "model"), (2, "model")]
            return tp, fsdp
    return [], []


def _leaf_path(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "name", p))))
    return "/".join(parts)


def _is_stacked(path: str) -> bool:
    return path.startswith(("layers/", "layers_rem/")) \
        or "/layers/" in path or "/layers_rem/" in path


def param_specs(cfg: ModelConfig, param_shapes, mesh: Mesh,
                *, fsdp: Optional[bool] = None, tp: bool = True):
    """Partition-spec tree for a parameter pytree of ShapeDtypeStructs.

    ``tp=False`` (§Perf, pure data parallelism): parameters fully
    replicated — for models that fit per chip, dropping tensor parallelism
    removes every per-layer collective; only the gradient all-reduce
    remains."""
    if fsdp is None:
        fsdp = cfg.param_count() > FSDP_PARAM_THRESHOLD
    if not tp:
        return jax.tree.map(lambda leaf: P(*([None] * len(leaf.shape))),
                            param_shapes)

    def assign(path, leaf):
        pstr = _leaf_path(path)
        shape = leaf.shape
        tp, fs = _match(pstr)
        shift = 1 if _is_stacked(pstr) else 0
        cands = [(i + shift if i >= 0 else i, a) for i, a in tp]
        if fsdp:
            cands += [(i + shift if i >= 0 else i, a) for i, a in fs]
        return _to_spec(shape, _first_fit(shape, cands, mesh))

    return jax.tree_util.tree_map_with_path(assign, param_shapes)


def batch_axes(mesh: Mesh) -> Axis:
    """The (composite) data-parallel axis: ('pod','data') on multi-pod."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def data_spec(mesh: Mesh, global_batch: int, ndim: int,
              *, seq_axis: Optional[int] = None,
              seq_len: int = 0) -> P:
    """Sharding for a batch tensor [B, ...]. Batch goes on the composite
    data axis when divisible; otherwise (long_500k, B=1) the sequence axis
    is sharded over ``data`` instead (context parallelism)."""
    dp = batch_axes(mesh)
    dp_size = _axis_size(mesh, tuple(dp))
    entries: List[Optional[Axis]] = [None] * ndim
    if global_batch % dp_size == 0 and global_batch > 1:
        entries[0] = tuple(dp) if len(dp) > 1 else dp[0]
    elif seq_axis is not None and seq_len % mesh.shape["data"] == 0:
        entries[seq_axis] = "data"
    return P(*entries)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def cache_specs(cfg: ModelConfig, cache_shapes, mesh: Mesh,
                global_batch: int, cache_len: int,
                seq_shard_model: bool = False):
    """Sharding for the DecodeCache pytree (leaves carry a leading stacked
    layer axis, then batch). Batch shards on data when divisible; for
    batch=1 long-context the KV sequence axis shards on data (context
    parallel). Head-ish axes go on model when divisible."""
    dp = batch_axes(mesh)
    dp_size = _axis_size(mesh, tuple(dp))
    batch_ok = global_batch % dp_size == 0 and global_batch > 1

    def assign(path, leaf):
        pstr = _leaf_path(path)
        shape = leaf.shape
        assign_map: Dict[int, Axis] = {}
        if batch_ok and len(shape) >= 2:
            assign_map[1] = tuple(dp) if len(dp) > 1 else dp[0]
        if pstr.endswith((".k", ".v", "/k", "/v")) or "positions" in pstr:
            # KVCache: [L, B, S, KV, hd]
            if not batch_ok and len(shape) >= 3 \
                    and shape[2] % mesh.shape["data"] == 0 and shape[2] > 1:
                assign_map[2] = "data"
            if seq_shard_model and len(shape) >= 3 \
                    and shape[2] % mesh.shape["model"] == 0:
                # flash-decoding context parallelism (§Perf): shard the KV
                # SEQUENCE over model; softmax combines partial (max, sum)
                assign_map[2] = ("data", "model") \
                    if assign_map.get(2) == "data" else "model"
            elif len(shape) == 5:
                assign_map.update(_first_fit(
                    shape, [(3, "model"), (4, "model")], mesh,
                    taken=assign_map))
        elif "ssm" in pstr and len(shape) == 5:
            # SSMState.ssm: [L, B, H, P, N] — heads on model
            assign_map.update(_first_fit(
                shape, [(2, "model"), (3, "model")], mesh, taken=assign_map))
        elif "conv" in pstr and len(shape) == 4:
            # SSMState.conv: [L, B, K-1, C] — channels on model
            assign_map.update(_first_fit(
                shape, [(3, "model")], mesh, taken=assign_map))
        return _to_spec(shape, assign_map)

    return jax.tree_util.tree_map_with_path(assign, cache_shapes)
