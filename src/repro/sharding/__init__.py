"""Partition rules mapping every architecture family onto the production
mesh (DESIGN.md §5)."""
from repro.sharding import rules  # noqa: F401
from repro.sharding.rules import (  # noqa: F401
    batch_axes,
    cache_specs,
    data_spec,
    param_specs,
)
