"""Roofline terms from the compiled dry-run (no real hardware):

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

``cost_analysis()`` gives HLO_FLOPs and HLO_bytes of the *partitioned*
(per-device) module, so terms are computed per chip directly. Collective
bytes are parsed from the post-optimization HLO text: the summed result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

# result of an HLO op line: `%name = TYPE[d0,d1]{layout} opcode(...)`
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
# tuple-result collectives: `= (f32[..]{..}, f32[..]{..}) all-reduce(`
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind collective result bytes from post-optimization HLO.

    ``-start`` variants are counted; their ``-done`` twins are skipped so
    async collectives are not double counted.
    """
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done(" in stripped:
            continue
        m = _OP_RE.search(stripped)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
            continue
        m = _TUPLE_RE.search(stripped)
        if m:
            shapes, kind = m.groups()
            for dm in _SHAPE_RE.finditer(shapes):
                out[kind] += _shape_bytes(*dm.groups())
            counts[kind] += 1
    out["_counts"] = counts  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-device HLO FLOPs
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective bytes
    coll_by_kind: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / (chips × HLO_FLOPs)

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (training) / 2·N·D (inference); N = active
    params, D = tokens processed by the step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: ONE token per sequence
    return 2.0 * n * shape.global_batch


def roofline_from_compiled(compiled, cfg: ModelConfig, shape: ShapeConfig,
                           chips: int,
                           hlo_text: Optional[str] = None,
                           scale: float = 1.0) -> RooflineTerms:
    """``scale`` multiplies the measured per-program terms — used when the
    cost program is one micro-batch of a ``scale``-step gradient-
    accumulation window (the programs are identical across micro-steps)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0)) * scale
    nbytes = float(cost.get("bytes accessed", 0.0)) * scale
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    counts = coll.pop("_counts")
    total_coll = float(sum(coll.values())) * scale

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = nbytes / HBM_BW
    collective_s = total_coll / ICI_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", collective_s), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    useful = mf / max(flops * chips, 1.0)
    return RooflineTerms(
        flops=flops, hbm_bytes=nbytes, coll_bytes=total_coll,
        coll_by_kind={**coll, "counts": counts},
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dom, model_flops=mf, useful_ratio=useful)


def terms_from_compiled(compiled, hlo_text: Optional[str] = None) -> Dict:
    """Raw per-device terms of one compiled program: flops, bytes, and
    collective bytes by kind (floats, unscaled)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    counts = coll.pop("_counts")
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": {k: float(v) for k, v in coll.items()},
        "counts": counts,
    }


def combine_layer_delta(t1: Dict, t2: Dict, n_units: float) -> Dict:
    """Layer-delta extrapolation: ``total = t1 + (n_units − 1)·(t2 − t1)``.

    t1/t2 are ``terms_from_compiled`` of 1-unit and 2-unit surrogate
    programs; layers are identical so the per-unit delta is exact. This
    sidesteps cost_analysis counting ``lax.scan`` while-bodies once."""
    f = n_units - 1.0
    out = {
        "flops": max(t1["flops"] + f * (t2["flops"] - t1["flops"]), 0.0),
        "bytes": max(t1["bytes"] + f * (t2["bytes"] - t1["bytes"]), 0.0),
        # clamp: GSPMD occasionally picks different collective mixes for
        # the two surrogates; a negative extrapolation is an artifact
        "coll": {k: max(t1["coll"][k] + f * (t2["coll"][k] - t1["coll"][k]),
                        0.0)
                 for k in t1["coll"]},
        "counts": {k: max(round(t1["counts"][k]
                                + f * (t2["counts"][k] - t1["counts"][k])),
                          0)
                   for k in t1["counts"]},
    }
    return out


def roofline_from_terms(terms: Dict, cfg: ModelConfig, shape: ShapeConfig,
                        chips: int, scale: float = 1.0) -> RooflineTerms:
    flops = terms["flops"] * scale
    nbytes = terms["bytes"] * scale
    coll = {k: v * scale for k, v in terms["coll"].items()}
    total_coll = float(sum(coll.values()))
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = nbytes / HBM_BW
    collective_s = total_coll / ICI_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", collective_s), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    return RooflineTerms(
        flops=flops, hbm_bytes=nbytes, coll_bytes=total_coll,
        coll_by_kind={**coll, "counts": terms.get("counts", {})},
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dom, model_flops=mf,
        useful_ratio=mf / max(flops * chips, 1.0))
