"""Three-term roofline analysis derived from compiled dry-run artifacts."""
from repro.roofline.analysis import (  # noqa: F401
    RooflineTerms,
    collective_bytes,
    model_flops,
    roofline_from_compiled,
)
