"""The three lowered step programs of the dry-run (DESIGN.md §5):

  * ``train_step``  (train_4k)    — teacher-forced GIPO + JIT-GAE + lagged
    advantage normalization + AdamW(ZeRO-2) over a [B, S] token batch. The
    sequence IS the stream of action tokens (the paper's token-level
    optimization, App. D.3, with A folded into S).
  * ``prefill_step`` (prefill_32k) — prompt pass emitting the decode cache.
  * ``serve_step``  (decode_32k / long_500k) — ONE new token against a
    KV/state cache of ``seq_len``.

Each ``make_*`` returns ``(fn, input_specs, shardings)`` so the dry-run and
the real launchers share the exact same program.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RLConfig, ShapeConfig
from repro.core import advnorm, gae, gipo
from repro.core.advnorm import AdvNormState
from repro.models import transformer
from repro.models.layers import Params
from repro.optim import adamw
from repro.sharding import rules

ATTN_BLOCK = 1024      # online-softmax KV block (perf knob, §Perf)


class SeqTrainState(NamedTuple):
    """Trainer state for the sequence-granularity production train step."""

    params: Any
    opt: adamw.AdamWState
    adv_norm: AdvNormState


def _value_mlp(vh: Params, hidden: jnp.ndarray,
               positions: jnp.ndarray) -> jnp.ndarray:
    """Per-position value estimate reusing the action-aware value head's
    parameters (single-token pooling ⇒ attention weight ≡ 1; step
    embedding indexed by episode-step = position mod max_steps)."""
    h = jax.lax.stop_gradient(hidden).astype(jnp.float32)
    max_steps = vh["step_emb"].shape[0]
    e_step = jnp.take(vh["step_emb"], positions % max_steps, axis=0)
    x = h + e_step[None]
    x = jax.nn.gelu(x @ vh["mlp_w1"] + vh["mlp_b1"])
    return (x @ vh["mlp_w2"] + vh["mlp_b2"])[..., 0]    # [B, S]


def seq_loss_fn(params, batch: Dict[str, jnp.ndarray], adv_state,
                cfg: ModelConfig, rl: RLConfig, *, remat: bool = True,
                block: Optional[int] = ATTN_BLOCK, unroll: bool = False,
                act_sharding=None):
    """Token-level GIPO over a [B, S] sequence with JIT value recomputation.

    batch: tokens [B,S] i32, behavior_logp [B,S] f32, rewards [B,S-1] f32,
    dones [B,S-1] f32, mask [B,S-1] f32, prefix (optional [B,P,F]).
    Tokens are unified ids; a token's action-bin id is ``token mod Va``
    (the slimmed head scores only the action vocabulary, App. D.1).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    window = cfg.sliding_window
    fused = rl.fused_loss and rl.algo == "gipo"
    out = transformer.forward(cfg, params, tokens,
                              batch.get("prefix"), window=window,
                              remat=remat, block=block, unroll=unroll,
                              act_sharding=act_sharding, head=not fused)
    # next-token factorization: logits[:, t] scores tokens[:, t+1]
    p = out["hidden"].shape[1] - s          # prefix length
    hidden = out["hidden"][:, p:]
    targets = tokens[:, 1:] % cfg.action_vocab_size

    # --- JIT value recomputation (App. C.1): values from THIS forward ----
    positions = jnp.arange(s)
    values = _value_mlp(params["value_head"], hidden, positions)  # [B,S]
    adv, returns = gae.jit_gae_from_forward(
        values, batch["rewards"], batch["dones"], rl.discount,
        rl.gae_lambda)
    stats = advnorm.local_stats(adv, batch["mask"])
    adv_n = jax.lax.stop_gradient(advnorm.normalize_lagged(adv, adv_state))

    mask = batch["mask"]
    logp_old = batch["behavior_logp"][:, 1:]
    if fused:
        # action head + GIPO/entropy/KL block-fused on hidden states
        # (kernels/dispatch.py) — no [B,S,Va] logits or log-softmax in HBM
        from repro.kernels import dispatch
        pg, _ent, kl, pg_m = dispatch.policy_head_loss(
            hidden[:, :-1].reshape(b * (s - 1), -1),
            params["action_head"]["w"], targets.reshape(-1),
            logp_old.reshape(-1), adv_n.reshape(-1), mask.reshape(-1),
            sigma=rl.gipo_sigma, mode=rl.kernel_dispatch)
        pg_m = jax.tree.map(jax.lax.stop_gradient, pg_m)
    else:
        logits = out["logits"][:, p:][:, :-1]                   # [B,S-1,Va]
        logp_all = jax.nn.log_softmax(logits, axis=-1)
        logp_new = jnp.take_along_axis(
            logp_all, targets[..., None], axis=-1)[..., 0]      # [B,S-1]
        if rl.algo == "gipo":
            pg, pg_m = gipo.gipo_loss(logp_new[..., None],
                                      logp_old[..., None], adv_n, mask,
                                      rl.gipo_sigma)
        else:
            pg, pg_m = gipo.ppo_loss(logp_new[..., None],
                                     logp_old[..., None], adv_n, mask,
                                     rl.ppo_clip)
        kl = gipo.kl_penalty(logp_new[..., None], logp_old[..., None], mask)
    v_loss = gipo.value_loss(values[:, :-1], jax.lax.stop_gradient(returns),
                             mask)
    total = pg + rl.value_coef * v_loss + rl.kl_coef * kl
    if cfg.arch_type == "moe":
        total = total + out["aux"]["load_balance"] + out["aux"]["router_z"]
    metrics = {"loss": total, "pg_loss": pg, "value_loss": v_loss,
               "kl": kl, **pg_m}
    return total, (metrics, stats)


def seq_train_step(state: SeqTrainState, batch, *, cfg: ModelConfig,
                   rl: RLConfig, remat: bool = True,
                   block: Optional[int] = ATTN_BLOCK,
                   accum: int = 1, unroll: bool = False,
                   grad_shardings=None,
                   act_sharding=None) -> Tuple[SeqTrainState, Dict]:
    """One optimizer step = ``accum`` sequential micro-batch passes (App.
    C.1: contiguous slicing, params frozen within the accumulation window,
    single deferred stats aggregation)."""
    grad_fn = jax.grad(
        functools.partial(seq_loss_fn, cfg=cfg, rl=rl, remat=remat,
                          block=block, unroll=unroll,
                          act_sharding=act_sharding), has_aux=True)

    if accum == 1:
        grads, (metrics, stats) = grad_fn(state.params, batch,
                                          state.adv_norm)
    else:
        # batch leaves carry a leading [accum] micro-batch axis (UNsharded;
        # the batch axis proper is axis 1) — scanning over it is the
        # paper's sequential contiguous slicing, and keeps every slice on
        # its home device (a dynamic-slice along the *sharded* batch axis
        # would force GSPMD to replicate the whole batch).
        def body(carry, mbatch):
            g_acc, s_acc = carry
            grads, (metrics, stats) = grad_fn(state.params, mbatch,
                                              state.adv_norm)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / accum, g_acc, grads)
            return (g_acc, s_acc + stats), metrics
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             state.params)
        (grads, stats), metrics = jax.lax.scan(
            body, (zeros, jnp.zeros((3,))), batch)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
    if grad_shardings is not None:
        grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
    lr = adamw.warmup_schedule(rl.lr_policy, rl.warmup_steps)(state.opt.step)
    new_params, new_opt, gnorm = adamw.update(
        grads, state.opt, state.params, lr, max_grad_norm=rl.max_grad_norm)
    new_adv = advnorm.welford_update(state.adv_norm, stats)
    metrics["grad_norm"] = gnorm
    return SeqTrainState(new_params, new_opt, new_adv), metrics


def _axes_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def choose_accum(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 *, carry_budget_bytes: float = 3 * 2**30,
                 pure_dp: bool = False) -> int:
    """Pick gradient-accumulation steps so the remat-saved layer carries of
    one micro-batch stay under ``carry_budget_bytes`` per device
    (carries = L × mb_local × S × d_model × 2 bytes)."""
    dp = tuple(rules.batch_axes(mesh)) + (("model",) if pure_dp else ())
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    local_b = max(shape.global_batch // dp_size, 1)
    per_seq = cfg.num_layers * shape.seq_len * cfg.d_model * 2
    mb = max(int(carry_budget_bytes // max(per_seq, 1)), 1)
    accum = 1
    while local_b // accum > mb and accum < local_b:
        accum *= 2
    return accum


def prefill_step(params, tokens, prefix, *, cfg: ModelConfig,
                 window: Optional[int], cache_len: int,
                 block: Optional[int] = ATTN_BLOCK, unroll: bool = False):
    out, cache = transformer.prefill(cfg, params, tokens, prefix,
                                     cache_len=cache_len, window=window,
                                     block=block, unroll=unroll)
    return out["logits"][:, -1], cache


def serve_step(params, token, cache, *, cfg: ModelConfig,
               window: Optional[int], unroll: bool = False,
               uniform: bool = False):
    out, cache = transformer.decode(cfg, params, token, cache, window=window,
                                    unroll=unroll, uniform=uniform)
    return out["logits"][:, -1], cache


# ---------------------------------------------------------------------------
# Spec builders — ShapeDtypeStructs + NamedShardings per (arch × shape)
# ---------------------------------------------------------------------------

def long_context_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    """Sliding-window fallback for dense archs at 500k (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.subquadratic \
            and not cfg.is_attention_free:
        return cfg.long_context_window
    return cfg.sliding_window


def effective_cache_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    window = long_context_window(cfg, shape)
    return min(shape.seq_len, window) if window else shape.seq_len


def param_structs(cfg: ModelConfig, *, with_value_head: bool = True):
    def init(key):
        if with_value_head:
            from repro.models.policy import init_policy_params
            return init_policy_params(cfg, key)
        return transformer.init_params(cfg, key)
    return jax.eval_shape(init, jax.random.PRNGKey(0))


def state_structs(cfg: ModelConfig):
    p = param_structs(cfg)
    f32 = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t)
    opt = adamw.AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=f32(p), nu=f32(p))
    advs = AdvNormState(*(jax.ShapeDtypeStruct((), jnp.float32),) * 3)
    return SeqTrainState(params=p, opt=opt, adv_norm=advs)


def train_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                accum: int = 1, fsdp=None, pure_dp: bool = False,
                fsdp_model: bool = False, zero3_axis=None):
    """(state_structs, batch_structs, state_shardings, batch_shardings).

    With ``accum > 1`` every batch leaf gains a LEADING unsharded
    [accum] micro-batch axis and the batch axis proper moves to axis 1
    (sequential micro-batch slicing, App. C.1)."""
    b, s = shape.global_batch, shape.seq_len
    state = state_structs(cfg)
    if fsdp_model or zero3_axis:
        # ZeRO-3 (§Perf): every tensor's largest divisible axis shards
        # over the chosen axis (params gathered per layer inside the
        # scan); combined with pure_dp batch this leaves ONLY param
        # gathers + grad reduce-scatters as collectives.
        from repro.optim import zero
        ax = zero3_axis or "model"
        repl = jax.tree.map(lambda l: P(*([None] * len(l.shape))),
                            state.params)
        pspec = zero.shard_moments_spec(state.params, repl,
                                        data_axis=ax,
                                        data_size=mesh.shape[ax])
    else:
        pspec = rules.param_specs(cfg, state.params, mesh, fsdp=fsdp,
                                  tp=not pure_dp)
    mspec = _moments_specs(state.params, pspec, mesh)
    scalar = P()
    state_spec = SeqTrainState(
        params=pspec,
        opt=adamw.AdamWState(step=scalar, mu=mspec,
                             nu=jax.tree.map(lambda x: x, mspec)),
        adv_norm=AdvNormState(scalar, scalar, scalar))

    mb = b // accum
    lead = (accum, mb) if accum > 1 else (b,)
    batch = {
        "tokens": jax.ShapeDtypeStruct(lead + (s,), jnp.int32),
        "behavior_logp": jax.ShapeDtypeStruct(lead + (s,), jnp.float32),
        "rewards": jax.ShapeDtypeStruct(lead + (s - 1,), jnp.float32),
        "dones": jax.ShapeDtypeStruct(lead + (s - 1,), jnp.float32),
        "mask": jax.ShapeDtypeStruct(lead + (s - 1,), jnp.float32),
    }
    if cfg.num_prefix_tokens:
        batch["prefix"] = jax.ShapeDtypeStruct(
            lead + (cfg.num_prefix_tokens, transformer.FRONTEND_DIM),
            jnp.float32)

    def bspec_for(v):
        nd = v.ndim - (1 if accum > 1 else 0)
        if pure_dp:
            # batch over BOTH mesh axes — no tensor parallelism at all
            dp = rules.batch_axes(mesh)
            axes = tuple(dp) + ("model",)
            spec = P(*((axes,) + (None,) * (nd - 1))) \
                if mb % _axes_size(mesh, axes) == 0 else P(*([None] * nd))
        else:
            spec = rules.data_spec(mesh, mb, nd)
        if accum > 1:
            spec = P(None, *spec)
        return spec
    bspec = {k: bspec_for(v) for k, v in batch.items()}
    return state, batch, _ns(mesh, state_spec), _ns(mesh, bspec)


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                  fsdp=None):
    b, s = shape.global_batch, shape.seq_len
    window = long_context_window(cfg, shape)
    cache_len = effective_cache_len(cfg, shape)
    params = param_structs(cfg, with_value_head=False)
    pspec = rules.param_specs(cfg, params, mesh, fsdp=fsdp)
    tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
    tspec = rules.data_spec(mesh, b, 2, seq_axis=1, seq_len=s)
    prefix = None
    prefix_spec = None
    if cfg.num_prefix_tokens:
        prefix = jax.ShapeDtypeStruct(
            (b, cfg.num_prefix_tokens, transformer.FRONTEND_DIM),
            jnp.float32)
        prefix_spec = rules.data_spec(mesh, b, 3)
    cache = jax.eval_shape(
        lambda: transformer.init_decode_cache(cfg, b, cache_len,
                                              window=window))
    cspec = rules.cache_specs(cfg, cache, mesh, b, cache_len)
    return dict(params=params, tokens=tokens, prefix=prefix, cache=cache,
                window=window, cache_len=cache_len,
                shardings=dict(params=_ns(mesh, pspec),
                               tokens=_ns(mesh, tspec),
                               prefix=_ns(mesh, prefix_spec)
                               if prefix is not None else None,
                               cache=_ns(mesh, cspec)))


def serve_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                fsdp=None, seq_shard: bool = False):
    b = shape.global_batch
    window = long_context_window(cfg, shape)
    cache_len = effective_cache_len(cfg, shape)
    params = param_structs(cfg, with_value_head=False)
    pspec = rules.param_specs(cfg, params, mesh, fsdp=fsdp)
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    tok_spec = rules.data_spec(mesh, b, 1)
    cache = jax.eval_shape(
        lambda: transformer.init_decode_cache(cfg, b, cache_len,
                                              window=window))
    cspec = rules.cache_specs(cfg, cache, mesh, b, cache_len,
                              seq_shard_model=seq_shard)
    return dict(params=params, token=token, cache=cache, window=window,
                cache_len=cache_len,
                shardings=dict(params=_ns(mesh, pspec),
                               token=_ns(mesh, tok_spec),
                               cache=_ns(mesh, cspec)))


def _moments_specs(param_structs_tree, pspec, mesh: Mesh):
    """ZeRO-2: Adam moments additionally sharded over ``data``."""
    from repro.optim import zero
    return zero.shard_moments_spec(
        param_structs_tree, pspec, data_axis="data",
        data_size=mesh.shape["data"])


def _ns(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))
