"""Launchers: production mesh construction, the multi-pod dry-run driver,
and the train/serve entry points."""
