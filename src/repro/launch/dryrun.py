"""Multi-pod dry-run driver (deliverable (e)).

For every (architecture × input shape × mesh): lower the step program with
production in/out shardings, ``.compile()`` it, and record
``memory_analysis()`` + ``cost_analysis()`` + the parsed collective
schedule.  The 512 placeholder host devices exist ONLY here — the two
os.environ lines below run before any jax-touching import so jax locks
onto them.

Cost methodology (see EXPERIMENTS.md §Roofline): ``cost_analysis`` counts a
``lax.scan`` while-body ONCE regardless of trip count, so roofline terms
come from a **layer-delta extrapolation**: two small surrogate programs
(1 layer-unit and 2 layer-units, attention KV loops unrolled) are compiled
and the exact per-unit delta is scaled to the full depth; trains
additionally scale by the gradient-accumulation factor (micro-programs of
one window are identical). The FULL production program is still lowered,
compiled, and memory-analysed — that is the fit proof.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json; cached
pairs are skipped so interrupted sweeps resume for free (--force recomputes).
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ the VERY FIRST executable statements — before any jax-touching import,
#   since jax locks the device count on first init.

import argparse
import dataclasses
import functools
import json
import pathlib
import time
import traceback
from typing import Dict, Optional, Tuple

import jax

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config, get_shape
from repro.configs.base import ModelConfig, RLConfig, ShapeConfig
from repro.launch import steps
from repro.launch.mesh import make_production_mesh, num_chips
from repro.roofline.analysis import (
    combine_layer_delta,
    roofline_from_terms,
    terms_from_compiled,
)
from repro.sharding.rules import FSDP_PARAM_THRESHOLD

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _sharded_bytes(structs, shardings) -> float:
    """Exact per-device bytes of a pytree under its NamedShardings."""
    import numpy as _np
    total = 0
    for st, sh in zip(jax.tree.leaves(structs), jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "num_devices"))):
        n = int(_np.prod(st.shape)) if st.shape else 1
        shard = sh.num_devices_in_shard if hasattr(
            sh, "num_devices_in_shard") else None
        # divide by the product of mesh-axis sizes used in the spec
        spec = sh.spec
        div = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                div *= sh.mesh.shape[a]
        total += n * st.dtype.itemsize / div
    return float(total)


def _mem_dict(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    out["total_hbm_bytes"] = (
        out.get("argument_size_in_bytes", 0.0)
        + out.get("output_size_in_bytes", 0.0)
        - out.get("alias_size_in_bytes", 0.0)
        + out.get("temp_size_in_bytes", 0.0))
    return out


def _layer_units(cfg: ModelConfig) -> Tuple[int, float]:
    """(layers per delta-unit, number of units incl. fractional remainder).

    Hybrids repeat in macro groups of ``shared_every`` Mamba2 layers behind
    one shared-attention application, so the unit is one macro group."""
    if cfg.arch_type == "hybrid":
        g = cfg.hybrid.shared_every
        n_macro, rem = divmod(cfg.num_layers, g)
        # the remainder triggers one extra shared-attn application + rem
        # SSM layers ≈ (rem + weight of one attn) / g of a unit
        return g, n_macro + (rem / g if rem else 0.0)
    return 1, float(cfg.num_layers)


def _surrogate(cfg: ModelConfig, n_units: int, unit: int) -> ModelConfig:
    return dataclasses.replace(cfg, num_layers=n_units * unit)


# --- §Perf hillclimb variants (EXPERIMENTS.md) ------------------------------
# each entry: (description, cfg transform, rules/step toggles)
VARIANTS = {
    "baseline": {},
    # musicgen: heads (24) don't divide model (16) -> baseline shards the
    # head_dim CONTRACTION of every attention dot (all-reduce per KV block);
    # prefer sharding d_model instead (one all-reduce per projection).
    "attn_dshard": {"attn_prefer_dmodel": True},
    # mamba2: the fused in_proj's z/xBC/dt split crosses shard boundaries
    # (per-layer all-gather of the full projection); split the projection
    # into three shard-aligned tensors.
    "split_inproj": {"split_inproj": True},
    # decode: lockstep serving -> scalar-slot dynamic-update-slice instead
    # of a batched scatter (the scatter forces cache replication).
    "uniform_decode": {"uniform_decode": True},
    "uniform+dshard": {"uniform_decode": True, "attn_prefer_dmodel": True},
    # musicgen: 24 heads don't divide the model axis, so scores stay
    # REPLICATED per device (O(T²·H) bytes each) and contract a sharded
    # head_dim (giant all-reduces). MaxText-style fix: zero-pad heads to
    # 32 so they shard 16-way (+33% attn FLOPs, ÷16 score bytes).
    "pad_heads": {"pad_heads": 32},
    # mamba2 (2.7B fits per chip): drop tensor parallelism entirely —
    # batch over BOTH mesh axes; only the gradient all-reduce remains.
    # zero2_grads reduce-scatters gradients into the ZeRO moment layout
    # (the paper's actual ZeRO-2 semantics) so f32 grads never live
    # replicated.
    "pure_dp": {"pure_dp": True, "zero2_grads": True},
    "pure_dp_chunk64": {"pure_dp": True, "zero2_grads": True, "chunk": 64},
    "zero2_grads": {"zero2_grads": True},
    # ZeRO-3 over the model axis: params/grads/moments sharded, batch on
    # data — per-layer param all-gather replaces per-token TP collectives.
    "fsdp_model": {"fsdp_model": True, "zero2_grads": True,
                   "split_inproj": True},
    # batch over BOTH axes + ZeRO-3 params over data: per-layer param
    # all-gather (~11 GB/step) replaces ALL per-token TP collectives AND
    # the replicated f32 grad tree (reduce-scattered instead).
    "pure_dp_zero3": {"pure_dp": True, "zero2_grads": True,
                      "zero3_axis": "data"},
    # decode: shard the KV cache SEQUENCE over model (flash-decoding
    # context parallelism) — softmax combines partial (max, sum, acc).
    "cache_seqshard": {"cache_seqshard": True, "uniform_decode": True},
}


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              rl: Optional[RLConfig] = None,
              variant: str = "baseline",
              rules_override=None) -> Dict:
    """Lower + compile one (arch, shape, mesh); return the record."""
    from repro.sharding import rules as rules_mod
    opts = VARIANTS[variant]
    cfg = get_config(arch)
    if opts.get("split_inproj") and cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, fused_in_proj=False))
    if opts.get("pad_heads"):
        hp = opts["pad_heads"]
        cfg = dataclasses.replace(
            cfg, num_heads=hp,
            num_kv_heads=hp if cfg.num_kv_heads == cfg.num_heads
            else cfg.num_kv_heads,
            head_dim_override=cfg.head_dim)
    if opts.get("chunk") and cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=opts["chunk"]))
    pure_dp = bool(opts.get("pure_dp"))
    zero3_axis = opts.get("zero3_axis")
    fsdp_model = bool(opts.get("fsdp_model"))
    zero2_grads = bool(opts.get("zero2_grads"))
    cache_seqshard = bool(opts.get("cache_seqshard"))
    rules_mod.ATTN_PREFER_DMODEL = bool(opts.get("attn_prefer_dmodel"))
    uniform_decode = bool(opts.get("uniform_decode"))
    shape = get_shape(shape_name)
    rl = rl or RLConfig()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = num_chips(mesh)
    fsdp = cfg.param_count() > FSDP_PARAM_THRESHOLD
    t0 = time.time()
    extra: Dict = {}

    def build_train(c: ModelConfig, accum: int, unroll: bool):
        # surrogate cost programs run ONE micro-batch (scaled by ``accum``
        # afterwards); the production program runs the full window
        eff_shape = dataclasses.replace(
            shape, global_batch=shape.global_batch // max(accum, 1)) \
            if unroll else shape
        use_accum = 1 if unroll else accum
        state, batch, sspec, bspec = steps.train_specs(
            c, eff_shape, mesh, accum=use_accum, fsdp=fsdp,
            pure_dp=pure_dp, fsdp_model=fsdp_model,
            zero3_axis=zero3_axis)
        act_sh = None
        if cfg.param_count() > 10e9 and c.d_model % mesh.shape["model"] == 0:
            # pin the remat carry layout on big models: batch on data,
            # d_model on model — otherwise GSPMD may replicate the batch
            # axis of the saved residual stack (16x memory blow-up)
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            from repro.sharding.rules import batch_axes
            dp = batch_axes(mesh)
            act_sh = NamedSharding(
                mesh, P(dp if len(dp) > 1 else dp[0], None, "model"))
        fn = functools.partial(steps.seq_train_step, cfg=c, rl=rl,
                               accum=use_accum, unroll=unroll,
                               grad_shardings=sspec.opt.mu if zero2_grads
                               else sspec.params,
                               act_sharding=act_sh)
        jfn = jax.jit(fn, in_shardings=(sspec, bspec),
                      out_shardings=(sspec, None), donate_argnums=(0,))
        return jfn.lower(state, batch)

    def build_prefill(c: ModelConfig, unroll: bool):
        sp = steps.prefill_specs(c, shape, mesh, fsdp=fsdp)
        sh = sp["shardings"]
        blk = max(steps.ATTN_BLOCK, shape.seq_len // 16) if unroll \
            else steps.ATTN_BLOCK
        fn = functools.partial(steps.prefill_step, cfg=c,
                               window=sp["window"],
                               cache_len=sp["cache_len"], block=blk,
                               unroll=unroll)
        jfn = jax.jit(fn, in_shardings=(sh["params"], sh["tokens"],
                                        sh["prefix"]),
                      out_shardings=(None, sh["cache"]))
        return jfn.lower(sp["params"], sp["tokens"], sp["prefix"])

    def build_serve(c: ModelConfig, unroll: bool):
        sp = steps.serve_specs(c, shape, mesh, fsdp=fsdp,
                               seq_shard=cache_seqshard)
        sh = sp["shardings"]
        fn = functools.partial(steps.serve_step, cfg=c,
                               window=sp["window"], unroll=unroll,
                               uniform=uniform_decode)
        jfn = jax.jit(fn, in_shardings=(sh["params"], sh["token"],
                                        sh["cache"]),
                      out_shardings=(None, sh["cache"]),
                      donate_argnums=(2,))
        return jfn.lower(sp["params"], sp["token"], sp["cache"])

    with mesh:
        accum = steps.choose_accum(cfg, shape, mesh, pure_dp=pure_dp) \
            if shape.kind == "train" else 1
        build = {"train": lambda c, u: build_train(c, accum, u),
                 "prefill": build_prefill,
                 "decode": build_serve}[shape.kind]

        # --- (a) production program: the compile + memory-fit proof -------
        lowered = build(cfg, False)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        # --- (b) roofline cost via layer-delta surrogates ------------------
        t1 = time.time()
        unit, n_units = _layer_units(cfg)
        t_one = terms_from_compiled(build(_surrogate(cfg, 1, unit),
                                          True).compile())
        t_two = terms_from_compiled(build(_surrogate(cfg, 2, unit),
                                          True).compile())
        rules_mod.ATTN_PREFER_DMODEL = False   # reset the toggle
        cost_terms = combine_layer_delta(t_one, t_two, n_units)
        extra["cost_compile_s"] = round(time.time() - t1, 2)
        if shape.kind == "train":
            extra["accum"] = accum
        terms = roofline_from_terms(cost_terms, cfg, shape, chips,
                                    scale=accum)

    if shape.kind in ("prefill", "decode"):
        # exact analytic resident state (params + cache) under the specs —
        # the TPU-true floor; the CPU-measured total above is an upper
        # bound inflated by the CPU backend's bf16→f32 normalization of
        # while-loop buffers (EXPERIMENTS.md §Dry-run).
        builder = (steps.prefill_specs if shape.kind == "prefill"
                   else steps.serve_specs)
        with mesh:
            sp2 = builder(cfg, shape, mesh, fsdp=fsdp)
        extra["state_bytes_per_dev"] = (
            _sharded_bytes(sp2["params"], sp2["shardings"]["params"])
            + _sharded_bytes(sp2["cache"], sp2["shardings"]["cache"]))

    mem = _mem_dict(compiled)
    rec = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "kind": shape.kind, "fsdp": fsdp,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "roofline": terms.as_dict(),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        **extra,
    }
    return rec


def run_and_save(arch: str, shape_name: str, *, multi_pod: bool,
                 force: bool = False, variant: str = "baseline") -> Dict:
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    out_dir = OUT_DIR / mesh_tag
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    path = out_dir / f"{arch}__{shape_name}{suffix}.json"
    if path.exists() and not force:
        rec = json.loads(path.read_text())
        if "error" not in rec:
            print(f"[skip] {arch} × {shape_name} ({mesh_tag}) — cached")
            return rec
    print(f"[dryrun] {arch} × {shape_name} ({mesh_tag}) ...", flush=True)
    try:
        rec = lower_one(arch, shape_name, multi_pod=multi_pod,
                        variant=variant)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "variant": variant, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        path.write_text(json.dumps(rec, indent=1))
        print(f"[FAIL] {arch} × {shape_name}: {e}")
        return rec
    path.write_text(json.dumps(rec, indent=1))
    r = rec["roofline"]
    print(f"  ok: compile {rec['compile_s']}s+{rec['cost_compile_s']}s | "
          f"compute {r['compute_s']:.3e}s memory {r['memory_s']:.3e}s "
          f"collective {r['collective_s']:.3e}s -> {r['dominant']}-bound | "
          f"useful {r['useful_ratio']:.2f} | "
          f"hbm/dev {rec['memory']['total_hbm_bytes']/2**30:.2f} GiB")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS + ["openvla-7b"])
    ap.add_argument("--shape", choices=[s.name for s in INPUT_SHAPES])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    args = ap.parse_args()

    if args.all:
        pairs = [(a, s.name) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in pairs:
        rec = run_and_save(arch, shape, multi_pod=args.multi_pod,
                           force=args.force, variant=args.variant)
        failures += "error" in rec
    print(f"\n{len(pairs) - failures}/{len(pairs)} lowered+compiled OK")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
