"""Production mesh construction (DESIGN.md §5).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, while smoke tests and benches see the single real CPU device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh() -> Mesh:
    """1×1 mesh over the real local device(s) — smoke tests / examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def num_chips(mesh: Mesh) -> int:
    return mesh.devices.size
