"""Production serving launcher: prefill + decode against the sharded
KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b \
        --tokens 16 --local
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, get_shape, reduced
from repro.launch import steps
from repro.launch.mesh import make_local_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=ASSIGNED_ARCHS)
    ap.add_argument("--shape", default="decode_32k",
                    choices=("decode_32k", "long_500k"))
    ap.add_argument("--tokens", type=int, default=8,
                    help="tokens to decode")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = get_shape(args.shape)
    if args.local:
        cfg = reduced(cfg, layers=2, d_model=128)
        shape = dataclasses.replace(shape, seq_len=64, global_batch=2)
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    window = steps.long_context_window(cfg, shape)
    cache_len = steps.effective_cache_len(cfg, shape)
    print(f"arch {cfg.name} | cache_len {cache_len} | "
          f"window {window} | batch {shape.global_batch}")

    with mesh:
        sp = steps.serve_specs(cfg, shape, mesh)
        sh = sp["shardings"]
        dfn = jax.jit(functools.partial(steps.serve_step, cfg=cfg,
                                        window=window),
                      in_shardings=(sh["params"], sh["token"], sh["cache"]),
                      out_shardings=(None, sh["cache"]),
                      donate_argnums=(2,))
        from repro.models import transformer
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        params = jax.device_put(params, sh["params"])
        cache = transformer.init_decode_cache(cfg, shape.global_batch,
                                              cache_len, window=window)
        cache = jax.device_put(cache, sh["cache"])
        token = jax.device_put(
            jnp.zeros((shape.global_batch,), jnp.int32), sh["token"])
        for i in range(args.tokens):
            t0 = time.perf_counter()
            logits, cache = dfn(params, token, cache)
            token = jnp.argmax(logits, -1).astype(jnp.int32)
            token = jax.device_put(token, sh["token"])
            jax.block_until_ready(logits)
            print(f"decode {i}: token[0]={int(token[0])} "
                  f"({(time.perf_counter()-t0)*1e3:.1f} ms)")


if __name__ == "__main__":
    main()
