"""Connect-mode rollout worker CLI (the multi-host lifecycle).

Dial a running :class:`~repro.runtime.transport.server.TransportServer`
(started by ``repro.launch.train --serve-workers N`` or any
``AcceRLSystem`` with ``rt.transport.connect_rollout_workers > 0``),
authenticate with the shared token, receive a worker slot's spec over the
``worker.hello`` handshake, and run the standard worker body
(``worker_main``) against that server — the SAME code a parent-spawned
worker runs, just started from another terminal (or another host):

    PYTHONPATH=src python -m repro.launch.worker \
        --address 127.0.0.1:5555 --token sekrit

The hello is retried with a short period until a slot opens (a freshly
killed worker's slot re-opens only after its liveness window lapses), so
"redial to rejoin" is literally re-running this command. A stopped or
superseded incarnation exits cleanly when its report reply says ``stop``.
"""
from __future__ import annotations

import argparse
import dataclasses
import random
import sys
import time
from typing import Optional


def run(address: str, *, token: str = "", name: Optional[str] = None,
        hello_timeout_s: float = 60.0, retry_s: float = 0.5) -> int:
    """Handshake until assigned (or ``hello_timeout_s``), then run the
    worker body. Returns a process exit code."""
    from repro.runtime.transport.channel import (TransportError, WireClient,
                                                 parse_address)
    from repro.runtime.transport.remote import spec_from_wire, worker_main

    addr = parse_address(address)
    deadline = time.monotonic() + hello_timeout_s
    while True:
        client = None
        try:
            client = WireClient(
                addr, connect_timeout=max(deadline - time.monotonic(), 0.1))
            header = {"m": "worker.hello", "token": token}
            if name:
                header["worker"] = name
            resp, _ = client.request(header)
            client.close()
            break
        except TransportError as e:        # includes ChannelClosed
            if client is not None:
                client.close()
            if time.monotonic() >= deadline:
                print(f"worker: no slot within {hello_timeout_s:.0f}s — "
                      f"giving up ({e})", file=sys.stderr)
                return 2
            # ±25% jitter: a fleet redialing a replaced server spreads
            # its hellos instead of hammering the listener in lockstep
            time.sleep(retry_s * (0.75 + 0.5 * random.random()))
    # the spec's address is as the SERVER sees itself; dial-side knows the
    # reachable one (NAT/0.0.0.0 binds), so the dialed address wins
    spec = dataclasses.replace(spec_from_wire(resp["spec"]), address=addr)
    print(f"worker {spec.name!r}: attached as incarnation "
          f"{spec.incarnation} -> {addr[0]}:{addr[1]} "
          f"({spec.num_envs} env(s), suite {spec.suite!r})")
    return worker_main(spec)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="connect-mode AcceRL rollout worker")
    ap.add_argument("--address", required=True, metavar="HOST:PORT",
                    help="TransportServer to dial")
    ap.add_argument("--token", default="",
                    help="shared secret for the worker.hello handshake")
    ap.add_argument("--name", default=None,
                    help="specific slot to claim (default: first open)")
    ap.add_argument("--hello-timeout", type=float, default=60.0,
                    help="seconds to keep redialing for an open slot")
    args = ap.parse_args()
    sys.exit(run(args.address, token=args.token, name=args.name,
                 hello_timeout_s=args.hello_timeout))


if __name__ == "__main__":
    main()
