"""Production training launcher.

On a real TPU pod this runs the sharded ``seq_train_step`` over the
production mesh; on CPU (``--local``) it runs the same program on a 1×1
mesh with a reduced config — the code path is identical, only the mesh and
scale differ.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --shape train_4k --steps 3 --local

``--remote-rollout N`` switches to the asynchronous runtime demo instead:
an :class:`AcceRLSystem` with N rollout worker processes hosted by the
Supervisor behind the transport subsystem (socket channels + weight-store
wire), trained for ``--steps`` policy updates on a reduced config:

    PYTHONPATH=src python -m repro.launch.train --remote-rollout 2 --steps 3

``--serve-workers N`` is the two-terminal multi-host demo: this process
binds ``--listen`` and waits for N connect-mode workers to dial in with
``--token``; each worker is a separate ``repro.launch.worker`` process
(any reachable host):

    # terminal 1
    PYTHONPATH=src python -m repro.launch.train --serve-workers 1 \
        --listen 127.0.0.1:5555 --token sekrit --steps 3
    # terminal 2
    PYTHONPATH=src python -m repro.launch.worker \
        --address 127.0.0.1:5555 --token sekrit

``--restart on_failure`` puts either flavor under a restart budget: a
killed worker is respawned (spawn mode) or its slot re-opened for a
redial (connect mode) instead of failing the run.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

# --trace-out arms the observability plane. Tracing is IMPORT-gated (every
# instrumented module binds its _tel at import time, keeping the off path
# free), so the env flag must be up before ANY repro import below —
# argparse has not run yet, scan argv directly.
if any(a == "--trace-out" or a.startswith("--trace-out=")
       for a in sys.argv[1:]):
    os.environ.setdefault("REPRO_TRACE", "1")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, get_shape, reduced
from repro.configs.base import RLConfig
from repro.launch import steps
from repro.launch.mesh import make_local_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=ASSIGNED_ARCHS)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--local", action="store_true",
                    help="reduced config on the local device mesh (CPU demo)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fused-loss", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="run the action head + GIPO loss tail block-fused "
                         "(kernels/dispatch.py) — no [B,S,Va] logits in "
                         "HBM; default ON, --no-fused-loss opts out")
    ap.add_argument("--kernel-dispatch", default="auto",
                    choices=("auto", "pallas", "jnp"),
                    help="hot-op routing: Pallas on TPU / jnp twins "
                         "elsewhere (auto), or force one side")
    ap.add_argument("--remote-rollout", type=int, default=0, metavar="N",
                    help="run the async AcceRLSystem demo with N rollout "
                         "worker processes spawned under the Supervisor "
                         "(reduced config; ignores --shape)")
    ap.add_argument("--serve-workers", type=int, default=0, metavar="N",
                    help="host N connect-mode worker slots and wait for "
                         "repro.launch.worker processes to dial in "
                         "(two-terminal multi-host demo)")
    ap.add_argument("--listen", default="127.0.0.1:0", metavar="HOST:PORT",
                    help="TransportServer bind address for --serve-workers")
    ap.add_argument("--token", default="",
                    help="shared worker.hello secret for --serve-workers")
    ap.add_argument("--restart", default="never",
                    choices=("never", "on_failure"),
                    help="supervision policy for remote/connect workers")
    ap.add_argument("--max-restarts", type=int, default=2,
                    help="restart budget per worker slot (with "
                         "--restart on_failure)")
    ap.add_argument("--remote-transport", default="socket",
                    choices=("socket", "shm", "ring"),
                    help="experience/weight wire for --remote-rollout: "
                         "per-message sockets, per-message SHM segments, "
                         "or persistent SHM rings (streaming data plane)")
    ap.add_argument("--put-window", type=int, default=0, metavar="W",
                    help="pipeline rollout flushes through a PutStream "
                         "with W frames in flight (0 = one RPC per flush; "
                         "ring transport always streams)")
    ap.add_argument("--journal-dir", default="", metavar="DIR",
                    help="write-ahead journal the TransportServer's hosted "
                         "state (channel contents, stream watermarks, "
                         "weight publishes) into DIR so a replacement "
                         "server can recover it")
    ap.add_argument("--resume-journal", action="store_true",
                    help="recover --journal-dir's state at startup (the "
                         "replacement-server path after a crash) instead "
                         "of requiring the directory to be fresh")
    ap.add_argument("--elastic-workers", type=int, default=0, metavar="MAX",
                    help="autoscale the remote worker fleet up to MAX "
                         "slots from queue-depth/weight-staleness signals "
                         "(0 = fixed fleet)")
    ap.add_argument("--inference-plane", default="", metavar="MODE",
                    choices=("", "host", "spawn"),
                    help="disaggregated inference for remote workers: "
                         "'host' serves the parent's pool behind the "
                         "transport, 'spawn' runs a supervised shared "
                         "inference tier process; default: each worker "
                         "keeps a colocated pool")
    ap.add_argument("--pipeline", action="store_true",
                    help="run the pipelined training-runtime demo: policy "
                         "trainer + world-model trainer as pipeline stages "
                         "on submeshes of the local device set, driven by "
                         "the static RUN/SEND/RECV/FREE schedules "
                         "(runtime/pipeline_exec.py); reduced config, "
                         "ignores --shape")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="write a Chrome-trace-event JSON (open in "
                         "Perfetto / chrome://tracing) covering every "
                         "process of the run; also arms the REPRO_TRACE "
                         "span recorder and the telemetry sink")
    args = ap.parse_args()
    if args.resume_journal and not args.journal_dir:
        ap.error("--resume-journal needs --journal-dir")

    if args.pipeline:
        _run_pipeline(args)
        return
    if args.remote_rollout or args.serve_workers:
        _run_remote_rollout(args)
        return

    cfg = get_config(args.arch)
    shape = get_shape(args.shape)
    assert shape.kind == "train", "use repro.launch.serve for decode shapes"

    if args.local:
        cfg = reduced(cfg, layers=2, d_model=128)
        shape = dataclasses.replace(shape, seq_len=256, global_batch=4)
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    rl = RLConfig(fused_loss=args.fused_loss,
                  kernel_dispatch=args.kernel_dispatch)
    if args.kernel_dispatch != "auto":
        # process-wide routing so attention / ssd_scan inside the
        # transformer follow the same side as the loss tail
        from repro.kernels import dispatch
        dispatch.set_mode(args.kernel_dispatch)
    accum = steps.choose_accum(cfg, shape, mesh)
    structs, batch_structs, sspec, bspec = steps.train_specs(
        cfg, shape, mesh, accum=accum)
    print(f"mesh {dict(mesh.shape)} | accum {accum} | "
          f"params {cfg.param_count()/1e6:.1f}M")

    with mesh:
        import functools
        fn = functools.partial(steps.seq_train_step, cfg=cfg, rl=rl,
                               accum=accum, grad_shardings=sspec.params)
        jfn = jax.jit(fn, in_shardings=(sspec, bspec),
                      out_shardings=(sspec, None))

        # materialize state + synthetic batch with the right shardings
        key = jax.random.PRNGKey(0)
        from repro.models.policy import init_policy_params
        from repro.optim import adamw
        from repro.core.advnorm import init_adv_state
        params = init_policy_params(cfg, key)
        state = steps.SeqTrainState(params=params, opt=adamw.init(params),
                                    adv_norm=init_adv_state())
        state = jax.device_put(state, sspec)
        rng = np.random.default_rng(0)
        batch = {
            k: jax.device_put(jnp.asarray(
                rng.integers(0, cfg.vocab_size, v.shape).astype(v.dtype)
                if v.dtype == jnp.int32 else
                rng.standard_normal(v.shape).astype(np.float32) * 0.1),
                bspec[k])
            for k, v in batch_structs.items()
        }
        for i in range(args.steps):
            t0 = time.perf_counter()
            state, metrics = jfn(state, batch)
            jax.block_until_ready(metrics["loss"])
            print(f"step {i}: loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({time.perf_counter() - t0:.2f}s)")


def _run_pipeline(args) -> None:
    """Pipelined training-runtime demo (reduced config): the world-model
    system with ``rt.pipeline`` on — the policy trainer's optimizer step
    and the WM trainer run as pipeline stages on submeshes of the local
    device list, one static instruction schedule per submesh."""
    from repro.configs.base import RuntimeConfig, TelemetryConfig, WMConfig
    from repro.wm.wm_system import AcceRLWMSystem

    cfg = reduced(get_config(args.arch), layers=2, d_model=64)
    rl = RLConfig(grad_accum=2, lr_policy=1e-4, lr_value=1e-3,
                  fused_loss=args.fused_loss,
                  kernel_dispatch=args.kernel_dispatch)
    rt = RuntimeConfig(num_rollout_workers=2, inference_batch=4,
                       pipeline=True,
                       telemetry=TelemetryConfig(sink=bool(args.trace_out),
                                                 trace_out=args.trace_out))
    wm = WMConfig(imagine_horizon=2, history_frames=2, diffusion_steps=4,
                  obs_train_interval=2, reward_train_interval=5)
    system = AcceRLWMSystem(cfg, rl, rt, wm, suite="spatial",
                            segment_horizon=4, max_episode_steps=8,
                            imagination_batch=4)
    layout = system.trainer._layout
    print(f"pipeline: policy submesh {[str(d) for d in layout.policy.devices]}"
          f" | wm submesh {[str(d) for d in layout.wm.devices]}"
          f" | disjoint={layout.disjoint} | K={rl.grad_accum}")
    t0 = time.time()
    m = system.run_wm(train_steps=args.steps, wall_timeout_s=300.0)
    pipe = system.trainer.pipeline
    print(f"trained {m['train_steps']} policy steps "
          f"({pipe.rounds} pipeline rounds) in {time.time() - t0:.1f}s | "
          f"imagined {m['imagined_steps']} steps | "
          f"wm updates {m['wm_updates']}")
    print(f"bubble frac {pipe.last_bubble} | "
          f"peak live grad bytes {pipe.peak_grad_bytes}")
    if args.trace_out:
        from repro.runtime import telemetry
        n = telemetry.dump(args.trace_out, process_name="train-pipeline")
        print(f"trace: {n} events -> {args.trace_out}")


def _run_remote_rollout(args) -> None:
    """Asynchronous-system demo with supervised remote rollout workers —
    spawned child processes and/or connect-mode workers dialing in."""
    from repro.configs import reduced
    from repro.configs.base import (RuntimeConfig, SupervisionConfig,
                                    TelemetryConfig, TransportConfig)
    from repro.runtime import AcceRLSystem

    cfg = reduced(get_config(args.arch), layers=2, d_model=64)
    rl = RLConfig(grad_accum=1, lr_policy=1e-4, lr_value=1e-3,
                  fused_loss=args.fused_loss,
                  kernel_dispatch=args.kernel_dispatch)
    rt = RuntimeConfig(
        num_rollout_workers=1, inference_batch=4,
        transport=TransportConfig(
            remote_rollout_workers=args.remote_rollout,
            connect_rollout_workers=args.serve_workers,
            kind=args.remote_transport,
            put_window=args.put_window,
            listen_addr=args.listen if args.serve_workers else "",
            token=args.token,
            journal_dir=args.journal_dir,
            resume_journal=args.resume_journal,
            inference_plane=args.inference_plane,
            reconnect_attempts=(20 if args.inference_plane else 0),
            supervision=SupervisionConfig(
                restart=args.restart,
                max_restarts=args.max_restarts,
                max_workers=args.elastic_workers,
                min_workers=(1 if args.elastic_workers else 0))),
        telemetry=TelemetryConfig(sink=bool(args.trace_out),
                                  trace_out=args.trace_out))
    system = AcceRLSystem(cfg, rl, rt, suite="spatial", segment_horizon=4,
                          max_episode_steps=12, batch_episodes=4)
    host, port = system.transport_server.address
    print(f"async system: 1 local + {args.remote_rollout} spawned + "
          f"{args.serve_workers} connect-mode rollout worker(s) over "
          f"{args.remote_transport} @ {host}:{port} "
          f"(restart={args.restart}"
          + (f", inference={args.inference_plane}" if args.inference_plane
             else "") + ")")
    if args.serve_workers:
        token_arg = f" --token {args.token}" if args.token else ""
        print(f"dial in from another terminal/host:\n"
              f"  PYTHONPATH=src python -m repro.launch.worker "
              f"--address {host}:{port}{token_arg}")
    t0 = time.time()
    m = system.run_async(train_steps=args.steps, wall_timeout_s=300.0)
    print(f"trained {m['train_steps']} steps in {time.time() - t0:.1f}s | "
          f"env SPS {m['sps_env']:.1f} | policy lag "
          f"{m['mean_policy_lag']:.2f}")
    for name, h in system.health().items():
        line = f"  {name:20s} {h['state']}"
        snap = m["services"].get(name, {})
        counters = snap.get("counters", {})
        for key in ("env_steps", "steps", "batches", "requests"):
            if key in counters:
                line += f"  {key}={int(counters[key])}"
        print(line + (f"  error={h['error']}" if h["error"] else ""))
    if args.trace_out:
        # one file covers every process: the parent's own buffers plus
        # the child events the server folded in from worker.report
        from repro.runtime import telemetry
        n = telemetry.dump(args.trace_out, process_name="train-parent")
        print(f"trace: {n} events -> {args.trace_out}")


if __name__ == "__main__":
    main()
