"""AdamW with bf16-friendly mixed precision: f32 master moments over
(possibly bf16) parameters, global-norm clipping, and warmup schedules.

No optax dependency — the state is a plain pytree so the ZeRO-2 partition
specs in ``repro.optim.zero`` can shard it over the ``data`` mesh axis.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray      # i32 scalar
    mu: dict               # first moments (f32), same tree as params
    nu: dict               # second moments (f32)


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def warmup_schedule(base_lr: float, warmup_steps: int) -> Callable:
    def lr(step):
        frac = jnp.minimum(
            (step.astype(jnp.float32) + 1.0) / max(warmup_steps, 1), 1.0)
        return base_lr * frac
    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(grads, state: AdamWState, params, lr: jnp.ndarray, *,
           b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
           weight_decay: float = 0.0,
           max_grad_norm: float = 0.0) -> Tuple[dict, AdamWState, jnp.ndarray]:
    """Returns (new_params, new_state, grad_norm)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if max_grad_norm > 0:
        grads, norm = clip_by_global_norm(grads, max_grad_norm)
    else:
        norm = global_norm(grads)
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                      state.nu, grads)

    # ``lr`` may be a scalar or a pytree of per-leaf scalars (e.g. the paper's
    # separate policy / value-head learning rates, Table 3).
    lr_tree = lr if isinstance(lr, dict) else jax.tree.map(
        lambda _: lr, params)

    def upd(p, m, v, lr_leaf):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_leaf * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu, lr_tree)
    return new_params, AdamWState(step=step, mu=mu, nu=nu), norm
