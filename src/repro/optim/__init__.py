from repro.optim import adamw, zero  # noqa: F401
from repro.optim.adamw import AdamWState, init, update, warmup_schedule  # noqa: F401
