"""ZeRO-2-style partitioning of optimizer state over the ``data`` axis.

Paper §3.1: "Trainer Workers employ ZeRO-2 to partition optimizer states and
gradients, supporting larger micro-batch sizes." The JAX-native equivalent:
parameters keep their tensor-parallel sharding (replicated across ``data``),
while the f32 Adam moments are *additionally* sharded over ``data`` along
each tensor's largest divisible axis. Gradients reduce-scatter into that
layout (GSPMD derives this from the output shardings of the grad step).

``shard_moments_spec`` takes the parameter PartitionSpec tree and returns
the moments' spec tree.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P


def _zero_spec_for(shape, param_spec: P, data_axis: str,
                   data_size: int) -> P:
    """Pick the largest axis not already sharded and divisible by data."""
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    # FSDP-style params already consume the data axis - nothing to add
    for e in entries:
        names = e if isinstance(e, tuple) else (e,)
        if data_axis in names:
            return param_spec
    best, best_dim = None, 0
    for i, (dim, taken) in enumerate(zip(shape, entries)):
        if taken is not None:
            continue
        if dim % data_size == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best is None:
        return param_spec
    entries[best] = data_axis
    return P(*entries)


def shard_moments_spec(param_shapes, param_specs, *, data_axis: str = "data",
                       data_size: int = 16):
    """param_shapes: pytree of jax.ShapeDtypeStruct; param_specs: pytree of
    PartitionSpec. Returns the ZeRO-sharded moments spec tree."""
    return jax.tree.map(
        lambda s, spec: _zero_spec_for(s.shape, spec, data_axis, data_size),
        param_shapes, param_specs,
        is_leaf=lambda x: isinstance(x, P))


def moments_bytes_per_device(param_count: int, data_size: int,
                             zero: bool) -> float:
    """Analytic check of the ZeRO-2 memory claim (2 × f32 moments)."""
    total = 2 * 4 * param_count
    return total / (data_size if zero else 1)


# --------------------------------------------------------------------------
# live-state wiring: turn the spec trees into actual device placements
# --------------------------------------------------------------------------

def moment_shardings(params, mesh, *, param_specs=None,
                     data_axis: str = "data"):
    """NamedSharding tree for the f32 moments of ``params`` on ``mesh``.

    ``param_specs`` defaults to fully-replicated (pure ZeRO, no tensor
    parallelism) — pass the tree from ``sharding.rules.param_specs`` to
    compose ZeRO with the TP/FSDP layout.
    """
    from jax.sharding import NamedSharding

    data_size = mesh.shape.get(data_axis, 1)
    shapes = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
    if param_specs is None:
        param_specs = jax.tree.map(lambda s: P(), shapes)
    mspecs = shard_moments_spec(shapes, param_specs, data_axis=data_axis,
                                data_size=data_size)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), mspecs,
                        is_leaf=lambda x: isinstance(x, P))


def shard_opt_state(opt, mesh, *, param_specs=None, data_axis: str = "data"):
    """Re-place an ``adamw.AdamWState`` so mu/nu live under the ZeRO specs."""
    shardings = moment_shardings(opt.mu, mesh, param_specs=param_specs,
                                 data_axis=data_axis)
    return opt._replace(mu=jax.device_put(opt.mu, shardings),
                        nu=jax.device_put(opt.nu, shardings))


def realized_moments_bytes_per_device(opt):
    """Measured per-device footprint of the moments, via addressable shards.

    Returns the max over devices — on an even ZeRO layout every device
    holds the same number of bytes, so this equals the analytic
    ``moments_bytes_per_device`` when every tensor found a divisible axis.
    """
    per_device: dict = {}
    for tree in (opt.mu, opt.nu):
        for leaf in jax.tree.leaves(tree):
            for shard in leaf.addressable_shards:
                did = shard.device.id
                per_device[did] = per_device.get(did, 0) + shard.data.nbytes
    return max(per_device.values()) if per_device else 0
