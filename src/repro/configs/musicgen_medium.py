"""musicgen-medium — decoder-only over EnCodec tokens. [arXiv:2306.05284]

The EnCodec conv codec is the stubbed modality frontend; the decoder consumes
precomputed frame embeddings (``num_prefix_tokens``) plus audio-token ids,
and its natural "action vocabulary" is the 2048-entry codec codebook.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    action_vocab_size=2048,          # codec codebook == output head
    num_prefix_tokens=64,            # conditioning frames from the stub codec
    source="arXiv:2306.05284",
)
