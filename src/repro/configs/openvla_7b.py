"""openvla-7b — the paper's own policy backbone: OpenVLA-OFT on Llama-2-7B
with the lm_head slimmed to 256 action bins (paper App. D.1, Table 3).
[arXiv:2502.19645]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="openvla-7b",
    arch_type="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    action_vocab_size=256,
    num_prefix_tokens=256,           # SigLIP/DINO patch embeds (stub frontend)
    source="arXiv:2502.19645 (OpenVLA-OFT on Llama-2-7B)",
)
