"""zamba2-1.2b — hybrid Mamba2 trunk + shared attention blocks.
[arXiv:2411.15242]"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk=128),
    hybrid=HybridConfig(shared_every=6, shared_d_ff=8192),
    source="arXiv:2411.15242",
)
