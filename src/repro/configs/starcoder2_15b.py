"""starcoder2-15b — dense, GQA kv=4, RoPE. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    arch_type="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    source="arXiv:2402.19173",
)
