"""llava-next-mistral-7b — VLM backbone (anyres tiling vision frontend is the
stub; decoder consumes projected patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    num_prefix_tokens=576,           # one 24x24 anyres tile of patch embeds
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
