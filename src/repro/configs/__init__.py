"""Architecture registry: ``get_config("<arch-id>")`` and ``reduced()``.

The ten assigned architectures plus the paper's own backbone
(``openvla-7b``). ``reduced()`` produces the smoke-test variant of the same
family (≤2 layers, d_model ≤ 512, ≤4 experts) per the deliverable spec.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    INPUT_SHAPES,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    RLConfig,
    RuntimeConfig,
    ShapeConfig,
    SSMConfig,
    WMConfig,
    get_shape,
)

from repro.configs.granite_20b import CONFIG as _granite_20b
from repro.configs.granite_moe_1b_a400m import CONFIG as _granite_moe
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.internlm2_1_8b import CONFIG as _internlm2
from repro.configs.zamba2_1_2b import CONFIG as _zamba2
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.deepseek_7b import CONFIG as _deepseek
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.llava_next_mistral_7b import CONFIG as _llava
from repro.configs.mamba2_2_7b import CONFIG as _mamba2
from repro.configs.openvla_7b import CONFIG as _openvla

_REGISTRY: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _granite_20b,
        _granite_moe,
        _starcoder2,
        _internlm2,
        _zamba2,
        _dbrx,
        _deepseek,
        _musicgen,
        _llava,
        _mamba2,
        _openvla,
    )
}

ASSIGNED_ARCHS: List[str] = [
    "granite-20b",
    "granite-moe-1b-a400m",
    "starcoder2-15b",
    "internlm2-1.8b",
    "zamba2-1.2b",
    "dbrx-132b",
    "deepseek-7b",
    "musicgen-medium",
    "llava-next-mistral-7b",
    "mamba2-2.7b",
]


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {list_archs()}") from None


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 256,
            vocab: int = 512) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests."""
    heads = 4 if cfg.num_heads else 0
    kv = 0
    if cfg.num_kv_heads:
        # preserve the GQA ratio shape: MQA stays MQA, MHA stays MHA.
        kv = 1 if cfg.num_kv_heads == 1 else (heads if cfg.num_kv_heads == cfg.num_heads else 2)
    updates = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        d_ff=0 if cfg.arch_type == "ssm" else 4 * d_model,
        vocab_size=min(cfg.vocab_size, vocab),
        action_vocab_size=min(cfg.action_vocab_size, 64),
        num_prefix_tokens=min(cfg.num_prefix_tokens, 8),
        max_episode_steps=64,
        param_dtype="float32",
        compute_dtype="float32",
        head_dim_override=d_model // heads if heads else None,
    )
    if cfg.moe is not None:
        # capacity_factor = E/k guarantees zero token drops, making the
        # grouped dispatch exactly equal to per-token top-k routing — so the
        # smoke tests can assert prefill/decode vs forward consistency.
        updates["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, d_ff=d_model,
            capacity_factor=2.0)
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=32, chunk=32)
    if cfg.hybrid is not None:
        updates["hybrid"] = dataclasses.replace(
            cfg.hybrid, shared_every=1, shared_d_ff=2 * d_model)
    return dataclasses.replace(cfg, **updates)
