"""Configuration dataclasses for the AcceRL reproduction.

Every selectable architecture (``--arch <id>``) is a :class:`ModelConfig`;
the RL pipeline, world model, and distribution settings have their own
dataclasses so the launcher can compose them freely.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (GShard-style capacity dispatch)."""

    num_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden width
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings."""

    state_dim: int = 128            # N
    head_dim: int = 64              # P
    expand: int = 2                 # d_inner = expand * d_model
    conv_dim: int = 4               # depthwise causal conv kernel
    chunk: int = 128                # SSD chunk length
    n_groups: int = 1               # B/C groups (shared across heads)
    # Hillclimb (§Perf, mamba2): split the fused in_proj into three
    # independently-sharded projections so the z/xBC/dt split never
    # crosses shard boundaries (kills a per-layer all-gather).
    fused_in_proj: bool = True

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: Mamba2 trunk + a single *shared* attention block
    applied every ``shared_every`` layers (weights tied across applications)."""

    shared_every: int = 6
    shared_d_ff: int = 8192


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A policy-backbone architecture.

    ``arch_type`` in {dense, moe, ssm, hybrid, audio, vlm}. ``audio`` and
    ``vlm`` use the same decoder stack as ``dense`` but accept precomputed
    modality embeddings from the (stubbed) frontend via ``prefix_embeds``.
    """

    name: str
    arch_type: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""                # citation / model card

    # -- action head (paper App. D.1 vocabulary slimming) --------------------
    action_vocab_size: int = 256    # slimmed output head
    action_dim: int = 7             # action tokens emitted per env step
    max_episode_steps: int = 512    # for the value-head step embedding

    # -- attention ------------------------------------------------------------
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    sliding_window: Optional[int] = None      # normal operation
    long_context_window: int = 8192           # long_500k fallback for dense
    head_dim_override: Optional[int] = None

    # -- optional sub-configs ---------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None

    # -- multimodal stub frontend ----------------------------------------------
    num_prefix_tokens: int = 0       # vision patches / audio frames per sample

    # -- numerics ---------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    @property
    def head_dim(self) -> int:
        if self.head_dim_override is not None:
            return self.head_dim_override
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Natively sub-quadratic in context length (SSM state / window)."""
        return self.arch_type in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + action head)."""
        d, L = self.d_model, self.num_layers
        total = self.vocab_size * d                    # embedding
        total += self.action_vocab_size * d            # slimmed head
        if self.arch_type == "ssm":
            assert self.ssm is not None
            di = self.ssm.d_inner(d)
            nh = self.ssm.num_heads(d)
            g = self.ssm.n_groups
            per = (
                d * (2 * di + 2 * g * self.ssm.state_dim + nh)  # in_proj
                + self.ssm.conv_dim * (di + 2 * g * self.ssm.state_dim)
                + nh                                   # A_log
                + nh                                   # dt bias
                + di                                   # gated norm
                + di * d                               # out_proj
                + d                                    # pre-norm
            )
            return total + L * per
        kvh = self.num_kv_heads
        hd = self.head_dim
        attn = d * self.num_heads * hd + 2 * d * kvh * hd + self.num_heads * hd * d
        if self.arch_type == "moe":
            assert self.moe is not None
            ff = 3 * d * self.moe.d_ff * self.moe.num_experts
            ff += d * self.moe.num_experts             # router
        else:
            ff = 3 * d * self.d_ff
        per = attn + ff + 2 * d                        # two norms
        total += L * per
        if self.arch_type == "hybrid":
            assert self.ssm is not None and self.hybrid is not None
            di = self.ssm.d_inner(d)
            nh = self.ssm.num_heads(d)
            g = self.ssm.n_groups
            per = (
                d * (2 * di + 2 * g * self.ssm.state_dim + nh)
                + self.ssm.conv_dim * (di + 2 * g * self.ssm.state_dim)
                + 2 * nh + di + di * d + d
            )
            shared = attn + 3 * d * self.hybrid.shared_d_ff + 2 * d
            total = self.vocab_size * d + self.action_vocab_size * d
            total += L * per + shared
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if self.arch_type != "moe":
            return self.param_count()
        assert self.moe is not None
        d, L = self.d_model, self.num_layers
        dense_share = self.param_count() - L * 3 * d * self.moe.d_ff * self.moe.num_experts
        return dense_share + L * 3 * d * self.moe.d_ff * self.moe.top_k


@dataclasses.dataclass(frozen=True)
class RLConfig:
    """GIPO / PPO training settings (paper Table 3/5/6)."""

    algo: str = "gipo"               # {"gipo", "ppo"}
    gipo_sigma: float = 0.2
    ppo_clip: float = 0.2
    discount: float = 0.99
    gae_lambda: float = 0.95
    value_coef: float = 0.5
    kl_coef: float = 0.1
    entropy_coef: float = 0.0
    lr_policy: float = 3e-6
    lr_value: float = 3e-5
    warmup_steps: int = 500
    micro_batch: int = 16
    grad_accum: int = 2
    value_recompute: bool = True     # JIT-GAE fused into the train step
    adv_norm: str = "lagged_global"  # {"lagged_global", "batch", "none"}
    max_grad_norm: float = 1.0
    # -- hot-path fusion (kernels/dispatch.py) -------------------------------
    # fused_loss: run the action head + GIPO/entropy/KL loss block-fused on
    # hidden states (never materializing [B,T,A,Va] logits); exact parity
    # with the reference path. Only effective for algo == "gipo".
    # Default ON since PR 5 (soaked on the async benchmarks + parity CI);
    # fused_loss=False remains the opt-out (--no-fused-loss).
    fused_loss: bool = True
    # kernel_dispatch: routing for the fused-loss op: "auto" = Pallas on
    # TPU, jnp twin elsewhere; "pallas"/"jnp" force one side (testing).
    # Attention routing has no per-config knob — use the process-wide
    # REPRO_KERNELS env var or dispatch.set_mode(), which also take
    # precedence over this field.
    kernel_dispatch: str = "auto"


@dataclasses.dataclass(frozen=True)
class WMConfig:
    """World model settings (paper §4, Table 4/5)."""

    imagine_horizon: int = 2
    frame_embed_dim: int = 256       # pixel-interface embedding width (stub codec)
    frame_tokens: int = 16           # patches per frame
    denoiser_layers: int = 4
    denoiser_d_model: int = 256
    denoiser_heads: int = 4
    history_frames: int = 4          # conditioning context ("step conditions")
    diffusion_steps: int = 8         # sampling steps at rollout time
    reward_train_interval: int = 15
    obs_train_interval: int = 3
    reward_scale: float = 5.0
    sigma_data: float = 0.5          # EDM preconditioning


@dataclasses.dataclass(frozen=True)
class SupervisionConfig:
    """Worker-lifecycle supervision (runtime/transport/supervision.py).

    ``restart="never"`` keeps the PR 3 semantics: any worker failure marks
    its slot FAILED and schedulers fail fast. ``"on_failure"`` respawns
    (spawn mode) or re-accepts a redial (connect mode) with exponential
    backoff, up to ``max_restarts`` inside a sliding ``window_s``."""

    restart: str = "never"            # {"never", "on_failure"}
    max_restarts: int = 2             # budget inside the sliding window
    window_s: float = 60.0
    backoff_initial_s: float = 0.1
    backoff_factor: float = 2.0
    backoff_max_s: float = 2.0
    # connect-mode stall detector: a report gap beyond this is a failure
    # (0 = auto: liveness_heartbeats missed beats, floored at the floor)
    liveness_timeout_s: float = 0.0
    liveness_heartbeats: float = 10.0
    liveness_floor_s: float = 2.0
    # -- elastic autoscaling (runtime/transport/supervision.ElasticPolicy)
    # max_workers > 0 arms the autoscaler: the supervisor scales the
    # fleet between min/max from the experience-queue depth fraction and
    # the weight-staleness signal, draining (not killing) on scale-down
    min_workers: int = 0
    max_workers: int = 0
    elastic_interval_s: float = 2.0
    scale_up_depth: float = 0.25
    scale_down_depth: float = 0.9
    staleness_cap: float = 0.0        # published - oldest-acted version;
                                      # 0 disables the staleness signal
    drain_timeout_s: float = 10.0
    # inference-tier pressure (the queue_depth/window_fill gauges the
    # disaggregated plane bridges): a tier with queue depth >= tier_queue_hot
    # or window fill >= tier_fill_hot counts as saturated, which is an
    # additional scale-up trigger (and blocks scale-down) — 0 disables
    tier_queue_hot: float = 0.0
    tier_fill_hot: float = 0.0


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """Cross-process transport (runtime/transport): socket/SHM experience
    channels + the weight-store wire for remote rollout workers (the
    paper's physical isolation of rollout from training)."""

    kind: str = "socket"              # {"socket", "shm", "ring"} — shm moves
                                      # large payloads out-of-band through
                                      # per-message shared memory; ring
                                      # through two persistent SHM rings per
                                      # channel (zero per-message churn)
    host: str = "127.0.0.1"
    port: int = 0                     # 0 = ephemeral
    listen_addr: str = ""             # "host:port" override of host/port —
                                      # bind 0.0.0.0 for multi-host workers
    token: str = ""                   # shared secret for the worker.hello
                                      # handshake (connect-mode workers)
    remote_rollout_workers: int = 0   # spawned rollout worker PROCESSES
    connect_rollout_workers: int = 0  # slots for workers that DIAL IN
                                      # (repro.launch.worker, other hosts)
    envs_per_worker: int = 1          # rollout envs inside each process
    heartbeat_s: float = 0.25         # child metrics/health report interval
    connect_timeout_s: float = 20.0
    shm_threshold_bytes: int = 1 << 16
    # wire-client resilience: transparent redial budget after a
    # server-side connection drop (0 = fail fast)
    reconnect_attempts: int = 0
    reconnect_backoff_s: float = 0.1
    # -- streaming data plane ------------------------------------------------
    # put_window > 0: rollout flushes go through a pipelined PutStream
    # (fire-and-forget frames, windowed async acks, exactly-once replay
    # after a reconnect) instead of one blocking RPC per flush. 0 keeps
    # the PR 4 request/response path.
    put_window: int = 0
    # adaptive streaming: tune the effective put window / ack cadence
    # online from observed cumulative-ack RTT (multiplicative increase on
    # low occupancy, halving backoff on verdict pressure or RTT spikes).
    # put_window and ack cadence become BOUNDS — the effective window
    # starts at put_window, so steady RTT never drops below static.
    adaptive_put_window: bool = False
    # ring capacity per direction for kind="ring" (the persistent SHM
    # ring data plane; must hold several encoded flushes)
    ring_bytes: int = 8 << 20
    # zero-copy ring pops: the trainer-side channel decodes experience
    # straight out of the committed ring region under a lease instead of
    # copying each record out (the Prefetcher releases leases post-collate)
    zero_copy_pop: bool = False
    # weight broadcast lane: > 0 gives the server a persistent SHM ring
    # of this capacity holding one encoded weight blob per version;
    # same-host acquires read it positionally instead of receiving the
    # blob per-message (kills per-acquire SHM segment churn)
    weight_lane_bytes: int = 0
    # -- disaggregated inference plane ---------------------------------------
    # "": every rollout child runs its own colocated inference pool.
    # "host": the parent serves its OWN InferenceService behind the
    #   infer.* endpoints of the main TransportServer; remote rollout
    #   children submit action requests to it instead of building a pool.
    # "spawn": a supervised inference-tier child hosts the shared pool
    #   behind its own TransportServer on a fixed pre-allocated port;
    #   rollout children dial the tier (and redial across its restarts).
    inference_plane: str = ""
    infer_listen_addr: str = ""       # "host:port" bind override for the
                                      # spawned tier (default loopback +
                                      # a pre-allocated ephemeral port)
    # -- resilient control plane (runtime/transport/resilience) --------------
    # journal_dir non-empty: hosted channel contents, stream dedup
    # watermarks, and weight-store publishes are write-ahead journaled
    # there (compacted once the log passes journal_compact_bytes);
    # resume_journal: recover that directory's state at startup instead
    # of requiring it empty — the --resume-journal replacement-server path
    journal_dir: str = ""
    journal_compact_bytes: int = 64 << 20
    resume_journal: bool = False
    supervision: SupervisionConfig = dataclasses.field(
        default_factory=SupervisionConfig)


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Observability plane (runtime/telemetry.py).

    ``sink=True`` registers a TelemetrySink service that samples every
    service's metrics/health into bounded history (and ``sink_path`` as
    JSONL), served remotely through the ``metrics.snapshot`` endpoint.
    Span/trace RECORDING is import-gated separately by the REPRO_TRACE
    env var (set automatically by ``launch/train.py --trace-out``) — it
    must be decided before the hot modules import, which a config field
    evaluated afterwards cannot do."""

    sink: bool = False
    sink_interval_s: float = 1.0
    sink_history: int = 256
    sink_path: str = ""               # JSONL history file ("" = memory only)
    trace_out: str = ""               # Chrome-trace JSON dump path written
                                      # by the launcher after the run


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Asynchronous runtime (paper §3, eq. 1)."""

    num_rollout_workers: int = 6
    num_inference_workers: int = 1
    num_trainer_workers: int = 1
    inference_batch: int = 8         # B in eq. 1
    inference_max_wait_s: float = 0.01   # T_max in eq. 1
    replay_capacity: int = 3000      # episodes
    wm_replay_capacity: int = 50_000
    img_replay_capacity: int = 10_000
    min_buffer_episodes: int = 4
    sync_mode: bool = False          # True reproduces the synchronous baseline
    weight_sync_interval: int = 1    # trainer steps between publishes
    drain: bool = True               # inference-drain protocol (App. D.6)
    prefetch_depth: int = 2
    # -- device ingest path (data/prefetch.py) -------------------------------
    prefetch_drain_timeout_s: float = 0.1   # partial-drain slice
    prefetch_idle_timeout_s: float = 0.5    # idle-backoff cap
    prefetch_staging: bool = True    # assemble batches into pooled
                                     # page-aligned host staging slabs
    prefetch_to_device: bool = False  # jax.device_put from the prefetch
                                      # thread (H2D overlaps next collate)
    batch_buckets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)  # TPU-friendly pads
    # -- pipelined training runtime (runtime/pipeline_exec.py) ---------------
    # pipeline=True routes TrainerWorker.train_on_batch through the static
    # per-submesh instruction schedule (RUN/SEND/RECV/FREE): the policy
    # trainer and the world-model trainer run as pipeline stages on
    # disjoint submeshes of the local device set, with microbatched
    # gradient accumulation and FREE instructions bounding live grads to
    # one micro-batch. On a 1-device host both submeshes share the device
    # (schedule semantics identical, overlap nil).
    pipeline: bool = False
    pipeline_microbatches: int = 0   # micro-batches per round (0 = grad_accum)
    pipeline_wm_devices: int = 0     # WM-submesh device count (0 = half the
                                     # local devices when >= 2, else shared)
    # -- experience channels (runtime/experience.py) -------------------------
    # Backpressure when the segment channel is full: "drop_oldest" is the
    # paper's fully-asynchronous mode (producers never block); "drop_newest"
    # keeps queued data; "block" clamps rollout to trainer throughput.
    replay_backpressure: str = "drop_oldest"
    # WM mode: target share of REAL segments in the policy trainer's batch
    # (MixedExperienceSource over B and B_img). 0.0 = paper §4 (pure B_img).
    mix_real_fraction: float = 0.0
    # -- cross-process transport (runtime/transport) -------------------------
    # remote_rollout_workers > 0 spawns that many rollout worker processes
    # whose channels/weight endpoints cross the boundary over this config.
    transport: TransportConfig = dataclasses.field(
        default_factory=TransportConfig)
    # -- observability plane (runtime/telemetry) -----------------------------
    telemetry: TelemetryConfig = dataclasses.field(
        default_factory=TelemetryConfig)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # {"train", "prefill", "decode"}


INPUT_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in INPUT_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown input shape {name!r}; have "
                   f"{[s.name for s in INPUT_SHAPES]}")
