"""Checkpointing: atomic save/restore of full trainer state (params +
Adam moments + advantage-normalization state + version counter).

Format: one ``.npz`` per checkpoint with flattened key paths (portable,
dependency-free), written atomically (tmp + rename — the same pattern the
shared-storage weight transport uses, App. G.3). ``restore`` can re-shard
onto a device mesh by passing ``shardings``.
"""
from __future__ import annotations

import io
import json
import pathlib
import re
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "::"


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(directory: str, step: int, state: Any, *,
         keep: int = 3, metadata: Optional[Dict] = None) -> str:
    """Atomically write ``ckpt_<step>.npz``; prune to the ``keep`` newest."""
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    buf = io.BytesIO()
    np.savez(buf, **flat)
    path = d / f"ckpt_{step:010d}.npz"
    tmp = d / f".tmp_{time.time_ns()}"
    tmp.write_bytes(buf.getvalue())
    tmp.rename(path)                                  # atomic publish
    meta = {"step": step, "time": time.time(), **(metadata or {})}
    (d / f"ckpt_{step:010d}.json").write_text(json.dumps(meta))
    # prune
    ckpts = sorted(d.glob("ckpt_*.npz"))
    for old in ckpts[:-keep]:
        old.unlink(missing_ok=True)
        old.with_suffix(".json").unlink(missing_ok=True)
    return str(path)


def latest_step(directory: str) -> Optional[int]:
    d = pathlib.Path(directory)
    steps = [int(m.group(1)) for f in d.glob("ckpt_*.npz")
             if (m := re.match(r"ckpt_(\d+)\.npz", f.name))]
    return max(steps) if steps else None


def restore(directory: str, template: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (matching pytree of NamedShardings)
    re-shards each leaf onto the mesh on load."""
    d = pathlib.Path(directory)
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    with np.load(d / f"ckpt_{step:010d}.npz") as z:
        flat = {k: z[k] for k in z.files}

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "mesh"))
        if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, tmpl), sh in zip(paths, shard_leaves):
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
            for p in path)
        arr = flat[key]
        if hasattr(tmpl, "dtype"):
            arr = arr.astype(tmpl.dtype)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
