"""Trajectory pytrees — the wire format between rollout, buffer, and trainer.

Paper eq. 2:  τ = (o_{1:T+1}, a_{1:T}, r_{1:T}, μ_{1:T}, v_{1:T}, ṽ_{T+1}, done)
Paper eq. 3:  τ̂ = the same with hats, fixed horizon H (imagined).

Arrays indexed 0..T carry T+1 entries; index T is the bootstrap slot
(observation o_{T+1}; its action/logp entries are padding). ``mask`` marks
valid *steps* (0..T−1) so FIFO segments of ragged episodes batch cleanly.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class TrajectoryBatch(NamedTuple):
    obs_tokens: jnp.ndarray          # [B, T+1, T_obs] i32
    actions: jnp.ndarray             # [B, T+1, A] i32 (index T = padding)
    behavior_logp: jnp.ndarray       # [B, T+1, A] f32  (μ)
    behavior_value: jnp.ndarray      # [B, T+1] f32     (v at collection)
    rewards: jnp.ndarray             # [B, T] f32
    dones: jnp.ndarray               # [B, T] f32 (natural termination)
    steps: jnp.ndarray               # [B, T+1] i32 episode-step index
    mask: jnp.ndarray                # [B, T] f32 valid steps
    policy_version: jnp.ndarray      # [B] i32 — version of μ (staleness)
    prefix_embeds: Optional[jnp.ndarray] = None   # [B, T+1, P, F] f32

    @property
    def horizon(self) -> int:
        return self.rewards.shape[1]

    def num_steps(self) -> jnp.ndarray:
        return self.mask.sum()


def stack_batches(batches):
    """Concatenate TrajectoryBatch list along the batch axis (host-side)."""
    def cat(*xs):
        if xs[0] is None:
            return None
        return np.concatenate([np.asarray(x) for x in xs], axis=0)
    return jax.tree.map(cat, *batches,
                        is_leaf=lambda x: x is None or hasattr(x, "shape"))


def dummy_batch(batch: int, horizon: int, t_obs: int, action_dim: int,
                vocab: int, action_vocab: int,
                num_prefix: int = 0, frontend_dim: int = 1024,
                seed: int = 0) -> TrajectoryBatch:
    """Random but well-formed batch for tests/benchmarks."""
    rng = np.random.default_rng(seed)
    tp1 = horizon + 1
    prefix = None
    if num_prefix:
        prefix = rng.standard_normal(
            (batch, tp1, num_prefix, frontend_dim)).astype(np.float32)
    return TrajectoryBatch(
        obs_tokens=rng.integers(0, vocab, (batch, tp1, t_obs)).astype(np.int32),
        actions=rng.integers(0, action_vocab,
                             (batch, tp1, action_dim)).astype(np.int32),
        behavior_logp=np.log(
            rng.uniform(0.05, 0.9, (batch, tp1, action_dim))
        ).astype(np.float32),
        behavior_value=rng.standard_normal((batch, tp1)).astype(np.float32),
        rewards=rng.uniform(-1, 1, (batch, horizon)).astype(np.float32),
        dones=(rng.uniform(size=(batch, horizon)) < 0.05).astype(np.float32),
        steps=np.tile(np.arange(tp1, dtype=np.int32), (batch, 1)),
        mask=np.ones((batch, horizon), np.float32),
        policy_version=np.zeros((batch,), np.int32),
        prefix_embeds=prefix,
    )
