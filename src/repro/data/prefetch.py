"""Asynchronous parallel data prefetching (paper App. D.5).

A background producer thread watches the FIFO replay buffer, assembles
ready-to-train super-batches (tensorization + batching off the critical
path), and parks them in a bounded local cache; the trainer pops fully
formed batches. While the accelerator runs step ``k``, the prefetcher
prepares the data for step ``k+1``.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

from repro.data.replay import FIFOReplayBuffer


class Prefetcher:
    def __init__(self, buffer: FIFOReplayBuffer, batch_size: int,
                 collate: Callable, depth: int = 2):
        self.buffer = buffer
        self.batch_size = batch_size
        self.collate = collate
        self._cache: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="prefetcher")
        self.batches_built = 0

    def start(self) -> "Prefetcher":
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.is_set():
            segments = self.buffer.pop_batch(self.batch_size, timeout=0.1)
            if segments is None:
                continue
            batch = self.collate(segments)
            self.batches_built += 1
            while not self._stop.is_set():
                try:
                    self._cache.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def get(self, timeout: Optional[float] = None):
        """Pop a ready super-batch (None on timeout)."""
        try:
            return self._cache.get(timeout=timeout)
        except queue.Empty:
            return None

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2.0)
