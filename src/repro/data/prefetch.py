"""Asynchronous parallel data prefetching (paper App. D.5).

A background producer thread watches an experience source, assembles
ready-to-train super-batches (tensorization + batching off the critical
path), and parks them in a bounded local cache; the trainer pops fully
formed batches. While the accelerator runs step ``k``, the prefetcher
prepares the data for step ``k+1``.

The source is anything exposing ``pop_batch(n, timeout)`` — a
:class:`~repro.data.replay.FIFOReplayBuffer`, a
:class:`~repro.runtime.experience.FifoChannel`, or a
:class:`~repro.runtime.experience.MixedExperienceSource` blending real and
imagined segments.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional


class Prefetcher:
    def __init__(self, source, batch_size: int,
                 collate: Callable, depth: int = 2):
        self.source = source
        self.batch_size = batch_size
        self.collate = collate
        self._cache: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="prefetcher")
        self.batches_built = 0

    def start(self) -> "Prefetcher":
        self._thread.start()
        return self

    def _run(self) -> None:
        # a pop_many source is drained in COALESCED partial batches (one
        # lock/RPC per drain, items accumulate here until a super-batch
        # is full) instead of exact-n pops that wait for the batch to
        # round out while ready items sit in the channel
        pop_many = getattr(self.source, "pop_many", None)
        pending = []
        while not self._stop.is_set():
            if pop_many is not None:
                got = pop_many(self.batch_size - len(pending), timeout=0.1)
                if got:
                    pending.extend(got)
                if len(pending) < self.batch_size:
                    continue
                segments, pending = pending, []
            else:
                segments = self.source.pop_batch(self.batch_size,
                                                 timeout=0.1)
                if segments is None:
                    continue
            batch = self.collate(segments)
            self.batches_built += 1
            while not self._stop.is_set():
                try:
                    self._cache.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def get(self, timeout: Optional[float] = None):
        """Pop a ready super-batch (None on timeout)."""
        try:
            return self._cache.get(timeout=timeout)
        except queue.Empty:
            return None

    def stop(self) -> None:
        self._stop.set()
        if self._thread.ident is not None:   # only join a started thread
            self._thread.join(timeout=2.0)
