"""Asynchronous parallel data prefetching (paper App. D.5).

A background producer thread watches an experience source, assembles
ready-to-train super-batches (tensorization + batching off the critical
path), and parks them in a bounded local cache; the trainer pops fully
formed batches. While the accelerator runs step ``k``, the prefetcher
prepares the data for step ``k+1``.

The source is anything exposing ``pop_batch(n, timeout)`` — a
:class:`~repro.data.replay.FIFOReplayBuffer`, a
:class:`~repro.runtime.experience.FifoChannel`, or a
:class:`~repro.runtime.experience.MixedExperienceSource` blending real and
imagined segments.

Device ingest path (the zero-copy pipeline's last hop):

  * zero-copy sources (a ring channel with ``zero_copy_pop``) deliver
    segments whose arrays VIEW transport memory and carry a ring lease
    under ``"_lease"``; collate copies the data into the batch, so the
    prefetcher releases every lease right after collate — at that point
    the producer may reclaim the ring bytes;
  * with ``stage_batches`` the collated batch is assembled into a slab
    from a small pool of reusable page-aligned host staging buffers
    (:class:`StagingPool`) instead of freshly allocated arrays — steady
    state runs at zero batch-sized allocations per step;
  * with ``to_device`` the staged batch is shipped by ``jax.device_put``
    (donated where the jax version supports it) from the prefetch
    thread, double-buffered by the cache: the H2D of batch N overlaps
    the collate of batch N+1. A slab is recycled only after the trainer
    pops the NEXT batch (``get`` → ``get``), because on the CPU backend
    ``device_put`` of an aligned numpy array may alias the slab rather
    than copy it — recycling any earlier could corrupt a batch still in
    flight.

The drain loop's partial-batch timeout is configurable
(``drain_timeout_s``) and backs off exponentially up to
``idle_timeout_max_s`` while the source stays empty, so an idle trainer
does not burn a wakeup every slice.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

_PAGE = 4096
_ALIGN = 64


def _align(n: int, to: int = _ALIGN) -> int:
    return (n + to - 1) & ~(to - 1)


class _Slab:
    """One page-aligned host staging buffer (``raw`` pins the allocation,
    ``buf`` is the aligned uint8 window batches are carved from)."""

    __slots__ = ("raw", "buf")

    def __init__(self, nbytes: int):
        self.raw = np.empty(nbytes + _PAGE, dtype=np.uint8)
        off = (-self.raw.ctypes.data) % _PAGE
        self.buf = self.raw[off:off + nbytes]


class StagingPool:
    """Small pool of reusable page-aligned host staging buffers.

    ``acquire`` prefers a free slab big enough for the request (batches
    are shape-stable, so after warmup every acquire is a reuse);
    ``release`` returns a slab once its batch can no longer be read —
    see the recycle-on-next-get rule in the module docstring.
    """

    def __init__(self, max_free: int = 4):
        self._free: List[_Slab] = []
        self._lock = threading.Lock()
        self._max_free = max(int(max_free), 1)
        self.staging_reuse = 0
        self.slabs_allocated = 0

    def acquire(self, nbytes: int) -> _Slab:
        nbytes = _align(max(nbytes, 1), _PAGE)
        with self._lock:
            for i, slab in enumerate(self._free):
                if slab.buf.nbytes >= nbytes:
                    self.staging_reuse += 1
                    return self._free.pop(i)
        self.slabs_allocated += 1
        return _Slab(nbytes)

    def release(self, slab: Optional[_Slab]) -> None:
        if slab is None:
            return
        with self._lock:
            if len(self._free) < self._max_free:
                self._free.append(slab)


def _flatten_batch(batch) -> Optional[Tuple[List[np.ndarray], Callable]]:
    """Split a collated batch (NamedTuple or dict of arrays) into its
    ndarray leaves + a rebuilder; None when the shape is unknown (staging
    is then skipped and the batch passes through untouched)."""
    if hasattr(batch, "_fields"):
        vals = [getattr(batch, f) for f in batch._fields]
        idx = [i for i, v in enumerate(vals) if isinstance(v, np.ndarray)]

        def rebuild(staged, vals=vals, idx=idx, cls=type(batch)):
            out = list(vals)
            for i, leaf in zip(idx, staged):
                out[i] = leaf
            return cls(*out)

        return [vals[i] for i in idx], rebuild
    if isinstance(batch, dict):
        keys = [k for k, v in batch.items() if isinstance(v, np.ndarray)]

        def rebuild(staged, batch=batch, keys=keys):
            out = dict(batch)
            out.update(zip(keys, staged))
            return out

        return [batch[k] for k in keys], rebuild
    return None


class Prefetcher:
    def __init__(self, source, batch_size: int,
                 collate: Callable, depth: int = 2, *,
                 drain_timeout_s: float = 0.1,
                 idle_timeout_max_s: float = 0.5,
                 stage_batches: bool = False,
                 to_device: bool = False,
                 staging_slabs: int = 4):
        self.source = source
        self.batch_size = batch_size
        self.collate = collate
        self.drain_timeout_s = max(float(drain_timeout_s), 0.001)
        self.idle_timeout_max_s = max(float(idle_timeout_max_s),
                                      self.drain_timeout_s)
        self.stage_batches = bool(stage_batches or to_device)
        self.to_device = bool(to_device)
        self._pool = StagingPool(max_free=staging_slabs)
        self._cache: queue.Queue = queue.Queue(maxsize=depth)
        self._in_use: Optional[_Slab] = None     # slab of the last get()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="prefetcher")
        self.batches_built = 0
        self.bytes_copied = 0        # staged bytes (collate → slab memcpy)
        self.views_served = 0        # ring leases consumed then released
        self.idle_backoffs = 0       # drains that came back empty

    def start(self) -> "Prefetcher":
        self._thread.start()
        return self

    # -- lease + staging plumbing ---------------------------------------------
    def _release_leases(self, segments) -> None:
        """Zero-copy sources stamp a refcounted ring lease into each
        segment; after collate has copied the arrays into the batch the
        views are dead weight — release them so the producer can reclaim
        the ring bytes."""
        for seg in segments:
            if isinstance(seg, dict):
                lease = seg.pop("_lease", None)
                if lease is not None:
                    lease.release()
                    self.views_served += 1

    def _stage(self, batch) -> Tuple[object, Optional[_Slab]]:
        """Assemble ``batch`` into one pooled page-aligned slab (and ship
        it to the device when configured). Returns the staged batch and
        the slab backing it (recycled on the get-after-next)."""
        flat = _flatten_batch(batch)
        if flat is None:
            return batch, None
        leaves, rebuild = flat
        total = sum(_align(leaf.nbytes) for leaf in leaves)
        slab = self._pool.acquire(total)
        staged, off = [], 0
        for leaf in leaves:
            view = (slab.buf[off:off + leaf.nbytes]
                    .view(leaf.dtype).reshape(leaf.shape))
            np.copyto(view, leaf)
            self.bytes_copied += leaf.nbytes
            staged.append(view)
            off += _align(leaf.nbytes)
        out = rebuild(staged)
        if self.to_device:
            out = self._device_put(out)
        return out, slab

    @staticmethod
    def _device_put(batch):
        import jax
        try:
            return jax.device_put(batch, donate=True)
        except TypeError:   # older jax: no donate kwarg on device_put
            return jax.device_put(batch)

    # -- producer loop ----------------------------------------------------------
    def _run(self) -> None:
        # a pop_many source is drained in COALESCED partial batches (one
        # lock/RPC per drain, items accumulate here until a super-batch
        # is full) instead of exact-n pops that wait for the batch to
        # round out while ready items sit in the channel
        pop_many = getattr(self.source, "pop_many", None)
        pending = []
        timeout = self.drain_timeout_s
        while not self._stop.is_set():
            if pop_many is not None:
                got = pop_many(self.batch_size - len(pending),
                               timeout=timeout)
                if got:
                    pending.extend(got)
                    timeout = self.drain_timeout_s
                else:
                    # empty drain: back off so an idle trainer sleeps in
                    # the source instead of waking every slice
                    self.idle_backoffs += 1
                    timeout = min(timeout * 2, self.idle_timeout_max_s)
                if len(pending) < self.batch_size:
                    continue
                segments, pending = pending, []
            else:
                segments = self.source.pop_batch(self.batch_size,
                                                 timeout=timeout)
                if segments is None:
                    self.idle_backoffs += 1
                    timeout = min(timeout * 2, self.idle_timeout_max_s)
                    continue
                timeout = self.drain_timeout_s
            batch = self.collate(segments)
            # collate copied everything it needs; transport views die here
            self._release_leases(segments)
            slab = None
            if self.stage_batches:
                batch, slab = self._stage(batch)
            self.batches_built += 1
            while not self._stop.is_set():
                try:
                    self._cache.put((batch, slab), timeout=0.1)
                    break
                except queue.Full:
                    continue

    # -- consumer surface -------------------------------------------------------
    def get(self, timeout: Optional[float] = None):
        """Pop a ready super-batch (None on timeout). Popping batch N+1
        recycles batch N's staging slab — by then the (sequential)
        trainer has fully consumed N, so even a CPU-backend aliased
        ``device_put`` cannot observe the reuse."""
        try:
            batch, slab = self._cache.get(timeout=timeout)
        except queue.Empty:
            return None
        self._pool.release(self._in_use)
        self._in_use = slab
        return batch

    def metrics(self) -> Dict[str, float]:
        return {
            "batches_built": float(self.batches_built),
            "bytes_copied": float(self.bytes_copied),
            "views_served": float(self.views_served),
            "staging_reuse": float(self._pool.staging_reuse),
            "staging_slabs": float(self._pool.slabs_allocated),
            "idle_backoffs": float(self.idle_backoffs),
        }

    def stop(self) -> None:
        self._stop.set()
        if self._thread.ident is not None:   # only join a started thread
            self._thread.join(timeout=2.0)
