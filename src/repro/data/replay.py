"""Replay buffers (paper §3.1, §4): the non-blocking FIFO trajectory buffer
``B`` feeding the trainer (single-epoch consumption), plus the ring buffer
``B_wm`` of real transitions for world-model training and the FIFO ``B_img``
of imagined segments.

All buffers are host-side, thread-safe, and hold numpy pytrees (trajectory
segments). The trainer-side batching/tensorization happens in the
prefetcher so the training critical path stays clean (App. D.5).

The FIFO buffer supports pluggable backpressure policies (consumed through
:mod:`repro.runtime.experience`, which layers the ExperienceChannel
abstraction on top of these buffers):

  * ``drop_oldest`` — the paper's fully-asynchronous default: producers
    never block, the stalest segments are evicted;
  * ``drop_newest`` — reject the incoming segment (bounded staleness:
    what is already queued wins);
  * ``block``       — producers wait (bounded by a timeout) for the
    consumer, i.e. rollout throughput is clamped to trainer throughput.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, List, Optional

import numpy as np

BACKPRESSURE_POLICIES = ("drop_oldest", "drop_newest", "block")


class FIFOReplayBuffer:
    """FIFO segment queue (the paper's ``B``).

    Producers ``push`` trajectory segments as episodes complete; the trainer
    ``pop_batch``es the oldest segments (single-epoch semantics — each
    segment is trained on once). The ``policy`` decides what happens when
    the buffer is full; the default ``drop_oldest`` never blocks the
    producer (full asynchrony).
    """

    def __init__(self, capacity: int, policy: str = "drop_oldest"):
        if policy not in BACKPRESSURE_POLICIES:
            raise ValueError(f"policy must be one of "
                             f"{BACKPRESSURE_POLICIES}, got {policy!r}")
        self.capacity = capacity
        self.policy = policy
        self._q: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self.total_pushed = 0
        self.total_dropped = 0

    def push(self, segment: Any, timeout: float = 0.5) -> bool:
        """Add a segment; returns False iff it was rejected (``drop_newest``
        full, or ``block`` timed out waiting for space)."""
        with self._lock:
            if len(self._q) >= self.capacity:
                if self.policy == "drop_oldest":
                    self._q.popleft()
                    self.total_dropped += 1
                elif self.policy == "drop_newest":
                    self.total_dropped += 1
                    return False
                else:  # block
                    if not self._not_full.wait_for(
                            lambda: len(self._q) < self.capacity,
                            timeout=timeout):
                        self.total_dropped += 1
                        return False
            self._q.append(segment)
            self.total_pushed += 1
            self._not_empty.notify_all()
            return True

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    def pop_batch(self, n: int, timeout: Optional[float] = None
                  ) -> Optional[List[Any]]:
        """Pop the n oldest segments; blocks until available (or timeout)."""
        with self._not_empty:
            if not self._not_empty.wait_for(lambda: len(self._q) >= n,
                                            timeout=timeout):
                return None
            out = [self._q.popleft() for _ in range(n)]
            self._not_full.notify_all()
            return out

    def pop_upto(self, max_items: int, timeout: Optional[float] = None
                 ) -> Optional[List[Any]]:
        """Coalescing pop: whatever is queued, at most ``max_items``,
        under ONE lock acquisition — blocks (up to ``timeout``) only for
        the first segment. The batch-drain primitive ``pop_many`` rides
        on (one RPC per drain over a remote channel)."""
        if max_items <= 0:
            return None
        with self._not_empty:
            if not self._not_empty.wait_for(lambda: len(self._q) >= 1,
                                            timeout=timeout):
                return None
            out = [self._q.popleft()
                   for _ in range(min(max_items, len(self._q)))]
            self._not_full.notify_all()
            return out

    def drain(self) -> List[Any]:
        """Pop everything currently queued (sync-mode round collection)."""
        with self._lock:
            out = list(self._q)
            self._q.clear()
            self._not_full.notify_all()
            return out

    def peek_depth(self) -> int:
        return len(self)

    def peek_all(self) -> List[Any]:
        """Non-destructive copy of the queued items, oldest first
        (journal snapshot capture)."""
        with self._lock:
            return list(self._q)


class RingReplayBuffer:
    """Uniform-sampling ring buffer (the paper's ``B_wm``)."""

    def __init__(self, capacity: int, seed: int = 0):
        self.capacity = capacity
        self._items: List[Any] = []
        self._ptr = 0
        self._lock = threading.Lock()
        self._rng = np.random.default_rng(seed)
        self.total_pushed = 0

    def push(self, item: Any) -> None:
        with self._lock:
            if len(self._items) < self.capacity:
                self._items.append(item)
            else:
                self._items[self._ptr] = item
                self._ptr = (self._ptr + 1) % self.capacity
            self.total_pushed += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def sample(self, n: int) -> Optional[List[Any]]:
        with self._lock:
            if not self._items:
                return None
            idx = self._rng.integers(0, len(self._items), size=n)
            return [self._items[i] for i in idx]
