from repro.data.prefetch import Prefetcher  # noqa: F401
from repro.data.replay import FIFOReplayBuffer, RingReplayBuffer  # noqa: F401
from repro.data.trajectory import TrajectoryBatch, dummy_batch, stack_batches  # noqa: F401
