"""Service layer: the uniform lifecycle every runtime component implements.

The async pipeline (paper §3) is a set of free-running components — rollout
workers, the inference pool, trainer loops, imagination workers, world-model
trainers. Before this layer each of them hand-rolled its own
``threading.Thread`` + stop-event + ad-hoc counters; the orchestrator had to
know every component's private start/stop dance, and the synchronous
baseline re-implemented the whole loop inline.

:class:`Service` gives all of them one contract:

  * ``start() / stop() / join()`` with an explicit :class:`ServiceState`
    machine (``stop`` is a signal, ``join`` the rendezvous — schedulers own
    the ordering);
  * crash containment — a thread that raises marks the service ``FAILED``
    and records the exception instead of dying silently;
  * a per-service :class:`MetricsRegistry` (counters / gauges / series /
    busy-timers) that ``AcceRLSystem.metrics()`` is rebuilt on, so every
    benchmark and launcher consumes one schema.

:class:`ServiceRegistry` is the bus the orchestrator and schedulers drive:
services register in dependency order, start in that order, stop in
reverse. World-model attachment (paper §4 "plug-and-play") is literally
``system.attach(...)`` registering more services on this bus.
"""
from __future__ import annotations

import collections
import contextlib
import math
import threading
import time
import traceback
from typing import Callable, Dict, Iterable, List, Optional


class ServiceState:
    """String states — cheap to compare, JSON-friendly in health reports."""

    NEW = "new"
    RUNNING = "running"
    STOPPING = "stopping"
    STOPPED = "stopped"
    FAILED = "failed"


#: recent-value window kept per series (``record``); the exact all-time
#: count/total ride alongside, so ``series_mean`` covers every observation
#: while memory stays O(window) over arbitrarily long runs
SERIES_WINDOW = 512

#: log2 histogram layout: bucket 0 holds v < 2^HIST_MIN_EXP (and v <= 0),
#: bucket i holds [2^(HIST_MIN_EXP+i-1), 2^(HIST_MIN_EXP+i)), the top
#: bucket is open-ended. 46 buckets span ~1 µs to ~16e6 — seconds-scale
#: latencies and version/count-scale lags share one fixed layout, which is
#: what makes histograms from different incarnations mergeable bucketwise.
HIST_MIN_EXP = -20
HIST_BUCKETS = 46


def _hist_bucket(value: float) -> int:
    if value <= 0.0 or value < 2.0 ** HIST_MIN_EXP:
        return 0
    idx = int(math.floor(math.log2(value))) - HIST_MIN_EXP + 1
    return max(0, min(idx, HIST_BUCKETS - 1))


def _hist_copy(h: Dict) -> Dict:
    out = dict(h)
    out["buckets"] = {str(k): int(v) for k, v in h.get("buckets",
                                                       {}).items()}
    return out


def _hist_merge(a: Optional[Dict], b: Optional[Dict]) -> Dict:
    """Bucketwise sum of two histogram summaries (either may be None).
    Pure addition — the merge is associative and commutative, so folds
    across incarnations and across services cannot double-count."""
    if not a:
        return _hist_copy(b or {"count": 0, "sum": 0.0, "min": 0.0,
                                "max": 0.0, "buckets": {}})
    if not b:
        return _hist_copy(a)
    out = _hist_copy(a)
    out["count"] = int(a.get("count", 0)) + int(b.get("count", 0))
    out["sum"] = float(a.get("sum", 0.0)) + float(b.get("sum", 0.0))
    out["min"] = min(a.get("min", b.get("min", 0.0)), b.get("min", 0.0))
    out["max"] = max(a.get("max", b.get("max", 0.0)), b.get("max", 0.0))
    for k, v in b.get("buckets", {}).items():
        k = str(k)
        out["buckets"][k] = out["buckets"].get(k, 0) + int(v)
    return out


class _SeriesStore:
    """Bounded series storage: exact cumulative count/total plus a recent
    window — ``series_mean`` stays the mean over ALL observations while a
    week-long run no longer grows a per-observation list."""

    __slots__ = ("count", "total", "window")

    def __init__(self, window: int = SERIES_WINDOW):
        self.count = 0
        self.total = 0.0
        self.window: "collections.deque[float]" = collections.deque(
            maxlen=window)

    def add(self, value: float) -> None:
        self.count += 1
        self.total += value
        self.window.append(value)


class MetricsRegistry:
    """Thread-safe counters, gauges, scalar series and histograms for one
    service.

    Counters are monotone floats (``inc``); gauges are last-write-wins;
    series accumulate observations (episode returns, policy lag) into a
    bounded window + exact running mean and snapshot as count/mean/last;
    histograms (``observe``) bucket observations into a fixed log2 layout
    so distributions (queue waits, batch ages, policy lag) survive the
    wire and merge across worker incarnations without double-counting.
    """

    def __init__(self, name: str = ""):
        self.name = name
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._series: Dict[str, _SeriesStore] = {}
        self._hists: Dict[str, Dict] = {}
        # cross-process bridge: counters/gauges/series/hists adopted from a
        # remote replica (a supervised worker slot mirrors its child
        # through these). Counters are split into the CURRENT incarnation's
        # absolute values plus a base folded in at each restart
        # (``begin_remote_incarnation``), so a worker that restarts and
        # re-reports from zero aggregates monotonically instead of
        # rewinding or double-counting.
        self._remote_counters: Dict[str, float] = {}
        self._remote_counter_base: Dict[str, float] = {}
        self._remote_gauges: Dict[str, float] = {}
        self._remote_series: Dict[str, Dict] = {}
        self._remote_series_base: Dict[str, Dict] = {}
        self._remote_hists: Dict[str, Dict] = {}
        self._remote_hist_base: Dict[str, Dict] = {}

    # -- counters -----------------------------------------------------------
    def inc(self, key: str, by: float = 1.0) -> float:
        with self._lock:
            val = self._counters.get(key, 0.0) + by
            self._counters[key] = val
            return val

    def counter(self, key: str, default: float = 0.0) -> float:
        with self._lock:
            if (key not in self._counters
                    and key not in self._remote_counters
                    and key not in self._remote_counter_base):
                return default
            return (self._counters.get(key, 0.0)
                    + self._remote_counter_base.get(key, 0.0)
                    + self._remote_counters.get(key, 0.0))

    # -- gauges -------------------------------------------------------------
    def set_gauge(self, key: str, value: float) -> None:
        with self._lock:
            self._gauges[key] = float(value)

    def gauge(self, key: str, default: float = 0.0) -> float:
        with self._lock:
            if key in self._remote_gauges:
                return self._remote_gauges[key]
            return self._gauges.get(key, default)

    # -- series -------------------------------------------------------------
    def record(self, key: str, value: float) -> None:
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = _SeriesStore()
            s.add(float(value))

    def series(self, key: str) -> List[float]:
        """The recent window of observations (newest last) — bounded at
        ``SERIES_WINDOW``; use ``series_mean``/``snapshot`` for all-time
        aggregates."""
        with self._lock:
            s = self._series.get(key)
            return list(s.window) if s is not None else []

    def series_mean(self, key: str, default: float = 0.0) -> float:
        with self._lock:
            s = self._series.get(key)
            if s is not None and s.count:
                return s.total / s.count
            remote = self._merged_remote_series().get(key)
            return remote["mean"] if remote else default

    # -- histograms ---------------------------------------------------------
    def observe(self, key: str, value: float) -> None:
        """Add one observation to the fixed log2-bucket histogram ``key``
        (see ``HIST_MIN_EXP``/``HIST_BUCKETS``). Bucket keys are strings
        so summaries survive JSON framing and the journal unchanged."""
        value = float(value)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = {"count": 0, "sum": 0.0,
                                        "min": value, "max": value,
                                        "buckets": {}}
            h["count"] += 1
            h["sum"] += value
            h["min"] = min(h["min"], value)
            h["max"] = max(h["max"], value)
            b = str(_hist_bucket(value))
            h["buckets"][b] = h["buckets"].get(b, 0) + 1

    def hist(self, key: str, default: Optional[Dict] = None
             ) -> Optional[Dict]:
        """Merged histogram summary (local + every remote incarnation):
        ``{"count", "sum", "min", "max", "buckets": {str(idx): n}}``."""
        with self._lock:
            out = self._merged_remote_hists().get(key)
            local = self._hists.get(key)
            if local:
                out = _hist_merge(out, local)
            return out if out else default

    # -- cross-process bridging ---------------------------------------------
    def apply_remote(self, snapshot: Dict) -> None:
        """Adopt a snapshot reported by a remote (cross-process) replica:
        the remote is the source of truth for its counters/gauges, and
        series arrive pre-summarized (count/mean/last), feeding
        ``snapshot()`` / ``series_mean()``.

        Re-applying the same snapshot is idempotent (absolute values, not
        deltas); counters from a NEW incarnation of the worker must be
        preceded by :meth:`begin_remote_incarnation` so the previous
        incarnation's totals fold into a base instead of being rewound."""
        with self._lock:
            for k, v in snapshot.get("counters", {}).items():
                self._remote_counters[k] = float(v)
            for k, v in snapshot.get("gauges", {}).items():
                self._remote_gauges[k] = float(v)
            self._remote_series = {k: dict(v) for k, v in
                                   snapshot.get("series", {}).items()}
            self._remote_hists = {k: _hist_copy(v) for k, v in
                                  snapshot.get("hists", {}).items()}

    def begin_remote_incarnation(self) -> None:
        """A supervised worker is being restarted: fold the dead
        incarnation's counters/series into the monotone base (so totals
        never rewind or double-count when the replacement re-reports from
        zero) and reset its gauges (a gauge describes the live process —
        there is none until the replacement reports)."""
        with self._lock:
            for k, v in self._remote_counters.items():
                self._remote_counter_base[k] = (
                    self._remote_counter_base.get(k, 0.0) + v)
            self._remote_counters = {}
            self._remote_gauges = {}
            self._remote_series_base = self._merged_remote_series()
            self._remote_series = {}
            self._remote_hist_base = self._merged_remote_hists()
            self._remote_hists = {}

    def _merged_remote_series(self) -> Dict[str, Dict]:
        """Count-weighted fold of the base (dead incarnations) and current
        series summaries. Caller holds the lock."""
        merged = {k: dict(v) for k, v in self._remote_series_base.items()}
        for k, cur in self._remote_series.items():
            base = merged.get(k)
            if base is None or not base["count"]:
                merged[k] = dict(cur)
                continue
            total = base["count"] + cur["count"]
            if cur["count"]:
                merged[k] = {
                    "count": total,
                    "mean": (base["mean"] * base["count"]
                             + cur["mean"] * cur["count"]) / total,
                    "last": cur["last"],
                }
        return merged

    def _merged_remote_hists(self) -> Dict[str, Dict]:
        """Bucketwise fold of the base (dead incarnations) and current
        remote histograms. Caller holds the lock."""
        merged = {k: _hist_copy(v) for k, v in
                  self._remote_hist_base.items()}
        for k, cur in self._remote_hists.items():
            merged[k] = _hist_merge(merged.get(k), cur)
        return merged

    # -- timers -------------------------------------------------------------
    @contextlib.contextmanager
    def timer(self, key: str):
        """Accumulate elapsed wall seconds into counter ``key``."""
        t0 = time.monotonic()
        try:
            yield
        finally:
            self.inc(key, time.monotonic() - t0)

    def snapshot(self) -> Dict:
        with self._lock:
            series = self._merged_remote_series()
            series.update({
                k: {"count": s.count,
                    "mean": (s.total / s.count) if s.count else 0.0,
                    "last": s.window[-1] if s.window else 0.0}
                for k, s in self._series.items()
            })
            hists = self._merged_remote_hists()
            for k, h in self._hists.items():
                hists[k] = _hist_merge(hists.get(k), h)
            counters = dict(self._counters)
            for k in set(self._remote_counters) | set(
                    self._remote_counter_base):
                counters[k] = (counters.get(k, 0.0)
                               + self._remote_counter_base.get(k, 0.0)
                               + self._remote_counters.get(k, 0.0))
            return {
                "counters": counters,
                "gauges": {**self._gauges, **self._remote_gauges},
                "series": series,
                "hists": hists,
            }


class RolloutGate:
    """Pacing hook a scheduler hands to rollout-style producer loops.

    The free-running (async) pipeline uses :class:`NullGate`; the
    synchronous baseline's :class:`~repro.runtime.scheduler.BarrierGate`
    implements the paper's step/episode barriers behind the same calls, so
    the producer loop itself is identical in both modes.
    """

    def begin_episode(self, stop: threading.Event) -> bool:
        """Block until an episode may start; False means shutting down."""
        raise NotImplementedError

    def before_step(self, stop: threading.Event) -> None:
        """Called before every env step (sync mode: the step barrier)."""
        raise NotImplementedError

    def end_episode(self) -> None:
        """Called exactly once per ``begin_episode`` that returned True."""
        raise NotImplementedError


class NullGate(RolloutGate):
    """Free-running: never blocks (the fully asynchronous mode)."""

    def begin_episode(self, stop: threading.Event) -> bool:
        return not stop.is_set()

    def before_step(self, stop: threading.Event) -> None:
        pass

    def end_episode(self) -> None:
        pass


NULL_GATE = NullGate()


class Service:
    """Base class for every runtime component (rollout, inference, trainer,
    imagination, WM trainers). Subclasses implement ``_run`` (or override
    ``_thread_targets`` for multi-threaded pools) plus optional
    ``on_start`` / ``on_stop`` hooks."""

    def __init__(self, name: str, *, role: str = "service"):
        self.name = name
        self.role = role
        self.metrics = MetricsRegistry(name)
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._state = ServiceState.NEW
        self._state_lock = threading.Lock()
        self.error: Optional[BaseException] = None
        #: structured record of the crash that FAILED this service (None
        #: while healthy): service/incarnation/timestamps/traceback —
        #: surfaced through ``health()`` and the telemetry sink instead of
        #: living only on a stderr nobody captured
        self.crash: Optional[Dict] = None
        self.started_at: Optional[float] = None

    # -- subclass surface ---------------------------------------------------
    def _run(self) -> None:
        raise NotImplementedError

    def _thread_targets(self) -> List[Callable[[], None]]:
        return [self._run]

    def on_start(self) -> None:
        """Hook run before threads spawn (publish weights, start helpers)."""

    def on_stop(self) -> None:
        """Hook run when stop is signalled (stop helpers)."""

    # -- lifecycle ----------------------------------------------------------
    @property
    def status(self) -> str:
        with self._state_lock:
            return self._state

    def _set_state(self, state: str) -> None:
        with self._state_lock:
            # FAILED is terminal — a crashed thread must stay visible
            if self._state != ServiceState.FAILED:
                self._state = state

    def start(self) -> "Service":
        if self.status != ServiceState.NEW:
            raise RuntimeError(
                f"service {self.name!r} already started (state={self.status})")
        self.started_at = time.monotonic()
        self.on_start()
        for i, target in enumerate(self._thread_targets()):
            t = threading.Thread(target=self._guard, args=(target,),
                                 daemon=True, name=f"{self.name}-{i}"
                                 if i else self.name)
            t.start()
            self._threads.append(t)
        self._set_state(ServiceState.RUNNING)
        return self

    def _crash_record(self, error: BaseException,
                      tb: Optional[str] = None) -> Dict:
        return {
            "service": self.name,
            "incarnation": int(getattr(self, "incarnation", 0)),
            "t_mono": time.monotonic(),
            "time": time.time(),
            "thread": threading.current_thread().name,
            "error": repr(error),
            "traceback": tb if tb is not None else "".join(
                traceback.format_exception(type(error), error,
                                           error.__traceback__)),
        }

    def _guard(self, target: Callable[[], None]) -> None:
        try:
            target()
        except BaseException as e:   # noqa: BLE001 — surface crashes as health
            self.error = e
            self.crash = self._crash_record(e, traceback.format_exc())
            with self._state_lock:
                self._state = ServiceState.FAILED
            traceback.print_exc()    # stderr stays useful for foreground runs

    def mark_failed(self, error: BaseException) -> None:
        """Mark this service FAILED from outside its own threads — how a
        supervisor surfaces a failure that happened in another process
        (or on the wire) with the exact semantics of a local crash."""
        self.error = error
        self.crash = self._crash_record(error)
        with self._state_lock:
            self._state = ServiceState.FAILED

    def stop(self) -> None:
        """Signal shutdown (non-blocking; pair with ``join``)."""
        if self.status == ServiceState.NEW:
            self._set_state(ServiceState.STOPPED)
            return
        self._stop.set()
        self._set_state(ServiceState.STOPPING)
        self.on_stop()

    def join(self, timeout: float = 5.0) -> None:
        deadline = time.monotonic() + timeout
        for t in self._threads:
            t.join(timeout=max(deadline - time.monotonic(), 0.0))
        if self.status == ServiceState.STOPPING and not any(
                t.is_alive() for t in self._threads):
            self._set_state(ServiceState.STOPPED)

    # -- health + metrics ---------------------------------------------------
    @property
    def healthy(self) -> bool:
        return self.error is None and self.status in (ServiceState.NEW,
                                                     ServiceState.RUNNING,
                                                     ServiceState.STOPPING,
                                                     ServiceState.STOPPED)

    def health(self) -> Dict:
        return {"state": self.status, "healthy": self.healthy,
                "uptime_s": self.uptime_s,
                "error": repr(self.error) if self.error else None,
                "crash": self.crash}

    @property
    def uptime_s(self) -> float:
        if self.started_at is None:
            return 0.0
        return time.monotonic() - self.started_at

    def utilization(self) -> float:
        """busy_s / uptime — services time hot sections into ``busy_s``."""
        if self.started_at is None:
            return 0.0
        return self.metrics.counter("busy_s") / max(self.uptime_s, 1e-9)


class ServiceRegistry:
    """Ordered service bus: register in dependency order, start in that
    order, stop in reverse. The orchestrator owns one; attachments (the
    world model) register additional services on it."""

    def __init__(self):
        self._services: Dict[str, Service] = {}

    def register(self, service: Service) -> Service:
        if service.name in self._services:
            raise ValueError(f"duplicate service name {service.name!r}")
        self._services[service.name] = service
        return service

    def deregister(self, name: str) -> Optional[Service]:
        return self._services.pop(name, None)

    def get(self, name: str) -> Service:
        return self._services[name]

    def __contains__(self, name: str) -> bool:
        return name in self._services

    def all(self, *, role: Optional[str] = None,
            exclude_roles: Iterable[str] = ()) -> List[Service]:
        ex = set(exclude_roles)
        return [s for s in self._services.values()
                if (role is None or s.role == role) and s.role not in ex]

    # -- bulk lifecycle -----------------------------------------------------
    def start_all(self, *, exclude_roles: Iterable[str] = ()) -> None:
        for s in self.all(exclude_roles=exclude_roles):
            s.start()

    def stop_all(self) -> None:
        for s in reversed(list(self._services.values())):
            s.stop()

    def join_all(self, timeout: float = 5.0) -> None:
        for s in reversed(list(self._services.values())):
            s.join(timeout=timeout)

    # -- reporting ----------------------------------------------------------
    def health(self) -> Dict[str, Dict]:
        return {name: s.health() for name, s in self._services.items()}

    def snapshot(self) -> Dict[str, Dict]:
        return {name: s.metrics.snapshot()
                for name, s in self._services.items()}
