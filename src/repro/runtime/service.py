"""Service layer: the uniform lifecycle every runtime component implements.

The async pipeline (paper §3) is a set of free-running components — rollout
workers, the inference pool, trainer loops, imagination workers, world-model
trainers. Before this layer each of them hand-rolled its own
``threading.Thread`` + stop-event + ad-hoc counters; the orchestrator had to
know every component's private start/stop dance, and the synchronous
baseline re-implemented the whole loop inline.

:class:`Service` gives all of them one contract:

  * ``start() / stop() / join()`` with an explicit :class:`ServiceState`
    machine (``stop`` is a signal, ``join`` the rendezvous — schedulers own
    the ordering);
  * crash containment — a thread that raises marks the service ``FAILED``
    and records the exception instead of dying silently;
  * a per-service :class:`MetricsRegistry` (counters / gauges / series /
    busy-timers) that ``AcceRLSystem.metrics()`` is rebuilt on, so every
    benchmark and launcher consumes one schema.

:class:`ServiceRegistry` is the bus the orchestrator and schedulers drive:
services register in dependency order, start in that order, stop in
reverse. World-model attachment (paper §4 "plug-and-play") is literally
``system.attach(...)`` registering more services on this bus.
"""
from __future__ import annotations

import contextlib
import threading
import time
import traceback
from typing import Callable, Dict, Iterable, List, Optional


class ServiceState:
    """String states — cheap to compare, JSON-friendly in health reports."""

    NEW = "new"
    RUNNING = "running"
    STOPPING = "stopping"
    STOPPED = "stopped"
    FAILED = "failed"


class MetricsRegistry:
    """Thread-safe counters, gauges and scalar series for one service.

    Counters are monotone floats (``inc``); gauges are last-write-wins;
    series accumulate observations (episode returns, policy lag) and
    snapshot as count/mean/last so the report stays bounded.
    """

    def __init__(self, name: str = ""):
        self.name = name
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._series: Dict[str, List[float]] = {}
        # cross-process bridge: counters/gauges/series adopted from a
        # remote replica (a supervised worker slot mirrors its child
        # through these). Counters are split into the CURRENT incarnation's
        # absolute values plus a base folded in at each restart
        # (``begin_remote_incarnation``), so a worker that restarts and
        # re-reports from zero aggregates monotonically instead of
        # rewinding or double-counting.
        self._remote_counters: Dict[str, float] = {}
        self._remote_counter_base: Dict[str, float] = {}
        self._remote_gauges: Dict[str, float] = {}
        self._remote_series: Dict[str, Dict] = {}
        self._remote_series_base: Dict[str, Dict] = {}

    # -- counters -----------------------------------------------------------
    def inc(self, key: str, by: float = 1.0) -> float:
        with self._lock:
            val = self._counters.get(key, 0.0) + by
            self._counters[key] = val
            return val

    def counter(self, key: str, default: float = 0.0) -> float:
        with self._lock:
            if (key not in self._counters
                    and key not in self._remote_counters
                    and key not in self._remote_counter_base):
                return default
            return (self._counters.get(key, 0.0)
                    + self._remote_counter_base.get(key, 0.0)
                    + self._remote_counters.get(key, 0.0))

    # -- gauges -------------------------------------------------------------
    def set_gauge(self, key: str, value: float) -> None:
        with self._lock:
            self._gauges[key] = float(value)

    def gauge(self, key: str, default: float = 0.0) -> float:
        with self._lock:
            if key in self._remote_gauges:
                return self._remote_gauges[key]
            return self._gauges.get(key, default)

    # -- series -------------------------------------------------------------
    def record(self, key: str, value: float) -> None:
        with self._lock:
            self._series.setdefault(key, []).append(float(value))

    def series(self, key: str) -> List[float]:
        with self._lock:
            return list(self._series.get(key, ()))

    def series_mean(self, key: str, default: float = 0.0) -> float:
        with self._lock:
            s = self._series.get(key)
            if s:
                return sum(s) / len(s)
            remote = self._merged_remote_series().get(key)
            return remote["mean"] if remote else default

    # -- cross-process bridging ---------------------------------------------
    def apply_remote(self, snapshot: Dict) -> None:
        """Adopt a snapshot reported by a remote (cross-process) replica:
        the remote is the source of truth for its counters/gauges, and
        series arrive pre-summarized (count/mean/last), feeding
        ``snapshot()`` / ``series_mean()``.

        Re-applying the same snapshot is idempotent (absolute values, not
        deltas); counters from a NEW incarnation of the worker must be
        preceded by :meth:`begin_remote_incarnation` so the previous
        incarnation's totals fold into a base instead of being rewound."""
        with self._lock:
            for k, v in snapshot.get("counters", {}).items():
                self._remote_counters[k] = float(v)
            for k, v in snapshot.get("gauges", {}).items():
                self._remote_gauges[k] = float(v)
            self._remote_series = {k: dict(v) for k, v in
                                   snapshot.get("series", {}).items()}

    def begin_remote_incarnation(self) -> None:
        """A supervised worker is being restarted: fold the dead
        incarnation's counters/series into the monotone base (so totals
        never rewind or double-count when the replacement re-reports from
        zero) and reset its gauges (a gauge describes the live process —
        there is none until the replacement reports)."""
        with self._lock:
            for k, v in self._remote_counters.items():
                self._remote_counter_base[k] = (
                    self._remote_counter_base.get(k, 0.0) + v)
            self._remote_counters = {}
            self._remote_gauges = {}
            self._remote_series_base = self._merged_remote_series()
            self._remote_series = {}

    def _merged_remote_series(self) -> Dict[str, Dict]:
        """Count-weighted fold of the base (dead incarnations) and current
        series summaries. Caller holds the lock."""
        merged = {k: dict(v) for k, v in self._remote_series_base.items()}
        for k, cur in self._remote_series.items():
            base = merged.get(k)
            if base is None or not base["count"]:
                merged[k] = dict(cur)
                continue
            total = base["count"] + cur["count"]
            if cur["count"]:
                merged[k] = {
                    "count": total,
                    "mean": (base["mean"] * base["count"]
                             + cur["mean"] * cur["count"]) / total,
                    "last": cur["last"],
                }
        return merged

    # -- timers -------------------------------------------------------------
    @contextlib.contextmanager
    def timer(self, key: str):
        """Accumulate elapsed wall seconds into counter ``key``."""
        t0 = time.monotonic()
        try:
            yield
        finally:
            self.inc(key, time.monotonic() - t0)

    def snapshot(self) -> Dict:
        with self._lock:
            series = self._merged_remote_series()
            series.update({
                k: {"count": len(v),
                    "mean": (sum(v) / len(v)) if v else 0.0,
                    "last": v[-1] if v else 0.0}
                for k, v in self._series.items()
            })
            counters = dict(self._counters)
            for k in set(self._remote_counters) | set(
                    self._remote_counter_base):
                counters[k] = (counters.get(k, 0.0)
                               + self._remote_counter_base.get(k, 0.0)
                               + self._remote_counters.get(k, 0.0))
            return {
                "counters": counters,
                "gauges": {**self._gauges, **self._remote_gauges},
                "series": series,
            }


class RolloutGate:
    """Pacing hook a scheduler hands to rollout-style producer loops.

    The free-running (async) pipeline uses :class:`NullGate`; the
    synchronous baseline's :class:`~repro.runtime.scheduler.BarrierGate`
    implements the paper's step/episode barriers behind the same calls, so
    the producer loop itself is identical in both modes.
    """

    def begin_episode(self, stop: threading.Event) -> bool:
        """Block until an episode may start; False means shutting down."""
        raise NotImplementedError

    def before_step(self, stop: threading.Event) -> None:
        """Called before every env step (sync mode: the step barrier)."""
        raise NotImplementedError

    def end_episode(self) -> None:
        """Called exactly once per ``begin_episode`` that returned True."""
        raise NotImplementedError


class NullGate(RolloutGate):
    """Free-running: never blocks (the fully asynchronous mode)."""

    def begin_episode(self, stop: threading.Event) -> bool:
        return not stop.is_set()

    def before_step(self, stop: threading.Event) -> None:
        pass

    def end_episode(self) -> None:
        pass


NULL_GATE = NullGate()


class Service:
    """Base class for every runtime component (rollout, inference, trainer,
    imagination, WM trainers). Subclasses implement ``_run`` (or override
    ``_thread_targets`` for multi-threaded pools) plus optional
    ``on_start`` / ``on_stop`` hooks."""

    def __init__(self, name: str, *, role: str = "service"):
        self.name = name
        self.role = role
        self.metrics = MetricsRegistry(name)
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._state = ServiceState.NEW
        self._state_lock = threading.Lock()
        self.error: Optional[BaseException] = None
        self.started_at: Optional[float] = None

    # -- subclass surface ---------------------------------------------------
    def _run(self) -> None:
        raise NotImplementedError

    def _thread_targets(self) -> List[Callable[[], None]]:
        return [self._run]

    def on_start(self) -> None:
        """Hook run before threads spawn (publish weights, start helpers)."""

    def on_stop(self) -> None:
        """Hook run when stop is signalled (stop helpers)."""

    # -- lifecycle ----------------------------------------------------------
    @property
    def status(self) -> str:
        with self._state_lock:
            return self._state

    def _set_state(self, state: str) -> None:
        with self._state_lock:
            # FAILED is terminal — a crashed thread must stay visible
            if self._state != ServiceState.FAILED:
                self._state = state

    def start(self) -> "Service":
        if self.status != ServiceState.NEW:
            raise RuntimeError(
                f"service {self.name!r} already started (state={self.status})")
        self.started_at = time.monotonic()
        self.on_start()
        for i, target in enumerate(self._thread_targets()):
            t = threading.Thread(target=self._guard, args=(target,),
                                 daemon=True, name=f"{self.name}-{i}"
                                 if i else self.name)
            t.start()
            self._threads.append(t)
        self._set_state(ServiceState.RUNNING)
        return self

    def _guard(self, target: Callable[[], None]) -> None:
        try:
            target()
        except BaseException as e:   # noqa: BLE001 — surface crashes as health
            self.error = e
            with self._state_lock:
                self._state = ServiceState.FAILED
            traceback.print_exc()

    def mark_failed(self, error: BaseException) -> None:
        """Mark this service FAILED from outside its own threads — how a
        supervisor surfaces a failure that happened in another process
        (or on the wire) with the exact semantics of a local crash."""
        self.error = error
        with self._state_lock:
            self._state = ServiceState.FAILED

    def stop(self) -> None:
        """Signal shutdown (non-blocking; pair with ``join``)."""
        if self.status == ServiceState.NEW:
            self._set_state(ServiceState.STOPPED)
            return
        self._stop.set()
        self._set_state(ServiceState.STOPPING)
        self.on_stop()

    def join(self, timeout: float = 5.0) -> None:
        deadline = time.monotonic() + timeout
        for t in self._threads:
            t.join(timeout=max(deadline - time.monotonic(), 0.0))
        if self.status == ServiceState.STOPPING and not any(
                t.is_alive() for t in self._threads):
            self._set_state(ServiceState.STOPPED)

    # -- health + metrics ---------------------------------------------------
    @property
    def healthy(self) -> bool:
        return self.error is None and self.status in (ServiceState.NEW,
                                                     ServiceState.RUNNING,
                                                     ServiceState.STOPPING,
                                                     ServiceState.STOPPED)

    def health(self) -> Dict:
        return {"state": self.status, "healthy": self.healthy,
                "uptime_s": self.uptime_s,
                "error": repr(self.error) if self.error else None}

    @property
    def uptime_s(self) -> float:
        if self.started_at is None:
            return 0.0
        return time.monotonic() - self.started_at

    def utilization(self) -> float:
        """busy_s / uptime — services time hot sections into ``busy_s``."""
        if self.started_at is None:
            return 0.0
        return self.metrics.counter("busy_s") / max(self.uptime_s, 1e-9)


class ServiceRegistry:
    """Ordered service bus: register in dependency order, start in that
    order, stop in reverse. The orchestrator owns one; attachments (the
    world model) register additional services on it."""

    def __init__(self):
        self._services: Dict[str, Service] = {}

    def register(self, service: Service) -> Service:
        if service.name in self._services:
            raise ValueError(f"duplicate service name {service.name!r}")
        self._services[service.name] = service
        return service

    def deregister(self, name: str) -> Optional[Service]:
        return self._services.pop(name, None)

    def get(self, name: str) -> Service:
        return self._services[name]

    def __contains__(self, name: str) -> bool:
        return name in self._services

    def all(self, *, role: Optional[str] = None,
            exclude_roles: Iterable[str] = ()) -> List[Service]:
        ex = set(exclude_roles)
        return [s for s in self._services.values()
                if (role is None or s.role == role) and s.role not in ex]

    # -- bulk lifecycle -----------------------------------------------------
    def start_all(self, *, exclude_roles: Iterable[str] = ()) -> None:
        for s in self.all(exclude_roles=exclude_roles):
            s.start()

    def stop_all(self) -> None:
        for s in reversed(list(self._services.values())):
            s.stop()

    def join_all(self, timeout: float = 5.0) -> None:
        for s in reversed(list(self._services.values())):
            s.join(timeout=timeout)

    # -- reporting ----------------------------------------------------------
    def health(self) -> Dict[str, Dict]:
        return {name: s.health() for name, s in self._services.items()}

    def snapshot(self) -> Dict[str, Dict]:
        return {name: s.metrics.snapshot()
                for name, s in self._services.items()}
