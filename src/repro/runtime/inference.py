"""Inference-as-a-Service pool (paper §3.2).

Rollout workers submit per-env observation requests and suspend; every
inference worker drains a shared request queue and triggers a batched
forward pass under the dynamic window rule (eq. 1):

    Trigger = (|Q| >= B) ∨ (t_now − t_first >= T_max)

TPU adaptation (DESIGN.md §2): dynamic batches are padded up to the nearest
bucket size so the jitted program never recompiles for new batch shapes.

The drain protocol (App. D.6): when the weight store raises its drain flag,
workers stop scheduling NEW batches, finish the in-flight one, then swap
weights in place before resuming — update atomicity + version consistency.

The pool is a :class:`~repro.runtime.service.Service` with one thread per
``rt.num_inference_workers``. The live window parameters
(``window_batch`` / ``window_wait_s``) are mutable so a scheduler can
re-shape the eq.-1 trigger — the barrier scheduler widens the window to
one-batch-per-lockstep-tick to reproduce the synchronous step barrier.
"""
from __future__ import annotations

import os
import queue
import threading
import time
from concurrent.futures import Future
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig, RuntimeConfig
from repro.models.policy import make_inference_fn
from repro.models.transformer import FRONTEND_DIM
from repro.runtime.service import Service
from repro.runtime.weight_store import VersionedWeightStore

# Import-gated tracing (see transport.faults for the idiom).
if os.environ.get("REPRO_TRACE"):
    from repro.runtime import telemetry as _tel
else:  # pragma: no cover - default path
    _tel = None


class _Request:
    __slots__ = ("obs_tokens", "frame", "step", "future", "t_arrival")

    def __init__(self, obs_tokens, frame, step):
        self.obs_tokens = obs_tokens        # [T_obs] i32
        self.frame = frame                  # [F] f32 or None
        self.step = step                    # int
        self.future: Future = Future()
        self.t_arrival = time.monotonic()


def pad_to_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket that fits ``n`` requests. ``n`` larger than the
    biggest bucket is the caller's bug — windows must be split first
    (``split_window``), otherwise the pad count would go negative and the
    stacked batch would silently carry ``n`` rows instead of ``nb``."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"window of {n} requests exceeds the largest batch bucket "
        f"{buckets[-1]}; split the window before padding")


def split_window(n: int, buckets: Sequence[int]) -> List[int]:
    """Chunk an ``n``-request window into bucket-sized pieces: full largest
    buckets, then one bucket-padded remainder."""
    top = buckets[-1]
    sizes = [top] * (n // top)
    if n % top:
        sizes.append(n % top)
    return sizes


class InferenceService(Service):
    """Centralized inference pool: one shared queue, N worker threads."""

    def __init__(self, cfg: ModelConfig, store: VersionedWeightStore,
                 rt: RuntimeConfig, *, temperature: float = 1.0, seed: int = 0):
        super().__init__("inference", role="inference")
        self.cfg = cfg
        self.store = store
        self.rt = rt
        self._fn = make_inference_fn(cfg, temperature)
        self._q: "queue.Queue[_Request]" = queue.Queue()
        self._key = jax.random.PRNGKey(seed)
        self._key_lock = threading.Lock()
        # live eq.-1 window parameters (schedulers may re-shape these)
        self.window_batch = rt.inference_batch
        self.window_wait_s = rt.inference_max_wait_s
        # versions whose first post-swap action has been trace-marked
        # (closes the publish -> acquire -> first-action flow)
        self._first_action_traced: set = set()

    # -- registry-backed counters ----------------------------------------------
    @property
    def batches_run(self) -> int:
        return int(self.metrics.counter("batches"))

    @property
    def requests_served(self) -> int:
        return int(self.metrics.counter("requests"))

    @property
    def padded_slots(self) -> int:
        return int(self.metrics.counter("padded_slots"))

    @property
    def weight_swaps(self) -> int:
        return int(self.metrics.counter("weight_swaps"))

    @property
    def degenerate_batches(self) -> int:
        return int(self.metrics.counter("degenerate_batches"))

    # -- client API -----------------------------------------------------------
    def submit(self, obs_tokens: np.ndarray, frame: Optional[np.ndarray],
               step: int) -> Future:
        """Asynchronous request; the rollout worker suspends on the future."""
        req = _Request(obs_tokens, frame, step)
        self._q.put(req)
        return req.future

    # -- service surface --------------------------------------------------------
    def _thread_targets(self):
        return [self._run] * self.rt.num_inference_workers

    # -- worker loop --------------------------------------------------------------
    def _next_key(self):
        with self._key_lock:
            self._key, sub = jax.random.split(self._key)
        return sub

    def _collect_window(self) -> List[_Request]:
        """Dynamic-window batching, eq. 1.

        The T_max timer anchors to COLLECTION start, not the first
        request's arrival: a request that sat queued while a previous
        batch was in flight would otherwise expire the window the moment
        it is picked up, dispatching degenerate 1-item batches exactly
        when the queue is busiest (the window never gets its T_max to
        fill). Queue wait before collection is tracked separately as the
        ``queue_wait_s`` series.
        """
        reqs: List[_Request] = []
        t_start = None
        while not self._stop.is_set():
            b, t_max = self.window_batch, self.window_wait_s
            timeout = 0.002 if t_start is None else max(
                0.0, t_max - (time.monotonic() - t_start))
            try:
                r = self._q.get(timeout=max(timeout, 1e-4))
                now = time.monotonic()
                if t_start is None:
                    t_start = now
                reqs.append(r)
                wait = max(now - r.t_arrival, 0.0)
                self.metrics.record("queue_wait_s", wait)
                self.metrics.observe("queue_wait_s", wait)
            except queue.Empty:
                pass
            if reqs and (len(reqs) >= b or
                         time.monotonic() - t_start >= t_max):
                # eq.-1 vital: how long the window took to fill (or time
                # out) from the first request picked up to dispatch
                self.metrics.observe("window_fill_s",
                                     time.monotonic() - t_start)
                return reqs
        return reqs

    def _note_swap(self, version: int) -> None:
        self.metrics.inc("weight_swaps")
        # bridged gauge: remote workers report which policy version
        # their colocated inference pool is serving
        self.metrics.set_gauge("weight_version", float(version))
        if _tel is not None:
            # middle leg of the policy-lag flow (version is the flow id)
            _tel.instant("weights.acquire", cat="weights",
                         trace=int(version),
                         args={"version": int(version)}, flow="step")

    def _run(self) -> None:
        params, version = None, -1
        while not self._stop.is_set():
            # drain protocol: no NEW batch while the trainer is publishing
            if self.store.draining or params is None:
                got = self.store.acquire(newer_than=version, timeout=0.1)
                if got is not None:
                    params, version = got
                    self._note_swap(version)
                if params is None:
                    continue
            reqs = self._collect_window()
            if not reqs:
                continue
            # the drain flag may have been raised while this worker was
            # parked inside _collect_window — a window carved AFTER the
            # signal is a NEW batch and must wait for the swap (update
            # atomicity: no batch starts on stale weights mid-publish)
            while self.store.draining and not self._stop.is_set():
                got = self.store.acquire(newer_than=version, timeout=0.1)
                if got is not None:
                    params, version = got
                    self._note_swap(version)
                    break
            if len(reqs) == 1:
                # a 1-item window after a non-empty wait is the shape the
                # wait-anchoring bug produced; kept as a counter so the
                # regression stays observable in metrics()["services"]
                self.metrics.inc("degenerate_batches")
            # autoscaling signal: how deep the queue still is after this
            # window was carved off (ElasticPolicy consumes it bridged)
            self.metrics.set_gauge("queue_depth", float(self._q.qsize()))
            # oversized windows (window_batch > largest bucket) are split
            # into bucket-sized chunks instead of under-padding silently
            start = 0
            for size in split_window(len(reqs), self.rt.batch_buckets):
                self._run_batch(reqs[start:start + size], params, version)
                start += size

    def _run_batch(self, reqs: List[_Request], params, version: int) -> None:
        with self.metrics.timer("busy_s"):
            n = len(reqs)
            nb = pad_to_bucket(n, self.rt.batch_buckets)
            self.metrics.inc("padded_slots", nb - n)
            # autoscaling signal: fraction of the padded batch carrying
            # real requests (low fill = idle accelerator slots)
            self.metrics.set_gauge("window_fill", n / nb)
            obs = np.stack([r.obs_tokens for r in reqs] +
                           [reqs[-1].obs_tokens] * (nb - n))
            steps = np.array([r.step for r in reqs] +
                             [reqs[-1].step] * (nb - n), np.int32)
            prefix = None
            if reqs[0].frame is not None:
                fr = np.stack([r.frame for r in reqs] +
                              [reqs[-1].frame] * (nb - n))
                prefix = _frame_to_prefix(fr)
            tokens, logps, values = self._fn(params, self._next_key(),
                                             obs, steps, prefix)
            tokens, logps, values = (np.asarray(tokens), np.asarray(logps),
                                     np.asarray(values))
            for i, r in enumerate(reqs):
                r.future.set_result({
                    "actions": tokens[i], "logp": logps[i],
                    "value": float(values[i]), "policy_version": version,
                })
            self.metrics.inc("batches")
            self.metrics.inc("requests", n)
            if (_tel is not None
                    and version not in self._first_action_traced):
                # closes the publish -> acquire -> first-action flow:
                # the first batch served with this weight version
                self._first_action_traced.add(version)
                _tel.instant("infer.first_action", cat="weights",
                             trace=int(version),
                             args={"version": int(version), "batch": n},
                             flow="end")


def _frame_to_prefix(frames: np.ndarray) -> np.ndarray:
    """[B, F_env] env frame -> [B, 1, FRONTEND_DIM] stub frontend embedding
    (zero-padded — the allowed modality-frontend carve-out)."""
    b, f = frames.shape
    out = np.zeros((b, 1, FRONTEND_DIM), np.float32)
    out[:, 0, :min(f, FRONTEND_DIM)] = frames[:, :FRONTEND_DIM]
    return out
