"""Inference-as-a-Service pool (paper §3.2).

Rollout workers submit per-env observation requests and suspend; every
inference worker drains a shared request queue and triggers a batched
forward pass under the dynamic window rule (eq. 1):

    Trigger = (|Q| >= B) ∨ (t_now − t_first >= T_max)

TPU adaptation (DESIGN.md §2): dynamic batches are padded up to the nearest
bucket size so the jitted program never recompiles for new batch shapes.

The drain protocol (App. D.6): when the weight store raises its drain flag,
workers stop scheduling NEW batches, finish the in-flight one, then swap
weights in place before resuming — update atomicity + version consistency.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig, RuntimeConfig
from repro.models.policy import make_inference_fn
from repro.models.transformer import FRONTEND_DIM
from repro.runtime.weight_store import VersionedWeightStore


class _Request:
    __slots__ = ("obs_tokens", "frame", "step", "future", "t_arrival")

    def __init__(self, obs_tokens, frame, step):
        self.obs_tokens = obs_tokens        # [T_obs] i32
        self.frame = frame                  # [F] f32 or None
        self.step = step                    # int
        self.future: Future = Future()
        self.t_arrival = time.monotonic()


def pad_to_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket that fits ``n`` requests. ``n`` larger than the
    biggest bucket is the caller's bug — windows must be split first
    (``split_window``), otherwise the pad count would go negative and the
    stacked batch would silently carry ``n`` rows instead of ``nb``."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"window of {n} requests exceeds the largest batch bucket "
        f"{buckets[-1]}; split the window before padding")


def split_window(n: int, buckets: Sequence[int]) -> List[int]:
    """Chunk an ``n``-request window into bucket-sized pieces: full largest
    buckets, then one bucket-padded remainder."""
    top = buckets[-1]
    sizes = [top] * (n // top)
    if n % top:
        sizes.append(n % top)
    return sizes


class InferenceService:
    """Centralized inference pool: one shared queue, N worker threads."""

    def __init__(self, cfg: ModelConfig, store: VersionedWeightStore,
                 rt: RuntimeConfig, *, temperature: float = 1.0, seed: int = 0):
        self.cfg = cfg
        self.store = store
        self.rt = rt
        self._fn = make_inference_fn(cfg, temperature)
        self._q: "queue.Queue[_Request]" = queue.Queue()
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._key = jax.random.PRNGKey(seed)
        self._key_lock = threading.Lock()
        # metrics
        self.batches_run = 0
        self.requests_served = 0
        self.busy_s = 0.0
        self.started_at: Optional[float] = None
        self.weight_swaps = 0
        self.padded_slots = 0

    # -- client API -----------------------------------------------------------
    def submit(self, obs_tokens: np.ndarray, frame: Optional[np.ndarray],
               step: int) -> Future:
        """Asynchronous request; the rollout worker suspends on the future."""
        req = _Request(obs_tokens, frame, step)
        self._q.put(req)
        return req.future

    # -- lifecycle --------------------------------------------------------------
    def start(self) -> "InferenceService":
        self.started_at = time.monotonic()
        for i in range(self.rt.num_inference_workers):
            t = threading.Thread(target=self._run, daemon=True,
                                 name=f"inference-{i}")
            t.start()
            self._threads.append(t)
        return self

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)

    # -- worker loop --------------------------------------------------------------
    def _next_key(self):
        with self._key_lock:
            self._key, sub = jax.random.split(self._key)
        return sub

    def _collect_window(self) -> List[_Request]:
        """Dynamic-window batching, eq. 1."""
        B = self.rt.inference_batch
        t_max = self.rt.inference_max_wait_s
        reqs: List[_Request] = []
        t_first = None
        while not self._stop.is_set():
            timeout = 0.002 if t_first is None else max(
                0.0, t_max - (time.monotonic() - t_first))
            try:
                r = self._q.get(timeout=max(timeout, 1e-4))
                reqs.append(r)
                if t_first is None:
                    t_first = r.t_arrival
            except queue.Empty:
                pass
            if reqs and (len(reqs) >= B or
                         time.monotonic() - t_first >= t_max):
                return reqs
        return reqs

    def _run(self) -> None:
        try:
            self._run_inner()
        except Exception:   # noqa: BLE001 — surface worker crashes
            import traceback
            traceback.print_exc()
            raise

    def _run_inner(self) -> None:
        params, version = None, -1
        while not self._stop.is_set():
            # drain protocol: no NEW batch while the trainer is publishing
            if self.store.draining or params is None:
                got = self.store.acquire(newer_than=version, timeout=0.1)
                if got is not None:
                    params, version = got
                    self.weight_swaps += 1
                if params is None:
                    continue
            reqs = self._collect_window()
            if not reqs:
                continue
            # oversized windows (inference_batch > largest bucket) are split
            # into bucket-sized chunks instead of under-padding silently
            start = 0
            for size in split_window(len(reqs), self.rt.batch_buckets):
                self._run_batch(reqs[start:start + size], params, version)
                start += size

    def _run_batch(self, reqs: List[_Request], params, version: int) -> None:
        t0 = time.monotonic()
        n = len(reqs)
        nb = pad_to_bucket(n, self.rt.batch_buckets)
        self.padded_slots += nb - n
        obs = np.stack([r.obs_tokens for r in reqs] +
                       [reqs[-1].obs_tokens] * (nb - n))
        steps = np.array([r.step for r in reqs] +
                         [reqs[-1].step] * (nb - n), np.int32)
        prefix = None
        if reqs[0].frame is not None:
            fr = np.stack([r.frame for r in reqs] +
                          [reqs[-1].frame] * (nb - n))
            prefix = _frame_to_prefix(fr)
        tokens, logps, values = self._fn(params, self._next_key(),
                                         obs, steps, prefix)
        tokens, logps, values = (np.asarray(tokens), np.asarray(logps),
                                 np.asarray(values))
        for i, r in enumerate(reqs):
            r.future.set_result({
                "actions": tokens[i], "logp": logps[i],
                "value": float(values[i]), "policy_version": version,
            })
        self.batches_run += 1
        self.requests_served += n
        self.busy_s += time.monotonic() - t0

    # -- metrics --------------------------------------------------------------
    def utilization(self) -> float:
        if not self.started_at:
            return 0.0
        wall = time.monotonic() - self.started_at
        return self.busy_s / max(wall, 1e-9)


def _frame_to_prefix(frames: np.ndarray) -> np.ndarray:
    """[B, F_env] env frame -> [B, 1, FRONTEND_DIM] stub frontend embedding
    (zero-padded — the allowed modality-frontend carve-out)."""
    b, f = frames.shape
    out = np.zeros((b, 1, FRONTEND_DIM), np.float32)
    out[:, 0, :min(f, FRONTEND_DIM)] = frames[:, :FRONTEND_DIM]
    return out
