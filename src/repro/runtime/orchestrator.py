"""Orchestrator: composes the runtime services — rollout workers, the
inference pool, the trainer — on a :class:`ServiceRegistry` and runs them
under a :class:`~repro.runtime.scheduler.Scheduler`:

  * ``run_async``  — :class:`FreeRunScheduler`, the fully asynchronous
    AcceRL pipeline (paper §3);
  * ``run_sync``   — :class:`BarrierScheduler`, the synchronous baseline
    with its step/episode/cluster barriers (paper Fig. 1) — the SAME
    services, only paced differently.

Extensions attach through ``system.attach(...)``: an attachment registers
additional services on the bus and may rewire the trainer's experience
source — the world model (paper §4) plugs in this way instead of
subclassing, which is what makes "plug-and-play" literal.

``metrics()`` is rebuilt on the per-service metric registries: one schema
consumed by the benchmarks (throughput, sync_overhead, sample_efficiency),
the examples, and the launchers, with the full per-service snapshot under
``metrics()["services"]``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig, RLConfig, RuntimeConfig
from repro.core.resampler import DynamicWeightedResampler
from repro.envs.toy_manipulation import TASKS_PER_SUITE, ManipulationEnv
from repro.runtime.experience import FifoChannel, RingChannel
from repro.runtime.inference import InferenceService
from repro.runtime.rollout import RolloutWorker
from repro.runtime.scheduler import BarrierScheduler, FreeRunScheduler
from repro.runtime.service import ServiceRegistry
from repro.runtime.trainer import TrainerWorker
from repro.runtime.weight_store import VersionedWeightStore


class AcceRLSystem:
    def __init__(self, cfg: ModelConfig, rl: RLConfig, rt: RuntimeConfig, *,
                 suite: str = "spatial", segment_horizon: int = 8,
                 max_episode_steps: int = 30, batch_episodes: int = 8,
                 latency=None, transport=None, seed: int = 0,
                 collect_frames: bool = False,
                 remote_latency_ms=None, remote_latency_sigma: float = 1.0):
        if cfg.num_prefix_tokens == 0:
            # a VLA policy always consumes the observation frame — give
            # text-only backbones a 1-token frame-embedding prefix
            cfg = dataclasses.replace(cfg, num_prefix_tokens=1)
        self.cfg, self.rl, self.rt = cfg, rl, rt
        self.suite = suite
        self.seed = seed
        self.max_episode_steps = max_episode_steps
        self.segment_horizon = segment_horizon
        self.store = VersionedWeightStore(transport=transport)
        # B: real trajectory segments -> trainer
        self.experience = FifoChannel(rt.replay_capacity,
                                      policy=rt.replay_backpressure)
        # B_wm: real transitions -> world-model trainers + imagination seeds
        self.frame_channel = (RingChannel(rt.wm_replay_capacity, seed=seed)
                              if collect_frames else None)
        self.resampler = DynamicWeightedResampler(TASKS_PER_SUITE, seed=seed)
        self.registry = ServiceRegistry()
        self.attachments: List = []
        tcfg = rt.transport
        self.transport_server = None
        self.supervisor = None
        self.journal = None
        self.remote_hosts: List = []
        self.inference_plane_host = None
        self.infer_address = None
        n_remote = tcfg.remote_rollout_workers + tcfg.connect_rollout_workers
        if n_remote > 0:
            # registered FIRST: the wire endpoint starts before any child
            # spawns and stops last, so shutdown stays cooperative
            from repro.runtime.transport import TransportServer
            from repro.runtime.transport.channel import parse_address
            host, port = tcfg.host, tcfg.port
            if tcfg.listen_addr:
                host, port = parse_address(tcfg.listen_addr)
            if tcfg.journal_dir:
                # resilient control plane: wrap the experience channel so
                # every accepted put / pop is write-ahead journaled, and
                # journal weight publishes through the store hook — BEFORE
                # the trainer and server capture channel references
                from repro.runtime.transport import TransportJournal
                self.journal = TransportJournal(
                    tcfg.journal_dir,
                    compact_bytes=tcfg.journal_compact_bytes,
                    resume=tcfg.resume_journal)
                self.journal.attach_store(self.store)
                self.experience = self.journal.wrap("experience",
                                                    self.experience)
            self.transport_server = self.registry.register(TransportServer(
                host=host, port=port,
                shm_threshold=tcfg.shm_threshold_bytes, token=tcfg.token,
                journal=self.journal,
                weight_lane_bytes=tcfg.weight_lane_bytes))
            self.transport_server.add_channel("experience", self.experience)
            if self.frame_channel is not None:
                self.transport_server.add_channel("frames",
                                                  self.frame_channel)
            self.transport_server.set_store(self.store)
            if self.journal is not None and tcfg.resume_journal:
                # adopt the previous incarnation's state before anything
                # starts: channels refill, stream watermarks rebuild (so
                # redialing producers replay exactly-once), the newest
                # recovered weights republish
                self.transport_server.resume_from_journal()
        self.inference = self.registry.register(
            InferenceService(cfg, self.store, rt, seed=seed))
        if (self.transport_server is not None
                and tcfg.inference_plane == "host"):
            # host mode: the parent's own pool serves remote workers'
            # action requests through the infer.* endpoints — continuous
            # batching across every local AND remote rollout worker
            from repro.runtime.transport import InferenceBroker
            self.transport_server.set_inference(
                InferenceBroker(self.inference))
        self.trainer = self.registry.register(
            TrainerWorker(cfg, rl, rt, self.experience, self.store,
                          batch_episodes=batch_episodes, seed=seed))
        self.workers = [
            self.registry.register(RolloutWorker(
                i, cfg, self.inference, self.experience,
                suite=suite, resampler=self.resampler,
                segment_horizon=segment_horizon,
                max_steps=max_episode_steps, latency=latency,
                seed=seed * 1000 + i,
                frame_channel=self.frame_channel))
            for i in range(rt.num_rollout_workers)
        ]
        if n_remote > 0:
            # ONE Supervisor owns every non-local worker slot: spawned
            # (child process) and connected (dialed in from another host)
            # incarnations run the same worker body under the same
            # RestartPolicy state machine
            from repro.runtime.transport import (RemoteWorkerSpec,
                                                 RestartPolicy, Supervisor)
            sup = tcfg.supervision
            policy = RestartPolicy(
                mode=sup.restart, max_restarts=sup.max_restarts,
                window_s=sup.window_s,
                backoff_initial_s=sup.backoff_initial_s,
                backoff_factor=sup.backoff_factor,
                backoff_max_s=sup.backoff_max_s)
            self.supervisor = self.registry.register(
                Supervisor(self.transport_server, policy))

            if tcfg.inference_plane == "spawn":
                # pre-allocate the tier's FIXED port so every restart
                # incarnation rebinds the same address (SO_REUSEADDR on
                # the server listener) and workers simply redial
                import socket as _socket
                infer_host, infer_port = "127.0.0.1", 0
                if tcfg.infer_listen_addr:
                    infer_host, infer_port = parse_address(
                        tcfg.infer_listen_addr)
                if infer_port == 0:
                    probe = _socket.socket()
                    probe.setsockopt(_socket.SOL_SOCKET,
                                     _socket.SO_REUSEADDR, 1)
                    probe.bind((infer_host, 0))
                    infer_port = probe.getsockname()[1]
                    probe.close()
                self.infer_address = (infer_host, infer_port)

            def make_spec(name: str, idx: int) -> RemoteWorkerSpec:
                return RemoteWorkerSpec(
                    name=name, cfg=cfg, rl=rl, rt=rt,
                    address=self.transport_server.address,
                    channel="experience",
                    frame_channel=("frames" if self.frame_channel is not None
                                   else None),
                    suite=suite, segment_horizon=segment_horizon,
                    max_episode_steps=max_episode_steps,
                    num_envs=tcfg.envs_per_worker,
                    seed=seed * 1000 + rt.num_rollout_workers + idx,
                    use_shm=(tcfg.kind == "shm"),
                    use_ring=(tcfg.kind == "ring"),
                    ring_bytes=tcfg.ring_bytes,
                    put_window=tcfg.put_window,
                    adaptive_window=tcfg.adaptive_put_window,
                    use_weight_lane=(tcfg.weight_lane_bytes > 0),
                    shm_threshold=tcfg.shm_threshold_bytes,
                    connect_timeout_s=tcfg.connect_timeout_s,
                    latency_mean_ms=remote_latency_ms,
                    latency_sigma=remote_latency_sigma,
                    heartbeat_s=tcfg.heartbeat_s, token=tcfg.token,
                    reconnect_attempts=tcfg.reconnect_attempts,
                    reconnect_backoff_s=tcfg.reconnect_backoff_s,
                    inference=("remote" if tcfg.inference_plane else "local"),
                    infer_address=self.infer_address)

            if self.infer_address is not None:
                # the tier slot registers BEFORE rollout slots so it is
                # already coming up while they dial; kept out of
                # remote_hosts (it contributes no env steps to metrics())
                plane_spec = dataclasses.replace(
                    make_spec("inference-plane", -1), kind="inference",
                    infer_listen=self.infer_address)
                self.inference_plane_host = self.registry.register(
                    self.supervisor.add_spawned(plane_spec))

            for i in range(tcfg.remote_rollout_workers):
                spec = make_spec(f"remote-rollout-{i}", i)
                self.remote_hosts.append(self.registry.register(
                    self.supervisor.add_spawned(spec)))
            for i in range(tcfg.connect_rollout_workers):
                spec = make_spec(f"connect-rollout-{i}",
                                 tcfg.remote_rollout_workers + i)
                self.remote_hosts.append(self.registry.register(
                    self.supervisor.add_connected(
                        spec, liveness_timeout_s=sup.liveness_timeout_s,
                        liveness_heartbeats=sup.liveness_heartbeats,
                        liveness_floor_s=sup.liveness_floor_s)))
            if sup.max_workers > 0:
                self._enable_elastic(make_spec, n_remote)
        # observability plane: a TelemetrySink samples the registry into
        # timestamped history (and serves metrics.snapshot when a
        # TransportServer is up). Armed by config or by the REPRO_TRACE
        # env so traced runs get the sink without extra flags; the
        # telemetry module import is deliberately lazy — untraced,
        # unsinked runs never load it.
        tel = rt.telemetry
        self.telemetry_sink = None
        if tel.sink or os.environ.get("REPRO_TRACE"):
            from repro.runtime.telemetry import TelemetrySink
            self.telemetry_sink = self.registry.register(TelemetrySink(
                self.registry, interval_s=tel.sink_interval_s,
                history=tel.sink_history, path=tel.sink_path))
            if self.transport_server is not None:
                self.transport_server.snapshot_provider = \
                    self.telemetry_sink.sample

    # --------------------------------------------------------------- elastic
    def _enable_elastic(self, make_spec, n_static: int) -> None:
        """Arm the supervisor's autoscaler with signals derived from
        state already on the bus: experience-queue depth fraction and
        the weight-version lag of the slowest live worker (the
        ``policy_version``/``weight_version`` gauges each report
        bridges)."""
        from repro.runtime.transport import ElasticPolicy
        sup = self.rt.transport.supervision
        tcfg = self.rt.transport
        policy = ElasticPolicy(
            min_workers=sup.min_workers,
            max_workers=max(sup.max_workers, n_static),
            interval_s=sup.elastic_interval_s,
            scale_up_depth=sup.scale_up_depth,
            scale_down_depth=sup.scale_down_depth,
            staleness_cap=sup.staleness_cap,
            tier_queue_hot=sup.tier_queue_hot,
            tier_fill_hot=sup.tier_fill_hot,
            drain_timeout_s=sup.drain_timeout_s)

        def elastic_spec(seq: int):
            return make_spec(f"elastic-rollout-{seq}", n_static + seq)

        def elastic_signals() -> Dict[str, float]:
            depth_frac = (len(self.experience)
                          / max(self.rt.replay_capacity, 1))
            published = self.store.version()
            versions = []
            for slot in self.supervisor.slots:
                if slot.error is not None or slot.phase == "done":
                    continue
                g = slot.metrics.snapshot()["gauges"]
                v = g.get("policy_version", g.get("weight_version"))
                if v is not None:
                    versions.append(float(v))
            staleness = (published - min(versions)
                         if versions and published >= 0 else 0.0)
            # inference-tier pressure: prefer the disaggregated tier's
            # bridged gauges (spawn mode) over the parent's local pool
            src = (self.inference_plane_host.metrics
                   if self.inference_plane_host is not None
                   else self.inference.metrics)
            g = src.snapshot()["gauges"]
            return {"depth_frac": float(depth_frac),
                    "staleness": float(max(staleness, 0.0)),
                    "infer_queue_depth": float(g.get("queue_depth", 0.0)),
                    "infer_window_fill": float(g.get("window_fill", 0.0))}

        def register_slot(slot) -> None:
            # NOT on the ServiceRegistry: this runs on the supervision
            # thread mid-run and the registry dict is not thread-safe.
            # remote_hosts is enough — metrics aggregation reads it, and
            # supervisor.on_stop raises every slot's stop flag.
            slot.start()
            self.remote_hosts.append(slot)

        self.supervisor.enable_elastic(
            policy, elastic_spec, elastic_signals,
            mode=("connect" if (tcfg.connect_rollout_workers
                                and not tcfg.remote_rollout_workers)
                  else "spawn"),
            register=register_slot)

    # ------------------------------------------------------------- attachments
    def attach(self, attachment) -> "AcceRLSystem":
        """Plug an extension into the runtime: the attachment registers its
        services on the bus (and may rewire the trainer) via ``bind``."""
        attachment.bind(self)
        self.attachments.append(attachment)
        return self

    # ------------------------------------------------------------------ runs
    def run_async(self, *, train_steps: int,
                  wall_timeout_s: float = 300.0) -> Dict:
        """The AcceRL mode: everything free-runs; returns system metrics."""
        return FreeRunScheduler().run(self, train_steps=train_steps,
                                      wall_timeout_s=wall_timeout_s)

    def run_sync(self, *, train_steps: int, episodes_per_round: int = 8,
                 wall_timeout_s: float = 300.0) -> Dict:
        """Synchronous baseline: rollout barrier → train → broadcast —
        the same services under the barrier scheduler."""
        if self.remote_hosts:
            raise RuntimeError(
                "the synchronous baseline is single-process: remote "
                "rollout workers (rt.transport.remote_rollout_workers / "
                "connect_rollout_workers) free-run and cannot join the "
                "step/episode barriers")
        return BarrierScheduler(episodes_per_round=episodes_per_round).run(
            self, train_steps=train_steps, wall_timeout_s=wall_timeout_s)

    def run_wm(self, *, train_steps: int,
               wall_timeout_s: float = 300.0) -> Dict:
        """World-model mode: the async pipeline with the WM attachment's
        imagination + WM-trainer services on the bus."""
        if not self.attachments:
            raise RuntimeError(
                "run_wm needs a world model: build the system via "
                "repro.wm.AcceRLWMSystem or system.attach(...) first")
        return self.run_async(train_steps=train_steps,
                              wall_timeout_s=wall_timeout_s)

    # -------------------------------------------------------------- evaluation
    def evaluate(self, *, episodes: int = 20, tasks: Optional[List[int]] =
                 None, seed: int = 123) -> Dict:
        """Greedy-ish evaluation success rate using the latest weights."""
        got = self.store.acquire(timeout=5.0)
        assert got is not None, "no published weights"
        params, _ = got
        from repro.models.policy import make_inference_fn
        from repro.runtime.inference import _frame_to_prefix
        import jax
        fn = make_inference_fn(self.cfg, temperature=0.35)
        env = ManipulationEnv(
            suite=self.suite, max_steps=self.max_episode_steps,
            action_vocab=self.cfg.action_vocab_size,
            action_dim=self.cfg.action_dim, seed=seed)
        key = jax.random.PRNGKey(seed)
        succ, returns = 0, []
        for ep in range(episodes):
            task = (tasks[ep % len(tasks)] if tasks
                    else ep % TASKS_PER_SUITE)
            obs = env.reset(task)
            done, ep_ret = False, 0.0
            while not done:
                key, sub = jax.random.split(key)
                toks, _, _ = fn(params, sub, obs["tokens"][None],
                                np.array([obs["step"]], np.int32),
                                _frame_to_prefix(obs["frame"][None]))
                obs, r, done, info = env.step(np.asarray(toks[0]))
                ep_ret += r
            succ += int(info["success"])
            returns.append(ep_ret)
        return {"success_rate": succ / episodes,
                "mean_return": float(np.mean(returns))}

    # ----------------------------------------------------------------- metrics
    def health(self) -> Dict:
        """Per-service health report from the registry."""
        return self.registry.health()

    def metrics(self, wall_s: float) -> Dict:
        """One metric schema for every consumer, rebuilt on the per-service
        registries; attachments extend it in place. Remote rollout hosts
        mirror their child's counters, so they aggregate exactly like
        local workers — the schema does not change with the topology."""
        rollouts = self.workers + self.remote_hosts
        env_steps = sum(w.env_steps for w in rollouts)
        episodes = sum(w.episodes_done for w in rollouts)
        rets = [r for w in rollouts for r in w.returns]
        m = {
            "wall_s": wall_s,
            "train_steps": self.trainer.steps_done,
            "env_steps": env_steps,
            "episodes": episodes,
            "sps_env": env_steps / max(wall_s, 1e-9),
            "sps_train": self.trainer.samples_seen / max(wall_s, 1e-9),
            "trainer_util": self.trainer.utilization(),
            "inference_util": self.inference.utilization(),
            "mean_policy_lag": self.trainer.metrics.series_mean("policy_lag"),
            "mean_return": float(np.mean(rets)) if rets else 0.0,
            "success_rate": (sum(w.successes for w in rollouts)
                             / max(episodes, 1)),
            "buffer_dropped": self.experience.total_dropped,
            "inference_batches": self.inference.batches_run,
            "sync_latency_s": self.store.last_sync_latency_s,
            "services": self.registry.snapshot(),
        }
        if getattr(self.trainer, "pipeline", None) is not None:
            pipe = self.trainer.pipeline
            m["pipeline_rounds"] = pipe.rounds
            m["pipeline_bubble"] = dict(pipe.last_bubble)
            m["pipeline_peak_grad_bytes"] = pipe.peak_grad_bytes
        for attachment in self.attachments:
            attachment.extend_metrics(m, self)
        return m
