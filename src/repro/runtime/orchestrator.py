"""Orchestrator: wires rollout workers, the inference pool, and the trainer
into (a) the fully asynchronous AcceRL pipeline or (b) the synchronous
baseline with its three long-tail barriers (paper Fig. 1).

In synchronous mode the SAME components run, but the orchestrator enforces
the barriers: all workers must finish their episode batch before training
starts, and training blocks rollouts — reproducing step/episode/cluster
idle bubbles so the throughput benchmark measures the paper's Table 1
contrast structurally.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig, RLConfig, RuntimeConfig
from repro.core.resampler import DynamicWeightedResampler
from repro.data.replay import FIFOReplayBuffer, RingReplayBuffer
from repro.envs.toy_manipulation import TASKS_PER_SUITE, ManipulationEnv
from repro.runtime.inference import InferenceService
from repro.runtime.rollout import RolloutWorker
from repro.runtime.trainer import TrainerWorker, collate_segments
from repro.runtime.weight_store import VersionedWeightStore


class AcceRLSystem:
    def __init__(self, cfg: ModelConfig, rl: RLConfig, rt: RuntimeConfig, *,
                 suite: str = "spatial", segment_horizon: int = 8,
                 max_episode_steps: int = 30, batch_episodes: int = 8,
                 latency=None, transport=None, seed: int = 0,
                 collect_frames: bool = False):
        import dataclasses
        if cfg.num_prefix_tokens == 0:
            # a VLA policy always consumes the observation frame — give
            # text-only backbones a 1-token frame-embedding prefix
            cfg = dataclasses.replace(cfg, num_prefix_tokens=1)
        self.cfg, self.rl, self.rt = cfg, rl, rt
        self.suite = suite
        self.store = VersionedWeightStore(transport=transport)
        self.buffer = FIFOReplayBuffer(rt.replay_capacity)
        self.frame_buffer = (RingReplayBuffer(rt.wm_replay_capacity)
                             if collect_frames else None)
        self.resampler = DynamicWeightedResampler(TASKS_PER_SUITE, seed=seed)
        self.inference = InferenceService(cfg, self.store, rt, seed=seed)
        self.trainer = TrainerWorker(cfg, rl, rt, self.buffer, self.store,
                                     batch_episodes=batch_episodes,
                                     seed=seed)
        self.workers = [
            RolloutWorker(i, cfg, self.inference, self.buffer,
                          suite=suite, resampler=self.resampler,
                          segment_horizon=segment_horizon,
                          max_steps=max_episode_steps, latency=latency,
                          seed=seed * 1000 + i,
                          frame_buffer=self.frame_buffer)
            for i in range(rt.num_rollout_workers)
        ]

    # ------------------------------------------------------------------ async
    def run_async(self, *, train_steps: int,
                  wall_timeout_s: float = 300.0) -> Dict:
        """The AcceRL mode: everything free-runs; returns system metrics."""
        t0 = time.monotonic()
        self.inference.start()
        self.trainer.start()
        for w in self.workers:
            w.start()
        try:
            while (self.trainer.steps_done < train_steps
                   and time.monotonic() - t0 < wall_timeout_s):
                time.sleep(0.02)
        finally:
            for w in self.workers:
                w.stop()
            self.trainer.stop()
            self.inference.stop()
            for w in self.workers:
                w.join()
        return self.metrics(time.monotonic() - t0)

    # ------------------------------------------------------------------ sync
    def run_sync(self, *, train_steps: int, episodes_per_round: int = 8,
                 wall_timeout_s: float = 300.0) -> Dict:
        """Synchronous baseline: rollout barrier → train → broadcast."""
        t0 = time.monotonic()
        self.inference.start()
        self.trainer.started_at = time.monotonic()
        self.store.publish(self.trainer.state.params, 0)
        envs = [w.env for w in self.workers]
        n = len(envs)
        while (self.trainer.steps_done < train_steps
               and time.monotonic() - t0 < wall_timeout_s):
            # --- rollout phase: EVERY env must finish (episode barrier) ----
            segments = []
            rounds = max(episodes_per_round // n, 1)
            for _ in range(rounds):
                states = [e.reset(self.resampler.sample_task())
                          for e in envs]
                dones = [False] * n
                trajs = [None] * n
                for i in range(n):
                    trajs[i] = {k: [] for k in (
                        "obs_tokens", "frames", "actions", "behavior_logp",
                        "values", "rewards", "dones", "steps")}
                while not all(dones):
                    # step barrier: one lockstep batched inference per tick
                    live = [i for i in range(n) if not dones[i]]
                    futs = [self.inference.submit(
                        states[i]["tokens"], states[i]["frame"],
                        states[i]["step"]) for i in live]
                    for i, fut in zip(live, futs):
                        res = fut.result(timeout=30.0)
                        tr = trajs[i]
                        tr["obs_tokens"].append(states[i]["tokens"])
                        tr["frames"].append(states[i]["frame"])
                        tr["steps"].append(states[i]["step"])
                        tr["actions"].append(res["actions"])
                        tr["behavior_logp"].append(res["logp"])
                        tr["values"].append(res["value"])
                        obs, r, d, info = envs[i].step(res["actions"])
                        tr["rewards"].append(r)
                        tr["dones"].append(
                            float(d and not info["truncated"]))
                        states[i] = obs
                        if d:
                            dones[i] = True
                            tr["policy_version"] = res["policy_version"]
                            tr["task_id"] = envs[i].task_id
                            tr["success"] = float(info["success"])
                for i in range(n):
                    tr = trajs[i]
                    tr["obs_tokens"].append(states[i]["tokens"])
                    tr["frames"].append(states[i]["frame"])
                    tr["steps"].append(states[i]["step"])
                    tr["actions"].append(
                        np.zeros(self.cfg.action_dim, np.int32))
                    tr["behavior_logp"].append(
                        np.zeros(self.cfg.action_dim, np.float32))
                    tr["values"].append(0.0)
                    from repro.runtime.rollout import episode_to_segments
                    segments.extend(episode_to_segments(
                        tr, self.workers[i].segment_horizon))
                    self.workers[i].episodes_done += 1
                    self.workers[i].env_steps += len(tr["rewards"])
            # --- train phase (rollouts idle — cluster barrier) -------------
            batch = collate_segments(segments[:self.trainer.prefetcher
                                              .batch_size]
                                     if len(segments) else segments)
            self.trainer.train_on_batch(batch)
            self.trainer.samples_seen = sum(
                w.env_steps for w in self.workers)
        self.inference.stop()
        return self.metrics(time.monotonic() - t0)

    # -------------------------------------------------------------- evaluation
    def evaluate(self, *, episodes: int = 20, tasks: Optional[List[int]] =
                 None, seed: int = 123) -> Dict:
        """Greedy-ish evaluation success rate using the latest weights."""
        got = self.store.acquire(timeout=5.0)
        assert got is not None, "no published weights"
        params, _ = got
        from repro.models.policy import make_inference_fn
        from repro.runtime.inference import _frame_to_prefix
        import jax
        fn = make_inference_fn(self.cfg, temperature=0.35)
        env = ManipulationEnv(
            suite=self.suite, max_steps=self.workers[0].env.max_steps,
            action_vocab=self.cfg.action_vocab_size,
            action_dim=self.cfg.action_dim, seed=seed)
        key = jax.random.PRNGKey(seed)
        succ, returns = 0, []
        for ep in range(episodes):
            task = (tasks[ep % len(tasks)] if tasks
                    else ep % TASKS_PER_SUITE)
            obs = env.reset(task)
            done, ep_ret = False, 0.0
            while not done:
                key, sub = jax.random.split(key)
                toks, _, _ = fn(params, sub, obs["tokens"][None],
                                np.array([obs["step"]], np.int32),
                                _frame_to_prefix(obs["frame"][None]))
                obs, r, done, info = env.step(np.asarray(toks[0]))
                ep_ret += r
            succ += int(info["success"])
            returns.append(ep_ret)
        return {"success_rate": succ / episodes,
                "mean_return": float(np.mean(returns))}

    # ----------------------------------------------------------------- metrics
    def metrics(self, wall_s: float) -> Dict:
        env_steps = sum(w.env_steps for w in self.workers)
        episodes = sum(w.episodes_done for w in self.workers)
        rets = [r for w in self.workers for r in w.returns]
        return {
            "wall_s": wall_s,
            "train_steps": self.trainer.steps_done,
            "env_steps": env_steps,
            "episodes": episodes,
            "sps_env": env_steps / max(wall_s, 1e-9),
            "sps_train": self.trainer.samples_seen / max(wall_s, 1e-9),
            "trainer_util": self.trainer.utilization(),
            "inference_util": self.inference.utilization(),
            "mean_policy_lag": (float(np.mean(self.trainer.policy_lag))
                                if self.trainer.policy_lag else 0.0),
            "mean_return": float(np.mean(rets)) if rets else 0.0,
            "success_rate": (sum(w.successes for w in self.workers)
                             / max(episodes, 1)),
            "buffer_dropped": self.buffer.total_dropped,
            "inference_batches": self.inference.batches_run,
            "sync_latency_s": self.store.last_sync_latency_s,
        }
