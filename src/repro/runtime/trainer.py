"""Trainer worker (paper §3.1, App. C/D).

Continuously pops prefetched super-batches from the FIFO buffer (never
waiting on rollouts — macro-asynchrony), runs the GIPO + JIT-GAE train
step, and publishes versioned weights through the store with the drain
protocol. ``weight_sync_interval`` throttles publishes ("broadcast only
when an actual update occurs").
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig, RLConfig, RuntimeConfig
from repro.core.train_step import TrainState, init_train_state, make_train_step
from repro.data.prefetch import Prefetcher
from repro.data.replay import FIFOReplayBuffer
from repro.data.trajectory import TrajectoryBatch
from repro.models.transformer import FRONTEND_DIM
from repro.runtime.weight_store import VersionedWeightStore


def collate_segments(segments: List[Dict[str, np.ndarray]]) -> TrajectoryBatch:
    """Stack rollout segments into a TrajectoryBatch (prefetcher thread)."""
    stack = lambda k: np.stack([s[k] for s in segments])
    frames = stack("frames")                        # [B, T+1, F_env]
    b, tp1, f = frames.shape
    prefix = np.zeros((b, tp1, 1, FRONTEND_DIM), np.float32)
    prefix[..., 0, :min(f, FRONTEND_DIM)] = frames[..., :FRONTEND_DIM]
    return TrajectoryBatch(
        obs_tokens=stack("obs_tokens").astype(np.int32),
        actions=stack("actions").astype(np.int32),
        behavior_logp=stack("behavior_logp").astype(np.float32),
        behavior_value=stack("behavior_value").astype(np.float32),
        rewards=stack("rewards").astype(np.float32),
        dones=stack("dones").astype(np.float32),
        steps=stack("steps").astype(np.int32),
        mask=stack("mask").astype(np.float32),
        policy_version=stack("policy_version").astype(np.int32),
        prefix_embeds=prefix,
    )


class TrainerWorker:
    def __init__(self, cfg: ModelConfig, rl: RLConfig, rt: RuntimeConfig,
                 buffer: FIFOReplayBuffer, store: VersionedWeightStore, *,
                 batch_episodes: int = 8, seed: int = 0,
                 checkpoint_dir=None, checkpoint_interval: int = 0):
        import jax
        self.cfg, self.rl, self.rt = cfg, rl, rt
        self.buffer = buffer
        self.store = store
        self.state: TrainState = init_train_state(
            cfg, jax.random.PRNGKey(seed))
        self._step_fn = make_train_step(cfg, rl, donate=False)
        self.prefetcher = Prefetcher(buffer, batch_episodes,
                                     collate_segments,
                                     depth=rt.prefetch_depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="trainer")
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_interval = checkpoint_interval
        self.steps_done = 0
        self.samples_seen = 0
        self.busy_s = 0.0
        self.started_at: Optional[float] = None
        self.metrics_log: List[Dict] = []
        self.policy_lag: List[float] = []

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> "TrainerWorker":
        self.started_at = time.monotonic()
        # version 0 published so inference can begin before the first step
        self.store.publish(self.state.params, 0)
        self.prefetcher.start()
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self.prefetcher.stop()
        self._thread.join(timeout=10.0)

    # -- loop -------------------------------------------------------------------
    def _run(self) -> None:
        while not self._stop.is_set():
            batch = self.prefetcher.get(timeout=0.2)
            if batch is None:
                continue
            self.train_on_batch(batch)

    def train_on_batch(self, batch: TrajectoryBatch) -> Dict:
        t0 = time.monotonic()
        version = int(self.state.version)
        lag = version - float(np.mean(batch.policy_version))
        self.policy_lag.append(lag)
        self.state, metrics = self._step_fn(self.state, batch)
        self.steps_done += 1
        self.samples_seen += int(np.asarray(batch.mask).sum())
        if self.steps_done % self.rt.weight_sync_interval == 0:
            if self.rt.drain:
                self.store.begin_publish()     # drain signal, App. D.6
            self.store.publish(self.state.params, version + 1)
        if (self.checkpoint_dir and self.checkpoint_interval
                and self.steps_done % self.checkpoint_interval == 0):
            from repro.data import checkpoint
            checkpoint.save(self.checkpoint_dir, self.steps_done,
                            self.state)
        self.busy_s += time.monotonic() - t0
        out = {k: float(v) for k, v in metrics.items()}
        out["policy_lag"] = lag
        self.metrics_log.append(out)
        return out

    # -- metrics -----------------------------------------------------------------
    def utilization(self) -> float:
        if not self.started_at:
            return 0.0
        return self.busy_s / max(time.monotonic() - self.started_at, 1e-9)

    def sps(self) -> float:
        if not self.started_at:
            return 0.0
        return self.samples_seen / max(
            time.monotonic() - self.started_at, 1e-9)
