"""Trainer worker (paper §3.1, App. C/D).

Continuously pops prefetched super-batches from its experience source
(never waiting on rollouts — macro-asynchrony), runs the GIPO + JIT-GAE
train step, and publishes versioned weights through the store with the
drain protocol. ``weight_sync_interval`` throttles publishes ("broadcast
only when an actual update occurs").

The trainer is a :class:`~repro.runtime.service.Service`. Two drive modes,
same train path:

  * free-running (``start``) — the asynchronous pipeline: the service
    thread pops from the prefetcher and steps continuously;
  * inline (``begin_inline`` + ``train_on_batch``) — the barrier scheduler
    drives steps between rollout rounds, reproducing the synchronous
    baseline's cluster barrier without duplicating any training code.

The source is any ``pop_batch(n, timeout)`` provider — the real segment
channel ``B``, or a :class:`~repro.runtime.experience.MixedExperienceSource`
blending ``B`` and ``B_img`` when a world model is attached.
"""
from __future__ import annotations

import functools
import os
import time
from typing import Dict, List

import numpy as np

from repro.configs.base import ModelConfig, RLConfig, RuntimeConfig
from repro.core.train_step import TrainState, init_train_state
from repro.data.prefetch import Prefetcher
from repro.data.trajectory import TrajectoryBatch
from repro.models.transformer import FRONTEND_DIM
from repro.runtime.service import Service
from repro.runtime.weight_store import VersionedWeightStore

# Import-gated tracing (see transport.faults for the idiom).
if os.environ.get("REPRO_TRACE"):
    from repro.runtime import telemetry as _tel
else:  # pragma: no cover - default path
    _tel = None


def collate_segments(segments: List[Dict[str, np.ndarray]],
                     metrics=None) -> TrajectoryBatch:
    """Stack rollout segments into a TrajectoryBatch (prefetcher thread).

    When tracing is on, rollout workers stamp ``_trace``/``_t_put`` into
    each segment; the trainer-side span here closes the per-episode flow
    (rollout.put -> server.apply -> trainer.collate) and the end-to-end
    batch age lands in the ``batch_age_s`` histogram.
    """
    if _tel is not None:
        now = time.time()
        for s in segments:
            trace = s.get("_trace")
            if trace is None:
                continue
            _tel.instant("trainer.collate", cat="trainer",
                         trace=int(trace),
                         args={"batch": len(segments)}, flow="end")
            if metrics is not None and s.get("_t_put") is not None:
                metrics.observe("batch_age_s",
                                max(now - float(s["_t_put"]), 0.0))
    stack = lambda k: np.stack([s[k] for s in segments])
    frames = stack("frames")                        # [B, T+1, F_env]
    b, tp1, f = frames.shape
    prefix = np.zeros((b, tp1, 1, FRONTEND_DIM), np.float32)
    prefix[..., 0, :min(f, FRONTEND_DIM)] = frames[..., :FRONTEND_DIM]
    return TrajectoryBatch(
        obs_tokens=stack("obs_tokens").astype(np.int32),
        actions=stack("actions").astype(np.int32),
        behavior_logp=stack("behavior_logp").astype(np.float32),
        behavior_value=stack("behavior_value").astype(np.float32),
        rewards=stack("rewards").astype(np.float32),
        dones=stack("dones").astype(np.float32),
        steps=stack("steps").astype(np.int32),
        mask=stack("mask").astype(np.float32),
        policy_version=stack("policy_version").astype(np.int32),
        prefix_embeds=prefix,
    )


class TrainerWorker(Service):
    def __init__(self, cfg: ModelConfig, rl: RLConfig, rt: RuntimeConfig,
                 source, store: VersionedWeightStore, *,
                 batch_episodes: int = 8, seed: int = 0,
                 checkpoint_dir=None, checkpoint_interval: int = 0,
                 name: str = "trainer"):
        import jax
        super().__init__(name, role="trainer")
        self.cfg, self.rl, self.rt = cfg, rl, rt
        self.source = source
        self.store = store

        # Both drive modes build the step through the same IR
        # (runtime/step_program.py) and materialize optimizer moments
        # under the ZeRO-2 shardings (no-op on one device).
        from repro.runtime import step_program
        n_micro = rt.pipeline_microbatches or rl.grad_accum
        if rt.pipeline:
            from repro.runtime import pipeline_exec
            self._layout = pipeline_exec.SubmeshLayout.split(
                jax.devices(), wm_devices=rt.pipeline_wm_devices)
            self._mesh = self._layout.policy.mesh()
            self.program = step_program.build_train_step_program(
                cfg, rl, n_micro=n_micro, mesh=self._mesh)
            self.state: TrainState = init_train_state(
                cfg, jax.random.PRNGKey(seed), mesh=self._mesh)
            self.pipeline = pipeline_exec.PipelineExecutor(
                self.program, self._layout, n_micro=n_micro,
                metrics=self.metrics)
            self._step_fn = None
        else:
            from repro.launch.mesh import make_local_mesh
            self._mesh = make_local_mesh()
            self.program = step_program.build_train_step_program(
                cfg, rl, n_micro=n_micro,
                mesh=self._mesh if self._mesh.devices.size > 1 else None)
            self.state = init_train_state(
                cfg, jax.random.PRNGKey(seed), mesh=self._mesh)
            self.pipeline = None
            self._step_fn = self.program.fused(donate=False)
        self.prefetcher = Prefetcher(
            source, batch_episodes,
            functools.partial(collate_segments, metrics=self.metrics),
            depth=rt.prefetch_depth,
            drain_timeout_s=rt.prefetch_drain_timeout_s,
            idle_timeout_max_s=rt.prefetch_idle_timeout_s,
            stage_batches=rt.prefetch_staging,
            to_device=rt.prefetch_to_device)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_interval = checkpoint_interval
        self.metrics_log: List[Dict] = []

    # -- registry-backed counters ----------------------------------------------
    @property
    def steps_done(self) -> int:
        return int(self.metrics.counter("steps"))

    @property
    def samples_seen(self) -> int:
        return int(self.metrics.counter("samples"))

    @property
    def policy_lag(self) -> List[float]:
        return self.metrics.series("policy_lag")

    @property
    def busy_s(self) -> float:
        return self.metrics.counter("busy_s")

    def _publish(self, version: int, step: int = 0) -> None:
        """Publish weights and open the policy-lag trace flow. The version
        is the flow id on both ends, so publish -> acquire -> first action
        line up in the trace viewer without any shared state."""
        self.store.publish(self.state.params, version)
        if _tel is not None:
            _tel.instant("weights.publish", cat="weights", trace=version,
                         args={"version": version, "step": step},
                         flow="start")

    # -- lifecycle -------------------------------------------------------------
    def on_start(self) -> None:
        # version 0 published so inference can begin before the first step
        self._publish(0)
        self.prefetcher.start()

    def begin_inline(self) -> None:
        """Scheduler-driven mode: publish v0 and mark the clock, without
        the free-running thread or the prefetcher."""
        self.started_at = time.monotonic()
        self._publish(0)

    def set_wm_stage(self, stage_fn, feed_fn, *, wm_micro: int = 1) -> None:
        """Attach the world-model trainer as the second pipeline stage
        (pipeline mode only — see WorldModelAttachment.bind)."""
        if self.pipeline is None:
            raise RuntimeError("set_wm_stage requires rt.pipeline")
        self.pipeline.set_wm_stage(stage_fn, feed_fn, wm_micro=wm_micro)

    def stop(self) -> None:
        was_running = bool(self._threads)
        super().stop()
        if was_running:
            self.prefetcher.stop()
            self.join(timeout=10.0)
        if self.pipeline is not None:
            self.pipeline.close()

    # -- loop -------------------------------------------------------------------
    def _run(self) -> None:
        while not self._stop.is_set():
            batch = self.prefetcher.get(timeout=0.2)
            if batch is None:
                continue
            self.train_on_batch(batch)

    def train_on_batch(self, batch: TrajectoryBatch) -> Dict:
        with self.metrics.timer("busy_s"):
            version = int(self.state.version)
            lag = version - float(np.mean(batch.policy_version))
            self.metrics.record("policy_lag", lag)
            self.metrics.observe("policy_lag", lag)
            if self.pipeline is not None:
                self.state, metrics, _ = self.pipeline.run_round(
                    self.state, batch)
            else:
                self.state, metrics = self._step_fn(self.state, batch)
            steps = int(self.metrics.inc("steps"))
            self.metrics.inc("samples", float(np.asarray(batch.mask).sum()))
            if steps % self.rt.weight_sync_interval == 0:
                if self.rt.drain:
                    self.store.begin_publish()     # drain signal, App. D.6
                self._publish(version + 1, step=steps)
            if (self.checkpoint_dir and self.checkpoint_interval
                    and steps % self.checkpoint_interval == 0):
                from repro.data import checkpoint
                checkpoint.save(self.checkpoint_dir, steps, self.state)
        out = {k: float(v) for k, v in metrics.items()}
        out["policy_lag"] = lag
        self.metrics_log.append(out)
        return out

    # -- metrics -----------------------------------------------------------------
    def sps(self) -> float:
        if not self.started_at:
            return 0.0
        return self.samples_seen / max(
            time.monotonic() - self.started_at, 1e-9)
