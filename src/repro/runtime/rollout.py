"""Rollout workers (paper §3.1–3.2).

Each worker owns ONE (non-vectorized) environment instance — the paper's
"no natural batchability" regime — and loops:

    obs → async inference request → suspend → env.step(actions)

Completed episodes are packaged per eq. 2 as
τ = (o_{1:T+1}, a_{1:T}, r_{1:T}, μ_{1:T}, v_{1:T}, ṽ_{T+1}, done) and
sliced into fixed-horizon segments streamed to the experience channel —
rollouts are *interruptible*: segments of an unfinished episode ship
immediately with a bootstrap value, so the trainer never waits for long
episodes (episode-level long-tail removal).

The worker is a :class:`~repro.runtime.service.Service`; its pacing is a
:class:`~repro.runtime.service.RolloutGate` supplied by the scheduler —
:class:`NullGate` free-runs (async mode), the barrier gate reproduces the
synchronous baseline's step/episode barriers through the SAME loop.

Task selection uses Dynamic Weighted Resampling (App. D.4).
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.resampler import DynamicWeightedResampler
from repro.envs.toy_manipulation import ManipulationEnv
from repro.runtime.service import NULL_GATE, RolloutGate, Service

# Import-gated tracing (see transport.faults for the idiom): when off,
# the put path below carries zero extra work and zero extra keys.
if os.environ.get("REPRO_TRACE"):
    from repro.runtime import telemetry as _tel
else:  # pragma: no cover - default path
    _tel = None


def episode_to_segments(traj: Dict[str, np.ndarray], horizon: int
                        ) -> List[Dict[str, np.ndarray]]:
    """Slice an episode (T steps) into fixed-``horizon`` segments with a
    T+1 bootstrap slot each; ragged tails are padded and masked."""
    t = len(traj["rewards"])
    segs = []
    for s0 in range(0, t, horizon):
        s1 = min(s0 + horizon, t)
        n = s1 - s0
        pad = horizon - n

        def pad_steps(x, fill=0):
            x = np.asarray(x[s0:s1])
            if pad:
                x = np.concatenate(
                    [x, np.full((pad,) + x.shape[1:], fill, x.dtype)])
            return x

        # T+1 slot: the observation after the last step of the segment
        def with_bootstrap(x):
            x = np.asarray(x[s0:s1 + 1])
            need = horizon + 1 - len(x)
            if need:
                x = np.concatenate(
                    [x, np.repeat(x[-1:], need, axis=0)])
            return x

        segs.append({
            "obs_tokens": with_bootstrap(traj["obs_tokens"]),
            "frames": with_bootstrap(traj["frames"]),
            "actions": with_bootstrap(traj["actions"]),
            "behavior_logp": with_bootstrap(traj["behavior_logp"]),
            "behavior_value": with_bootstrap(traj["values"]),
            "rewards": pad_steps(traj["rewards"]),
            "dones": pad_steps(traj["dones"]),
            "steps": with_bootstrap(traj["steps"]),
            "mask": np.concatenate(
                [np.ones(n, np.float32), np.zeros(pad, np.float32)]),
            "policy_version": np.int32(traj["policy_version"]),
            "task_id": np.int32(traj["task_id"]),
            "success": np.float32(traj["success"]),
        })
    return segs


class RolloutWorker(Service):
    def __init__(self, worker_id: int, cfg: ModelConfig,
                 inference, experience, *,
                 suite: str = "spatial",
                 resampler: Optional[DynamicWeightedResampler] = None,
                 segment_horizon: int = 8,
                 max_steps: int = 30,
                 latency=None, seed: int = 0,
                 frame_channel=None,
                 gate: Optional[RolloutGate] = None):
        super().__init__(f"rollout-{worker_id}", role="rollout")
        self.worker_id = worker_id
        self.cfg = cfg
        self.inference = inference
        self.experience = experience
        self.resampler = resampler
        self.segment_horizon = segment_horizon
        self.frame_channel = frame_channel    # optional B_wm feed (real frames)
        self.gate = gate or NULL_GATE
        self.env = ManipulationEnv(
            suite=suite, task_id=0, max_steps=max_steps,
            action_vocab=cfg.action_vocab_size, action_dim=cfg.action_dim,
            latency=latency, seed=seed)

    # -- registry-backed counters (the service's public read surface) ----------
    @property
    def env_steps(self) -> int:
        return int(self.metrics.counter("env_steps"))

    @property
    def episodes_done(self) -> int:
        return int(self.metrics.counter("episodes"))

    @property
    def successes(self) -> int:
        return int(self.metrics.counter("successes"))

    @property
    def returns(self) -> List[float]:
        return self.metrics.series("return")

    # -- episode loop -----------------------------------------------------------
    def _run(self) -> None:
        while not self._stop.is_set():
            if not self.gate.begin_episode(self._stop):
                continue
            try:
                task = (self.resampler.sample_task()
                        if self.resampler is not None else 0)
                self._episode(task)
            finally:
                self.gate.end_episode()

    def _episode(self, task_id: int) -> None:
        obs = self.env.reset(task_id)
        traj = {k: [] for k in ("obs_tokens", "frames", "actions",
                                "behavior_logp", "values", "rewards",
                                "dones", "steps")}
        version = -1
        ep_return, success = 0.0, False
        done = False
        while not done and not self._stop.is_set():
            self.gate.before_step(self._stop)
            fut = self.inference.submit(obs["tokens"], obs["frame"],
                                        obs["step"])
            try:
                res = fut.result(timeout=30.0)
            except Exception:
                return
            traj["obs_tokens"].append(obs["tokens"])
            traj["frames"].append(obs["frame"])
            traj["steps"].append(obs["step"])
            traj["actions"].append(res["actions"])
            traj["behavior_logp"].append(res["logp"])
            traj["values"].append(res["value"])
            version = res["policy_version"]
            obs, reward, done, info = self.env.step(res["actions"])
            traj["rewards"].append(reward)
            # natural termination only (truncation bootstraps, App. C.1)
            traj["dones"].append(float(done and not info["truncated"]))
            ep_return += reward
            success = success or info["success"]
            self.metrics.inc("env_steps")
        if self._stop.is_set() and not done:
            return
        # bootstrap slot o_{T+1}
        traj["obs_tokens"].append(obs["tokens"])
        traj["frames"].append(obs["frame"])
        traj["steps"].append(obs["step"])
        traj["actions"].append(np.zeros(self.cfg.action_dim, np.int32))
        traj["behavior_logp"].append(np.zeros(self.cfg.action_dim,
                                              np.float32))
        traj["values"].append(0.0)
        traj["policy_version"] = version
        traj["task_id"] = task_id
        traj["success"] = float(success)

        segments = episode_to_segments(traj, self.segment_horizon)
        # batched flush: one backpressure verdict per segment, and over a
        # remote channel ONE codec blob + round-trip per episode instead
        # of one per segment (or one pipelined stream frame, in which
        # case the verdicts here are provisional and the channel's
        # stream stats carry the authoritative accept counts)
        if _tel is not None:
            # One trace per episode flush: the id is stamped into every
            # segment (collate only stacks named keys, so extra scalars
            # survive the channel untouched) and rides the put-frame
            # header, joining rollout.put -> server.apply -> trainer
            # collate into one cross-process chain.
            trace = _tel.new_id()
            t_put = time.time()
            for seg in segments:
                seg["_trace"] = trace
                seg["_t_put"] = t_put
            with _tel.span("rollout.put", cat="rollout", trace=trace,
                           args={"worker": self.worker_id,
                                 "segments": len(segments),
                                 "policy_version": int(version)},
                           flow="start"):
                verdicts = self.experience.put_many(segments)
        else:
            verdicts = self.experience.put_many(segments)
        self.metrics.inc("segments", float(len(segments)))
        rejected = sum(1 for v in verdicts if not v)
        if rejected:
            self.metrics.inc("segments_rejected", float(rejected))
        # bridged gauges: a SupervisedWorker slot mirrors these to the
        # parent, so policy-staleness is visible for out-of-process
        # workers too
        self.metrics.set_gauge("policy_version", float(version))
        if self.frame_channel is not None:
            self.frame_channel.put_many([
                {
                    "frame": traj["frames"][i],
                    "next_frame": traj["frames"][i + 1],
                    "tokens": traj["obs_tokens"][i],
                    "step": np.int32(traj["steps"][i]),
                    "actions": traj["actions"][i],
                    "reward": traj["rewards"][i],
                    "success": np.float32(
                        traj["success"] if i == len(traj["rewards"]) - 1
                        else 0.0),
                }
                for i in range(len(traj["rewards"]))
            ])
        self.metrics.inc("episodes")
        self.metrics.inc("successes", float(success))
        self.metrics.record("return", ep_return)
        if self.resampler is not None:
            self.resampler.update_history(task_id, float(success))
