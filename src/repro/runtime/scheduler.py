"""Schedulers: the SAME services under different pacing (paper Fig. 1).

The asynchronous AcceRL pipeline and the synchronous baseline used to be
two separate code paths (``run_async`` starting threads, ``run_sync``
re-implementing the whole rollout loop inline). Here both are expressed as
schedulers over the one service set:

  * :class:`FreeRunScheduler` — everything free-runs (the AcceRL mode):
    start every registered service, poll the primary trainer until the
    step budget or wall clock is hit, stop in reverse order.

  * :class:`BarrierScheduler` — the synchronous baseline with its three
    long-tail barriers, reproduced as *pacing* rather than a parallel
    implementation:
      - step barrier    — a :class:`BarrierGate` makes every live worker
        rendezvous before each env step, and the inference window widens to
        one batched forward per lockstep tick;
      - episode barrier — each round releases a fixed episode quota and
        waits for ALL of it to finish before training may begin;
      - cluster barrier — the trainer steps inline between rounds, so
        rollouts are idle while the optimizer runs (and vice versa).

Because the barriers live in the gate + scheduler, the rollout loop,
inference pool, and train step are byte-for-byte the code the async mode
runs — exactly the paper's claim that the contrast is *structural*.
"""
from __future__ import annotations

import threading
import time
from typing import Dict

from repro.runtime.service import RolloutGate


class _DynamicBarrier:
    """A barrier whose party count changes as workers join/leave mid-round
    (episodes end at different times). ``wait`` releases a generation when
    every currently-joined party has arrived."""

    def __init__(self):
        self._cv = threading.Condition()
        self._parties = 0
        self._waiting = 0
        self._gen = 0

    def join(self) -> None:
        with self._cv:
            self._parties += 1

    def leave(self) -> None:
        with self._cv:
            self._parties -= 1
            self._release_if_full()

    def wait(self, stop: threading.Event, poll_s: float = 0.05) -> None:
        with self._cv:
            gen = self._gen
            self._waiting += 1
            self._release_if_full()
            while self._gen == gen:
                if stop.is_set():
                    self._waiting -= 1        # withdraw from this round
                    return
                self._cv.wait(poll_s)

    def _release_if_full(self) -> None:
        # >=, not ==: leave() can drop parties below the waiting count
        if self._parties > 0 and self._waiting >= self._parties:
            self._gen += 1
            self._waiting = 0
            self._cv.notify_all()


class BarrierGate(RolloutGate):
    """Synchronous-mode pacing: episodes gated by a permit quota (episode
    barrier), env steps by a dynamic lockstep barrier (step barrier).

    ``completed`` counts ``end_episode`` calls — finished AND aborted
    episodes — so a permit can never leak: the scheduler's round ends when
    every released permit has been accounted for, even if an episode died
    on an inference error."""

    def __init__(self, lockstep: bool = True):
        self._permits = threading.Semaphore(0)
        self._barrier = _DynamicBarrier()
        self._lockstep = lockstep
        self._done_lock = threading.Lock()
        self.completed = 0

    def release(self, n: int) -> None:
        for _ in range(n):
            self._permits.release()

    def begin_episode(self, stop: threading.Event) -> bool:
        while not stop.is_set():
            if self._permits.acquire(timeout=0.05):
                if self._lockstep:
                    self._barrier.join()
                return True
        return False

    def before_step(self, stop: threading.Event) -> None:
        if self._lockstep:
            self._barrier.wait(stop)

    def end_episode(self) -> None:
        if self._lockstep:
            self._barrier.leave()
        with self._done_lock:
            self.completed += 1


class Scheduler:
    """Drives a system's service registry to a train-step budget."""

    def run(self, system, *, train_steps: int,
            wall_timeout_s: float = 300.0) -> Dict:
        raise NotImplementedError

    @staticmethod
    def _failed(system) -> bool:
        """A crashed service can never make progress — spinning on the
        step counter until the wall clock would hide the crash."""
        return any(s.error is not None for s in system.registry.all())


class FreeRunScheduler(Scheduler):
    """The AcceRL mode: every service free-runs; returns system metrics."""

    def run(self, system, *, train_steps: int,
            wall_timeout_s: float = 300.0) -> Dict:
        t0 = time.monotonic()
        trainer = system.trainer
        try:
            system.registry.start_all()
            while (trainer.steps_done < train_steps
                   and time.monotonic() - t0 < wall_timeout_s
                   and not self._failed(system)):
                time.sleep(0.02)
        finally:
            system.registry.stop_all()
            system.registry.join_all()
        return system.metrics(time.monotonic() - t0)


class BarrierScheduler(Scheduler):
    """Synchronous baseline: rollout quota → barrier → train → broadcast."""

    def __init__(self, *, episodes_per_round: int = 8, lockstep: bool = True):
        self.episodes_per_round = episodes_per_round
        self.lockstep = lockstep

    def run(self, system, *, train_steps: int,
            wall_timeout_s: float = 300.0) -> Dict:
        from repro.runtime.trainer import collate_segments

        t0 = time.monotonic()
        deadline = t0 + wall_timeout_s
        trainer = system.trainer
        trainer.begin_inline()
        gate = BarrierGate(lockstep=self.lockstep)
        workers = system.workers
        for w in workers:
            w.gate = gate
        # step barrier at the inference window: one batched forward per
        # lockstep tick of all live workers
        system.inference.window_batch = max(len(workers), 1)
        empty_rounds = 0
        try:
            # rollout workers (and any attachment services) run; the
            # trainer thread does NOT — the scheduler steps it inline
            system.registry.start_all(exclude_roles=("trainer",))
            while (trainer.steps_done < train_steps
                   and time.monotonic() < deadline
                   and not self._failed(system)):
                # --- rollout phase: the full quota must finish ------------
                # (gate.completed counts aborted episodes too, so a failed
                # episode cannot leak its permit and stall the round)
                target = gate.completed + self.episodes_per_round
                gate.release(self.episodes_per_round)
                while (gate.completed < target
                       and time.monotonic() < deadline
                       and not self._failed(system)):
                    time.sleep(0.005)
                # --- train phase (rollouts idle — cluster barrier) --------
                segments = system.experience.drain()
                batch_size = trainer.prefetcher.batch_size
                if not segments:
                    # a completed round with zero data means every episode
                    # aborted (dead inference / broken store) — fail loudly
                    # like the old inline loop did, don't spin to the wall
                    if time.monotonic() < deadline:
                        empty_rounds += 1
                        if empty_rounds >= 2:
                            raise RuntimeError(
                                "sync rollout rounds produce no segments — "
                                "every episode is aborting (inference or "
                                "weight-store failure?)")
                    continue
                empty_rounds = 0
                trainer.train_on_batch(
                    collate_segments(segments[:batch_size]))
                dropped = max(len(segments) - batch_size, 0)
                if dropped:
                    # single-epoch semantics: a sync round trains on ONE
                    # super-batch; the surplus is discarded, as the
                    # baseline's inline loop always did
                    trainer.metrics.inc("sync_surplus_segments", dropped)
        finally:
            system.registry.stop_all()
            system.registry.join_all()
        return system.metrics(time.monotonic() - t0)
