"""The asynchronous runtime (paper §3): rollout workers, the
Inference-as-a-Service pool with dynamic-window batching (eq. 1), the
trainer worker, the versioned weight store with the drain protocol
(App. D.6), and the orchestrator that wires them into the fully
asynchronous pipeline — or the synchronous baseline (``sync_mode=True``)
that reproduces the long-tail bubbles of Figure 1."""
from repro.runtime.weight_store import (  # noqa: F401
    DirectTransport,
    DiskTransport,
    SerializedTransport,
    VersionedWeightStore,
)
from repro.runtime.inference import InferenceService  # noqa: F401
from repro.runtime.rollout import RolloutWorker  # noqa: F401
from repro.runtime.trainer import TrainerWorker  # noqa: F401
from repro.runtime.orchestrator import AcceRLSystem  # noqa: F401
