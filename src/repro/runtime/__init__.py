"""The asynchronous runtime (paper §3), structured in three layers:

  * **Service** (``service.py``) — the uniform start/stop/join lifecycle,
    health state, and per-service ``MetricsRegistry`` that every component
    (rollout workers, the Inference-as-a-Service pool with dynamic-window
    batching (eq. 1), trainer loops, imagination producers, WM trainers)
    implements, wired on a ``ServiceRegistry`` bus;
  * **ExperienceChannel** (``experience.py``) — the data plane: FIFO /
    ring channels with pluggable backpressure and the
    ``MixedExperienceSource`` composing real and imagined segments;
  * **Scheduler** (``scheduler.py``) — ``FreeRunScheduler`` (the fully
    asynchronous pipeline) and ``BarrierScheduler`` (the synchronous
    baseline with step/episode/cluster barriers of Figure 1) pacing the
    SAME services.

``orchestrator.AcceRLSystem`` composes the layers; extensions (the world
model, paper §4) plug in via ``system.attach(...)``. The versioned weight
store implements the drain protocol (App. D.6)."""
from repro.runtime.weight_store import (  # noqa: F401
    DirectTransport,
    DiskTransport,
    SerializedTransport,
    VersionedWeightStore,
)
from repro.runtime.service import (  # noqa: F401
    MetricsRegistry,
    NullGate,
    RolloutGate,
    Service,
    ServiceRegistry,
    ServiceState,
)
from repro.runtime.experience import (  # noqa: F401
    ExperienceChannel,
    FifoChannel,
    MixedExperienceSource,
    RingChannel,
)
from repro.runtime.scheduler import (  # noqa: F401
    BarrierGate,
    BarrierScheduler,
    FreeRunScheduler,
    Scheduler,
)
from repro.runtime.inference import InferenceService  # noqa: F401
from repro.runtime.pipeline_exec import (  # noqa: F401
    Instruction,
    PipelineExecutor,
    PipelineOp,
    Submesh,
    SubmeshLayout,
    build_train_schedules,
    validate_schedules,
)
from repro.runtime.rollout import RolloutWorker  # noqa: F401
from repro.runtime.step_program import (  # noqa: F401
    StageSpec,
    StepProgram,
    build_train_step_program,
)
from repro.runtime.trainer import TrainerWorker  # noqa: F401
from repro.runtime.transport import (  # noqa: F401
    ChannelClosed,
    RemoteWorkerSpec,
    RestartPolicy,
    ShmChannel,
    SocketChannel,
    SupervisedWorker,
    Supervisor,
    TransportError,
    TransportServer,
    WeightStoreTransport,
)
from repro.runtime.orchestrator import AcceRLSystem  # noqa: F401
