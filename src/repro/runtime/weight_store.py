"""Versioned weight store with the inference-drain protocol (App. D.6).

The paper's NCCL broadcast + drain maps to a publish/acquire channel:

  * ``begin_publish()`` — the trainer's *drain signal*, sent before the
    optimizer step finishes. Inference workers stop scheduling new batches,
    finish in-flight computation, and park at ``wait_weights()``.
  * ``publish(params, version)`` — the broadcast: an atomic in-place swap of
    the weight reference (on real pods: an ICI device-to-device transfer
    onto the inference mesh slice).
  * ``acquire()`` — inference side: newest (params, version).

Three transports reproduce Table 8's comparison:
  * :class:`DirectTransport`      — in-memory reference swap (NCCL analogue)
  * :class:`SerializedTransport`  — full serialize→deserialize round-trip
    (PCIe / host-mediated analogue)
  * :class:`DiskTransport`        — checkpoint write + poll + reload
    (shared-storage / AReaL analogue)
"""
from __future__ import annotations

import io
import pathlib
import pickle
import tempfile
import threading
import time
from typing import Any, Optional, Tuple

import numpy as np


class DirectTransport:
    """Reference handoff — the NCCL-broadcast analogue."""

    name = "nccl_direct"

    def send(self, params: Any) -> Any:
        return params

    def recv(self, payload: Any) -> Any:
        return payload


class SerializedTransport:
    """Full host-side serialize/deserialize — the PCIe/host-mediated path."""

    name = "host_serialized"

    def send(self, params: Any) -> bytes:
        import jax
        host = jax.tree.map(np.asarray, params)
        return pickle.dumps(host, protocol=pickle.HIGHEST_PROTOCOL)

    def recv(self, payload: bytes) -> Any:
        return pickle.loads(payload)


class DiskTransport:
    """Checkpoint to shared storage + reload — the AReaL-style path."""

    name = "shared_storage"

    def __init__(self, directory: Optional[str] = None):
        self._dir = pathlib.Path(directory or tempfile.mkdtemp(
            prefix="accerl_ckpt_"))
        self._dir.mkdir(parents=True, exist_ok=True)

    def send(self, params: Any) -> str:
        import jax
        host = jax.tree.map(np.asarray, params)
        leaves, treedef = jax.tree.flatten(host)
        buf = io.BytesIO()
        np.savez(buf, *leaves)
        path = self._dir / f"ckpt_{time.time_ns()}.npz"
        tmp = path.with_suffix(".tmp")
        tmp.write_bytes(buf.getvalue())
        tmp.rename(path)                      # atomic publish
        self._treedef = treedef
        return str(path)

    def recv(self, payload: str) -> Any:
        import jax
        with np.load(payload) as z:
            leaves = [z[k] for k in z.files]
        return jax.tree.unflatten(self._treedef, leaves)


class VersionedWeightStore:
    """Thread-safe publish/acquire channel between trainer and inference."""

    def __init__(self, transport=None):
        self.transport = transport or DirectTransport()
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._payload = None
        self._version = -1
        self._draining = False
        self.publishes = 0
        self.last_sync_latency_s = 0.0
        # optional observer called AFTER each publish commits (outside the
        # cv, so a slow observer never blocks acquirers) with
        # ``(params, version)`` — the transport journal hangs off this
        self.on_publish = None

    # -- trainer side --------------------------------------------------------
    def begin_publish(self) -> None:
        """Drain signal: sent before the optimizer step completes."""
        with self._lock:
            self._draining = True

    def publish(self, params: Any, version: int) -> None:
        t0 = time.monotonic()
        payload = self.transport.send(params)
        with self._cv:
            self._payload = payload
            self._version = version
            self._draining = False
            self.publishes += 1
            self.last_sync_latency_s = time.monotonic() - t0
            self._cv.notify_all()
        hook = self.on_publish
        if hook is not None:
            hook(params, version)

    # -- inference side ------------------------------------------------------
    @property
    def draining(self) -> bool:
        with self._lock:
            return self._draining

    def version(self) -> int:
        with self._lock:
            return self._version

    def acquire(self, newer_than: int = -1,
                timeout: Optional[float] = None) -> Optional[Tuple[Any, int]]:
        """Newest (params, version); blocks until version > ``newer_than``."""
        raw = self.acquire_raw(newer_than, timeout)
        if raw is None:
            return None
        payload, version = raw
        return self.transport.recv(payload), version

    def acquire_raw(self, newer_than: int = -1,
                    timeout: Optional[float] = None
                    ) -> Optional[Tuple[Any, int]]:
        """Newest (transport payload, version) WITHOUT ``transport.recv``:
        the wire server (runtime/transport) re-serves the published
        payload to many remote consumers and decodes/encodes once per
        version instead of once per acquire."""
        with self._cv:
            if not self._cv.wait_for(
                    lambda: self._version > newer_than, timeout=timeout):
                return None
            return self._payload, self._version
