"""Pipeline-wide observability: cross-process trace spans + Perfetto export.

The async pipeline's whole claim (paper §3) is overlap — rollouts,
inference, and training proceeding without barriers. Counters can say the
overlap exists; only a *timeline* can show where it breaks. This module is
the span recorder behind that timeline:

  * **Import-gated like ``transport/faults.py``** — hot modules do::

        if os.environ.get("REPRO_TRACE"):
            from repro.runtime import telemetry as _tel
        else:
            _tel = None

    so with ``REPRO_TRACE`` unset this module is *never imported* and
    every instrumentation site costs one ``is None`` check. Spawned child
    processes inherit ``os.environ``, so one env var lights up the whole
    process tree.

  * **Per-thread append-only ring buffers** — :func:`span` /
    :func:`instant` append one small dict to a thread-local ring (no
    locks on the hot path; the registration of a NEW thread's buffer is
    the only locked step). The ring bounds memory: a long run keeps the
    newest ``REPRO_TRACE_BUF`` events per thread.

  * **Trace context that crosses the wire** — :func:`context` installs a
    ``(trace, span)`` pair thread-locally; :func:`wire_ctx` reads it back
    as JSON-safe header fields (``tr``/``sp``). PutStream frames,
    ``infer.submit`` requests, and ``worker.report`` payloads carry these
    ids, so one experience flush is followable rollout worker → wire →
    TransportServer → replay → trainer collate, and one weight version
    publish → acquire → first action (the policy-lag path).

  * **Chrome-trace-event export** — :func:`dump` writes
    ``{"traceEvents": [...]}`` that loads directly in Perfetto
    (ui.perfetto.dev) or ``chrome://tracing``. Complete events (``ph:X``)
    carry ``args.trace``; flow events (``s``/``t``/``f``) with
    ``id = trace`` draw the cross-process arrows. Timestamps are epoch
    microseconds (``time.time_ns() // 1000``) so events from different
    processes land on one comparable axis.

Child-process buffers travel to the parent as the ``trace`` key of
``worker.report`` payloads (see ``transport/remote.py``); the server folds
them into the parent's collector via :func:`extend_foreign`, so one
``trace.dump`` (or ``--trace-out``) sees the whole tree.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import os
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.runtime.service import Service as _ServiceBase

ENV_VAR = "REPRO_TRACE"

#: per-thread ring capacity (events); the cap bounds a long run's memory
BUF_EVENTS = int(os.environ.get("REPRO_TRACE_BUF", "65536") or "65536")
#: cap on events adopted from child processes (oldest dropped first)
FOREIGN_EVENTS = 4 * BUF_EVENTS

_pid = os.getpid()


def enabled() -> bool:
    """Whether recording is on. Gated importers never load this module
    when off, but direct importers (tests, exporters) may call it."""
    return bool(os.environ.get(ENV_VAR))


def now_us() -> int:
    """Epoch microseconds — the one clock every process shares, so spans
    from different processes align on a single Perfetto axis."""
    return time.time_ns() // 1000


def new_id() -> int:
    """A fresh 63-bit trace/span id (positive, JSON/int64-safe)."""
    return int.from_bytes(os.urandom(8), "big") >> 1


class _Buf:
    """One thread's append-only event ring (no lock: single writer)."""

    __slots__ = ("events", "idx", "dropped", "tid")

    def __init__(self, tid: int):
        self.events: List[Dict] = []
        self.idx = 0                       # next overwrite slot once full
        self.dropped = 0
        self.tid = tid

    def append(self, ev: Dict) -> None:
        if len(self.events) < BUF_EVENTS:
            self.events.append(ev)
        else:                              # ring wrap: newest wins
            self.events[self.idx] = ev
            self.idx = (self.idx + 1) % BUF_EVENTS
            self.dropped += 1

    def drain(self) -> List[Dict]:
        out = self.events[self.idx:] + self.events[:self.idx]
        self.events, self.idx = [], 0
        return out


_local = threading.local()
_reg_lock = threading.Lock()
_bufs: List[_Buf] = []
_foreign: List[Dict] = []
_foreign_dropped = 0


def _buf() -> _Buf:
    b = getattr(_local, "buf", None)
    if b is None:
        b = _Buf(threading.get_ident())
        with _reg_lock:
            _bufs.append(b)
        _local.buf = b
    return b


# -- trace context ------------------------------------------------------------
def current() -> Optional[Tuple[int, int]]:
    """The installed ``(trace, span)`` pair for this thread, or None."""
    stack = getattr(_local, "ctx", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def context(trace: int, span: int = 0) -> Iterator[None]:
    """Install a trace context for the dynamic extent — spans opened
    inside inherit ``trace`` and parent onto ``span``; :func:`wire_ctx`
    reads it for header stamping."""
    stack = getattr(_local, "ctx", None)
    if stack is None:
        stack = _local.ctx = []
    stack.append((int(trace), int(span)))
    try:
        yield
    finally:
        stack.pop()


def wire_ctx() -> Dict[str, int]:
    """The current context as JSON-safe frame-header fields (``tr`` /
    ``sp``) — {} when no context is installed."""
    cur = current()
    if cur is None:
        return {}
    return {"tr": cur[0], "sp": cur[1]}


# -- recording ----------------------------------------------------------------
_FLOW_PH = {"start": "s", "step": "t", "end": "f"}


def _flow_event(name: str, trace: int, ts: int, flow: str,
                tid: int) -> Dict:
    ev = {"name": name, "cat": "flow", "ph": _FLOW_PH[flow],
          "id": trace, "ts": ts, "pid": _pid, "tid": tid}
    if flow != "start":
        ev["bp"] = "e"                     # bind to the enclosing slice
    return ev


#: span ids only disambiguate parent/child within one process's trace
#: view, so a counter off a random base beats an urandom syscall per span
_sid_counter = itertools.count(int.from_bytes(os.urandom(6), "big"))


class _Span:
    """Class-based context manager for :func:`span` — the put-path hot
    wrapper, so no generator-contextmanager machinery."""

    __slots__ = ("name", "cat", "trace", "parent", "args", "flow",
                 "sid", "buf", "t0", "_stack")

    def __init__(self, name, cat, trace, parent, args, flow):
        self.name, self.cat, self.flow = name, cat, flow
        self.trace, self.parent, self.args = trace, parent, args

    def __enter__(self) -> Tuple[int, int]:
        cur = current()
        if self.trace is None:
            self.trace = cur[0] if cur else new_id()
        if self.parent is None and cur is not None:
            self.parent = cur[1]
        self.sid = next(_sid_counter)
        stack = getattr(_local, "ctx", None)
        if stack is None:
            stack = _local.ctx = []
        stack.append((int(self.trace), self.sid))
        self._stack = stack
        self.buf = _buf()
        self.t0 = now_us()
        return (self.trace, self.sid)

    def __exit__(self, *exc) -> None:
        buf, t0 = self.buf, self.t0
        a: Dict[str, Any] = {"trace": int(self.trace), "span": self.sid}
        if self.parent:
            a["parent"] = int(self.parent)
        if self.args:
            a.update(self.args)
        buf.append({"name": self.name, "cat": self.cat, "ph": "X",
                    "ts": t0, "dur": max(now_us() - t0, 1), "pid": _pid,
                    "tid": buf.tid, "args": a})
        if self.flow is not None:
            buf.append(_flow_event(self.name, self.trace, t0, self.flow,
                                   buf.tid))
        self._stack.pop()


def span(name: str, *, cat: str = "repro", trace: Optional[int] = None,
         parent: Optional[int] = None, args: Optional[Dict] = None,
         flow: Optional[str] = None) -> _Span:
    """Record a complete event (``ph:X``) around the body and install its
    ``(trace, span)`` as the thread context. ``trace=None`` inherits the
    installed context (new root trace otherwise). ``flow`` in
    {"start","step","end"} additionally emits a flow event with
    ``id = trace`` — the Perfetto arrow tying this slice to its
    cross-process siblings. Yields ``(trace, span_id)``."""
    return _Span(name, cat, trace, parent, args, flow)


def instant(name: str, *, cat: str = "repro", trace: Optional[int] = None,
            args: Optional[Dict] = None, flow: Optional[str] = None) -> None:
    """Record a point event (``ph:i``); same trace/flow semantics as
    :func:`span` without a duration or context install."""
    cur = current()
    if trace is None and cur is not None:
        trace = cur[0]
    buf = _buf()
    ts = now_us()
    a: Dict[str, Any] = {} if trace is None else {"trace": int(trace)}
    if args:
        a.update(args)
    buf.append({"name": name, "cat": cat, "ph": "i", "s": "t", "ts": ts,
                "pid": _pid, "tid": buf.tid, "args": a})
    if flow is not None and trace is not None:
        buf.append(_flow_event(name, trace, ts, flow, buf.tid))


# -- collection / export ------------------------------------------------------
def extend_foreign(events: List[Dict]) -> None:
    """Adopt events shipped from another process (``worker.report``'s
    ``trace`` payload). Bounded: oldest foreign events drop first."""
    global _foreign_dropped
    if not events:
        return
    with _reg_lock:
        _foreign.extend(e for e in events if isinstance(e, dict))
        excess = len(_foreign) - FOREIGN_EVENTS
        if excess > 0:
            del _foreign[:excess]
            _foreign_dropped += excess


def drain(clear: bool = True) -> List[Dict]:
    """Collect every buffered event (all threads + foreign), clearing the
    buffers by default. ``clear=False`` copies without consuming."""
    with _reg_lock:
        bufs = list(_bufs)
        if clear:
            foreign, _foreign[:] = list(_foreign), []
        else:
            foreign = list(_foreign)
    out: List[Dict] = []
    for b in bufs:
        if clear:
            out.extend(b.drain())
        else:
            out.extend(b.events[b.idx:] + b.events[:b.idx])
    out.extend(foreign)
    return out


def dump(path: str, events: Optional[List[Dict]] = None,
         *, process_name: str = "") -> int:
    """Write a Chrome-trace-event JSON file (open in Perfetto). Drains
    the buffers unless ``events`` is given. Returns the event count."""
    if events is None:
        events = drain()
    meta: List[Dict] = []
    if process_name:
        meta.append({"name": "process_name", "ph": "M", "pid": _pid,
                     "tid": 0, "args": {"name": process_name}})
    doc = {"traceEvents": meta + events, "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(doc, f, separators=(",", ":"))
    return len(events)


def reset() -> None:
    """Drop every buffered event and foreign record (test isolation)."""
    global _foreign_dropped
    with _reg_lock:
        for b in _bufs:
            b.events, b.idx, b.dropped = [], 0, 0
        _foreign[:] = []
        _foreign_dropped = 0


class TelemetrySink(_ServiceBase):
    """A Service that samples the :class:`ServiceRegistry` into timestamped
    history — the scrape target behind the ``metrics.snapshot`` wire
    endpoint and the optional JSONL file.

    Each sample is ``{"t": epoch_s, "services": registry.snapshot(),
    "health": registry.health()}`` — counters, gauges, series summaries,
    histograms, and any structured crash records, at one instant. The
    in-memory history is bounded (``history`` samples); ``path`` appends
    one JSON line per sample for offline analysis. Unlike the span
    recorder this needs no env gating: it samples at ``interval_s``, not
    per operation.

    Declared here (not ``service.py``) so the observability plane stays
    one module; imported lazily by the orchestrator to keep gated-off
    processes from loading it as a side effect.
    """

    def __init__(self, registry, *, interval_s: float = 1.0,
                 history: int = 256, path: str = ""):
        super().__init__("telemetry", role="observability")
        self._registry = registry
        self._interval = max(float(interval_s), 0.05)
        self._history_cap = max(int(history), 1)
        self._path = path
        self._samples: List[Dict] = []
        self._samples_lock = threading.Lock()
        self._file = None

    def on_start(self) -> None:
        if self._path:
            self._file = open(self._path, "a")

    def sample(self) -> Dict:
        """Take (and retain) one sample now — also the wire endpoint's
        body via :meth:`latest`."""
        s = {"t": time.time(),
             "services": self._registry.snapshot(),
             "health": self._registry.health()}
        with self._samples_lock:
            self._samples.append(s)
            if len(self._samples) > self._history_cap:
                del self._samples[:len(self._samples) - self._history_cap]
        if self._file is not None:
            try:
                self._file.write(json.dumps(s, default=str) + "\n")
                self._file.flush()
            except OSError:
                pass
        return s

    def latest(self) -> Optional[Dict]:
        with self._samples_lock:
            return self._samples[-1] if self._samples else None

    def tail(self, n: int = 0) -> List[Dict]:
        with self._samples_lock:
            return list(self._samples[-n:] if n else self._samples)

    def _run(self) -> None:
        while not self._stop.wait(self._interval):
            self.sample()

    def on_stop(self) -> None:
        self.sample()                      # final sample: shutdown state
        if self._file is not None:
            try:
                self._file.close()
            finally:
                self._file = None
