"""ExperienceChannel: the typed data plane between runtime services.

The paper's pipeline moves experience through three conceptual channels —
``B`` (real trajectory segments → trainer), ``B_wm`` (real transitions →
world-model trainers + imagination seeds), and ``B_img`` (imagined segments
→ trainer). This module gives them one abstraction over the host-side
buffers in :mod:`repro.data.replay`:

  * :class:`FifoChannel`   — streaming single-epoch segments with a
    pluggable backpressure policy (drop_oldest / drop_newest / block);
  * :class:`RingChannel`   — uniform-resampling transitions;
  * :class:`MixedExperienceSource` — composes a real and an imagined
    channel at a configurable real fraction, so the trainer consumes ONE
    source regardless of whether a world model is attached (the mix ratio
    is how §4's "policy trains on B_img" generalizes to hybrid diets).

Everything exposing ``pop_batch(n, timeout)`` is a valid trainer source
(the :class:`~repro.data.prefetch.Prefetcher` contract).
"""
from __future__ import annotations

import abc
import os
import time
from typing import Any, Dict, List, Optional

from repro.data.replay import (BACKPRESSURE_POLICIES, FIFOReplayBuffer,
                               RingReplayBuffer)

# Import-gated tracing (see transport.faults for the idiom).
if os.environ.get("REPRO_TRACE"):
    from repro.runtime import telemetry as _tel
else:  # pragma: no cover - default path
    _tel = None

__all__ = ["BACKPRESSURE_POLICIES", "ExperienceChannel", "FifoChannel",
           "RingChannel", "MixedExperienceSource"]


def _trace_pop(out: Optional[List[Any]], where: str) -> None:
    """Mark a successful drain on the trace of its FIRST item: segments
    carry ``_trace`` stamped by the rollout worker, so the replay hop
    shows up on the same episode timeline as rollout.put/server.apply."""
    if _tel is None or not out:
        return
    first = out[0]
    trace = first.get("_trace") if isinstance(first, dict) else None
    if trace is not None:
        _tel.instant("replay.pop", cat="experience", trace=int(trace),
                     args={"count": len(out), "src": where}, flow="step")


class ExperienceChannel(abc.ABC):
    """Producer-facing contract: non-blocking-ish ``put`` + depth + stats."""

    @abc.abstractmethod
    def put(self, item: Any) -> bool:
        """Offer one item; False iff rejected by the backpressure policy."""

    def put_many(self, items: List[Any]) -> List[bool]:
        """Offer a batch; one backpressure verdict per item. In-process
        this is just a loop, but remote channels override it into a single
        wire round-trip (one codec blob per flush instead of one per
        item), so producers should flush episodes through it."""
        return [self.put(item) for item in items]

    def pop_many(self, max_items: int, timeout: Optional[float] = None
                 ) -> Optional[List[Any]]:
        """Coalescing drain: block (up to ``timeout``) only for the FIRST
        item, then take everything immediately available up to
        ``max_items`` — never fewer than one on success, never blocks to
        round a batch out. Remote channels override it into ONE wire
        round-trip and codec blob per drain; consumers that can accept
        partial batches (the prefetcher, the mixed source) should drain
        through it. Default rides on ``pop_batch`` where a subclass
        provides one."""
        if max_items <= 0:
            return None
        pop_batch = getattr(self, "pop_batch", None)
        if pop_batch is None:
            raise NotImplementedError(
                f"{type(self).__name__} has no pop path")
        got = pop_batch(1, timeout=timeout)
        if not got:
            return None
        if max_items > 1:
            more = pop_batch(min(max_items - 1, len(self)), timeout=0) \
                if len(self) else None
            if more:
                got = list(got) + list(more)
        return got

    @abc.abstractmethod
    def __len__(self) -> int:
        ...

    def stats(self) -> Dict[str, float]:
        return {"depth": float(len(self))}


class FifoChannel(ExperienceChannel):
    """Streaming segment channel (B / B_img): FIFO, single-epoch pops."""

    def __init__(self, capacity: int, *, policy: str = "drop_oldest",
                 block_timeout: float = 0.5):
        self._buf = FIFOReplayBuffer(capacity, policy=policy)
        self._block_timeout = block_timeout

    @property
    def policy(self) -> str:
        return self._buf.policy

    @property
    def capacity(self) -> int:
        return self._buf.capacity

    def put(self, item: Any) -> bool:
        return self._buf.push(item, timeout=self._block_timeout)

    def pop_batch(self, n: int, timeout: Optional[float] = None
                  ) -> Optional[List[Any]]:
        out = self._buf.pop_batch(n, timeout=timeout)
        if _tel is not None:
            _trace_pop(out, "fifo")
        return out

    def pop_many(self, max_items: int, timeout: Optional[float] = None
                 ) -> Optional[List[Any]]:
        # single lock acquisition in the buffer, not two pop_batch calls
        out = self._buf.pop_upto(max_items, timeout=timeout)
        if _tel is not None:
            _trace_pop(out, "fifo")
        return out

    def drain(self) -> List[Any]:
        return self._buf.drain()

    def peek_all(self) -> List[Any]:
        """Non-destructive copy (journal snapshot capture)."""
        return self._buf.peek_all()

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def total_pushed(self) -> int:
        return self._buf.total_pushed

    @property
    def total_dropped(self) -> int:
        return self._buf.total_dropped

    def stats(self) -> Dict[str, float]:
        return {"depth": float(len(self)),
                "pushed": float(self.total_pushed),
                "dropped": float(self.total_dropped)}


class RingChannel(ExperienceChannel):
    """Resampling transition channel (B_wm): ring storage, uniform sample."""

    def __init__(self, capacity: int, seed: int = 0):
        self._buf = RingReplayBuffer(capacity, seed=seed)

    def put(self, item: Any) -> bool:
        self._buf.push(item)
        return True

    def sample(self, n: int) -> Optional[List[Any]]:
        return self._buf.sample(n)

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def total_pushed(self) -> int:
        return self._buf.total_pushed

    def stats(self) -> Dict[str, float]:
        return {"depth": float(len(self)),
                "pushed": float(self.total_pushed)}


class MixedExperienceSource:
    """Compose a real and an imagined FIFO channel into one trainer source.

    ``real_fraction`` sets the target share of real segments per batch.
    For intermediate fractions, a starved side is backfilled by the other
    so the trainer never stalls on the mix (availability beats ratio).
    The extremes are HARD pins: ``0.0`` reproduces the paper's WM mode —
    the policy trains purely on B_img and waits for imagination rather
    than silently consuming real segments — and ``1.0`` is the pure
    model-free diet.

    Single-consumer source (the trainer's prefetcher): items gathered
    before a timeout are carried to the next ``pop_batch`` call, so
    batches are always exactly ``n`` items and nothing is dropped.
    """

    def __init__(self, real, imagined, *, real_fraction: float = 0.0):
        if not 0.0 <= real_fraction <= 1.0:
            raise ValueError(f"real_fraction must be in [0, 1], "
                             f"got {real_fraction}")
        self.real = real
        self.imagined = imagined
        self.real_fraction = real_fraction
        self.real_consumed = 0
        self.imagined_consumed = 0
        self._pending: List[Any] = []

    def _take(self, chan, k: int) -> int:
        # coalesced non-blocking drain: one call (one RPC when the side
        # is remote), no separate len() probe to race against producers
        got = chan.pop_many(k, timeout=0) if k else None
        if got:
            self._pending.extend(got)
            return len(got)
        return 0

    def _mix_round(self, need: int, want_real: int, taken_real: int) -> int:
        """ONE non-blocking take at the mix policy (the single home of
        the ratio rules): real share first (capped by availability),
        backfill across sides only for intermediate fractions — the
        extremes are hard pins (0.0 never touches real, 1.0 never
        imagined). Returns how many real items were taken."""
        k_real = min(max(want_real - taken_real, 0), len(self.real))
        if (0.0 < self.real_fraction
                and len(self.imagined) < need - k_real):
            k_real = min(need - len(self.imagined), len(self.real))
        got_real = self._take(self.real, min(k_real, need))
        self.real_consumed += got_real
        k_img = need - got_real if self.real_fraction < 1.0 else 0
        self.imagined_consumed += self._take(self.imagined, k_img)
        return got_real

    def pop_batch(self, n: int, timeout: Optional[float] = None,
                  poll_s: float = 0.005) -> Optional[List[Any]]:
        deadline = None if timeout is None else time.monotonic() + timeout
        want_real = int(round(n * self.real_fraction))
        taken_real = 0
        while True:
            need = n - len(self._pending)
            if need <= 0:
                out, self._pending = (self._pending[:n],
                                      self._pending[n:])
                if _tel is not None:
                    _trace_pop(out, "mixed")
                    self._blend_trace(out)
                return out
            taken_real += self._mix_round(need, want_real, taken_real)
            if len(self._pending) >= n:
                continue
            if deadline is not None and time.monotonic() >= deadline:
                return None        # gathered items carry to the next call
            time.sleep(poll_s)

    def pop_many(self, max_items: int, timeout: Optional[float] = None,
                 poll_s: float = 0.005) -> Optional[List[Any]]:
        """Coalescing drain at the mixed ratio: returns as soon as ANY
        items are available (≤ ``max_items``) instead of waiting to round
        out an exact batch — the prefetcher accumulates partials, so the
        mix is still targeted per drain but a starved side never stalls
        the pipeline. The extremes stay hard pins (0.0 never touches
        real, 1.0 never imagined)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        want_real = int(round(max_items * self.real_fraction))
        while True:
            if self._pending:
                out, self._pending = (self._pending[:max_items],
                                      self._pending[max_items:])
                if _tel is not None:
                    _trace_pop(out, "mixed")
                    self._blend_trace(out)
                return out
            self._mix_round(max_items, want_real, 0)
            if self._pending:
                continue
            if deadline is not None and time.monotonic() >= deadline:
                return None
            time.sleep(poll_s)

    def _blend_trace(self, out: List[Any]) -> None:
        """One ``mixed.blend`` instant per served drain, on the batch's
        trace id (first traced item): the real/imagined diet actually
        served shows up next to wm.imagine on the Perfetto timeline."""
        first = out[0]
        trace = first.get("_trace") if isinstance(first, dict) else None
        _tel.instant("mixed.blend", cat="experience",
                     trace=int(trace) if trace is not None else None,
                     args={"count": len(out),
                           "real_consumed": self.real_consumed,
                           "imagined_consumed": self.imagined_consumed,
                           "real_fraction": self.real_fraction},
                     flow="step")

    def __len__(self) -> int:
        return len(self.real) + len(self.imagined)

    def stats(self) -> Dict[str, float]:
        return {"depth": float(len(self)),
                "real_consumed": float(self.real_consumed),
                "imagined_consumed": float(self.imagined_consumed),
                "real_fraction": self.real_fraction}
