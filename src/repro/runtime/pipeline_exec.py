"""Pipelined multi-submesh training runtime (Alpa-style static schedules).

Runs the policy trainer and the world-model trainer as pipeline stages on
disjoint submeshes of one local device set. Each submesh executes a
STATIC instruction schedule — a flat tuple of RUN / SEND / RECV / FREE
instructions compiled from the :class:`~repro.runtime.step_program
.StepProgram` — on its own worker thread:

  * RUN   — invoke one jitted stage body on buffers already resident on
            the submesh (micro-batch grads fold into the f32 accumulator
            immediately after each fwd_bwd, GPipe/1F1B-style, so live
            gradient memory is bounded to ONE micro-batch regardless of
            the accumulation depth);
  * SEND / RECV — rendezvous through a tagged mailbox; cross-submesh
            transfers reshard via ``jax.device_put`` onto the receiving
            submesh (the weight-publish path out of the policy submesh is
            exactly this resharding);
  * FREE  — drop the buffer reference so XLA can reuse the allocation;
            the schedule validator proves every buffer is freed and that
            the micro-grad high-water mark is 1.

On CPU CI the submeshes are slices of the host device list (with a single
device both stages share it — schedule semantics identical, overlap nil),
so schedule correctness, parity against the fused path, and bubble
accounting are all testable without a TPU.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.step_program import StepProgram

# Import-gated tracing (see runtime/trainer.py for the idiom).
if os.environ.get("REPRO_TRACE"):
    from repro.runtime import telemetry as _tel
else:  # pragma: no cover - default path
    _tel = None


class PipelineOp(enum.IntEnum):
    RUN = 0
    SEND = 1
    RECV = 2
    FREE = 3


@dataclasses.dataclass(frozen=True)
class Instruction:
    """One schedule entry. RUN names a program stage and its buffer
    bindings; SEND/RECV move ``buffer`` through the mailbox under
    ``tag``; FREE drops ``buffer``."""

    op: PipelineOp
    stage: str = ""
    inputs: Tuple[str, ...] = ()
    outputs: Tuple[str, ...] = ()
    buffer: str = ""
    micro: int = -1
    tag: str = ""

    def __repr__(self):
        if self.op == PipelineOp.RUN:
            m = f" m={self.micro}" if self.micro >= 0 else ""
            return (f"RUN {self.stage}{m} ({','.join(self.inputs)})->"
                    f"({','.join(self.outputs)})")
        if self.op == PipelineOp.FREE:
            return f"FREE {self.buffer}"
        return f"{self.op.name} {self.buffer} tag={self.tag}"


@dataclasses.dataclass(frozen=True)
class Submesh:
    """A named slice of the local device list."""

    name: str
    devices: Tuple

    @property
    def device(self):
        return self.devices[0]

    def mesh(self):
        """(n, 1) Mesh over exactly these devices, axes (data, model)."""
        from jax.sharding import Mesh
        return Mesh(np.array(self.devices).reshape(len(self.devices), 1),
                    ("data", "model"))


@dataclasses.dataclass(frozen=True)
class SubmeshLayout:
    """Policy + WM submeshes carved from one device list."""

    policy: Submesh
    wm: Submesh
    disjoint: bool

    @classmethod
    def split(cls, devices: Sequence, *, wm_devices: int = 0
              ) -> "SubmeshLayout":
        """Slice the host device list: the WM stage takes ``wm_devices``
        from the tail (default: half when >=2 devices). With one device
        both submeshes alias it — the schedules still interleave
        correctly, there is just nothing to overlap."""
        devices = tuple(devices)
        if len(devices) >= 2:
            n_wm = wm_devices or len(devices) // 2
            n_wm = max(1, min(n_wm, len(devices) - 1))
            return cls(Submesh("policy", devices[:len(devices) - n_wm]),
                       Submesh("wm", devices[len(devices) - n_wm:]),
                       disjoint=True)
        return cls(Submesh("policy", devices), Submesh("wm", devices),
                   disjoint=False)


# --------------------------------------------------------------------------
# schedule construction + static validation
# --------------------------------------------------------------------------

def _I(op, **kw):
    return Instruction(op=op, **kw)


@functools.lru_cache(maxsize=32)
def build_train_schedules(n_micro: int, wm_micro: int
                          ) -> Dict[str, Tuple[Instruction, ...]]:
    """Static per-submesh schedules for one training round.

    Policy stream: RECV state + micro feeds, fold each micro-batch's
    grads immediately (1F1B — ``g{m}`` FREEd before ``g{m+1}`` exists),
    optimizer update, SEND the updated state back to the host (the
    cross-mesh weight-publish reshard). WM stream: one RUN per WM
    micro-batch. Host-side tags are the feeds/collects of
    ``PipelineExecutor.run_round``.
    """
    pol: List[Instruction] = [
        _I(PipelineOp.RECV, buffer="state", tag="host:policy:state"),
        _I(PipelineOp.RUN, stage="grad_reduce/init", inputs=("state",),
           outputs=("acc0",)),
    ]
    for m in range(n_micro):
        pol += [
            _I(PipelineOp.RECV, buffer=f"mb{m}", tag=f"host:policy:micro{m}"),
            _I(PipelineOp.RUN, stage="fwd_bwd", micro=m,
               inputs=("state", f"mb{m}"), outputs=(f"g{m}", f"aux{m}")),
            _I(PipelineOp.RUN, stage="grad_reduce", micro=m,
               inputs=(f"acc{m}", f"g{m}", f"aux{m}"),
               outputs=(f"acc{m + 1}",)),
            _I(PipelineOp.FREE, buffer=f"g{m}"),
            _I(PipelineOp.FREE, buffer=f"mb{m}"),
            _I(PipelineOp.FREE, buffer=f"acc{m}"),
        ]
        if m < n_micro - 1:
            pol.append(_I(PipelineOp.FREE, buffer=f"aux{m}"))
    last = n_micro - 1
    pol += [
        _I(PipelineOp.RUN, stage="optim_update",
           inputs=("state", f"acc{n_micro}", f"aux{last}"),
           outputs=("state_out", "metrics")),
        _I(PipelineOp.FREE, buffer=f"acc{n_micro}"),
        _I(PipelineOp.FREE, buffer=f"aux{last}"),
        _I(PipelineOp.FREE, buffer="state"),
        _I(PipelineOp.SEND, buffer="state_out", tag="pipe:policy:state"),
        _I(PipelineOp.SEND, buffer="metrics", tag="pipe:policy:metrics"),
        _I(PipelineOp.FREE, buffer="state_out"),
        _I(PipelineOp.FREE, buffer="metrics"),
    ]

    wm: List[Instruction] = []
    for m in range(wm_micro):
        wm += [
            _I(PipelineOp.RECV, buffer=f"wmb{m}", tag=f"host:wm:micro{m}"),
            _I(PipelineOp.RUN, stage="wm_update", micro=m,
               inputs=(f"wmb{m}",), outputs=(f"wmo{m}",)),
            _I(PipelineOp.FREE, buffer=f"wmb{m}"),
        ]
        if m < wm_micro - 1:
            wm.append(_I(PipelineOp.FREE, buffer=f"wmo{m}"))
    if wm_micro:
        wm += [
            _I(PipelineOp.SEND, buffer=f"wmo{wm_micro - 1}",
               tag="pipe:wm:out"),
            _I(PipelineOp.FREE, buffer=f"wmo{wm_micro - 1}"),
        ]
    return {"policy": tuple(pol), "wm": tuple(wm)}


def validate_schedules(schedules: Dict[str, Tuple[Instruction, ...]], *,
                       feeds: Sequence[str], collects: Sequence[str]
                       ) -> Dict[str, Dict]:
    """Abstractly interpret the schedules; raise on any unsound program.

    Checks, per stream: RUN/SEND/FREE only touch live buffers, no buffer
    is redefined while live, everything is FREEd by the end. Globally:
    every RECV tag is fed exactly once (by the host or a peer SEND) and
    every SEND is consumed (host collect or peer RECV). Returns per-stream
    stats including the micro-grad high-water mark (the 1F1B bound).
    """
    sends: Dict[str, str] = {}
    recvs: Dict[str, str] = {}
    stats: Dict[str, Dict] = {}
    for name, sched in schedules.items():
        live: set = set()
        peak_grads = grads_live = 0
        for ins in sched:
            if ins.op == PipelineOp.RECV:
                if ins.tag in recvs:
                    raise ValueError(f"[{name}] duplicate RECV {ins.tag}")
                recvs[ins.tag] = name
                if ins.buffer in live:
                    raise ValueError(
                        f"[{name}] RECV redefines live {ins.buffer!r}")
                live.add(ins.buffer)
            elif ins.op == PipelineOp.RUN:
                dead = [b for b in ins.inputs if b not in live]
                if dead:
                    raise ValueError(
                        f"[{name}] {ins!r} reads dead buffers {dead}")
                clash = [b for b in ins.outputs if b in live]
                if clash:
                    raise ValueError(
                        f"[{name}] {ins!r} redefines live {clash}")
                live.update(ins.outputs)
                grads_live += sum(
                    1 for b in ins.outputs
                    if b.startswith("g") and b[1:].isdigit())
                peak_grads = max(peak_grads, grads_live)
            elif ins.op == PipelineOp.SEND:
                if ins.buffer not in live:
                    raise ValueError(
                        f"[{name}] SEND of dead buffer {ins.buffer!r}")
                if ins.tag in sends:
                    raise ValueError(f"[{name}] duplicate SEND {ins.tag}")
                sends[ins.tag] = name
            elif ins.op == PipelineOp.FREE:
                if ins.buffer not in live:
                    raise ValueError(
                        f"[{name}] FREE of dead buffer {ins.buffer!r}")
                live.discard(ins.buffer)
                if ins.buffer.startswith("g") and ins.buffer[1:].isdigit():
                    grads_live -= 1
        if live:
            raise ValueError(f"[{name}] leaks buffers {sorted(live)}")
        stats[name] = {"instructions": len(sched),
                       "peak_micro_grads": peak_grads}

    for tag, stream in recvs.items():
        if tag not in feeds and sends.get(tag, stream) == stream:
            raise ValueError(f"RECV {tag} in [{stream}] never fed")
    for tag, stream in sends.items():
        if tag not in collects and recvs.get(tag, stream) == stream:
            raise ValueError(f"SEND {tag} from [{stream}] never consumed")
    return stats


# --------------------------------------------------------------------------
# executor
# --------------------------------------------------------------------------

def host_microbatches(batch, n_micro: int) -> List:
    """Contiguous micro-batch slices (App. C.1) as host-side views —
    matches ``core.train_step._microbatches`` exactly."""
    import jax
    b = batch.obs_tokens.shape[0]
    # floor like the fused scan does (a non-divisible tail is dropped)
    mb = b // n_micro
    if mb == 0:
        raise ValueError(f"batch of {b} too small for {n_micro} "
                         f"micro-batches")
    out = []
    for i in range(n_micro):
        sl = lambda x: None if x is None else x[i * mb:(i + 1) * mb]
        out.append(jax.tree.map(sl, batch, is_leaf=lambda v: v is None))
    return out


class _Mailbox:
    """Tagged single-consumer rendezvous between host and streams."""

    def __init__(self):
        self._cv = threading.Condition()
        self._slots: Dict[str, object] = {}

    def put(self, tag: str, value) -> None:
        with self._cv:
            if tag in self._slots:
                raise RuntimeError(f"mailbox tag {tag!r} already occupied")
            self._slots[tag] = value
            self._cv.notify_all()

    def take(self, tag: str, timeout: float = 120.0):
        deadline = time.monotonic() + timeout
        with self._cv:
            while tag not in self._slots:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise TimeoutError(f"RECV {tag!r} timed out")
                self._cv.wait(left)
            return self._slots.pop(tag)


def _tree_nbytes(value) -> int:
    import jax
    total = 0
    for leaf in jax.tree.leaves(value):
        total += getattr(leaf, "nbytes", 0)
    return total


class _Stream:
    """One submesh's persistent worker thread executing its schedule."""

    def __init__(self, name: str, submesh: Submesh, mailbox: _Mailbox,
                 run_fns: Dict[str, Callable], *, place: bool):
        self.name = name
        self.submesh = submesh
        self.mailbox = mailbox
        self.run_fns = run_fns
        self.place = place                       # device_put RECVs onto
                                                 # the submesh (disjoint
                                                 # layouts only)
        self.busy_s = 0.0
        self.peak_live_bytes = 0
        self.peak_grad_bytes = 0
        self._schedule: Tuple[Instruction, ...] = ()
        self._go = threading.Event()
        self._done = threading.Event()
        self._error: Optional[BaseException] = None
        self._shutdown = False
        self._thread = threading.Thread(
            target=self._loop, name=f"pipeline-{name}", daemon=True)
        self._thread.start()

    def submit(self, schedule: Tuple[Instruction, ...]) -> None:
        self._schedule = schedule
        self._error = None
        self._done.clear()
        self._go.set()

    def wait(self, timeout: float = 300.0) -> None:
        if not self._done.wait(timeout):
            raise TimeoutError(f"pipeline stream {self.name!r} wedged")
        if self._error is not None:
            raise self._error

    def close(self) -> None:
        self._shutdown = True
        self._go.set()
        self._thread.join(timeout=10.0)

    # -- instruction interpreter ------------------------------------------------
    def _loop(self) -> None:
        while True:
            self._go.wait()
            self._go.clear()
            if self._shutdown:
                return
            try:
                self._execute(self._schedule)
            except BaseException as e:  # surfaced by wait()
                self._error = e
            self._done.set()

    def _execute(self, schedule: Tuple[Instruction, ...]) -> None:
        import jax
        bufs: Dict[str, object] = {}
        live_bytes = grad_bytes = 0
        sizes: Dict[str, int] = {}
        self.busy_s = 0.0
        for ins in schedule:
            if ins.op == PipelineOp.RECV:
                value = self.mailbox.take(ins.tag)
                if self.place:
                    # cross-mesh reshard: commit the buffer to this
                    # submesh so RUNs execute here, not where the
                    # producer left it
                    value = jax.device_put(value, self.submesh.device)
                bufs[ins.buffer] = value
            elif ins.op == PipelineOp.RUN:
                fn = self.run_fns[ins.stage]
                args = tuple(bufs[b] for b in ins.inputs)
                t0 = time.perf_counter()
                if _tel is not None:
                    with _tel.span("train.stage", cat="train",
                                   args={"stage": ins.stage,
                                         "submesh": self.name,
                                         "micro": ins.micro}):
                        out = fn(*args)
                        out = jax.block_until_ready(out)
                else:
                    out = fn(*args)
                    out = jax.block_until_ready(out)
                self.busy_s += time.perf_counter() - t0
                if len(ins.outputs) == 1:
                    out = (out,)
                for b, v in zip(ins.outputs, out):
                    bufs[b] = v
                    sizes[b] = _tree_nbytes(v)
                    live_bytes += sizes[b]
                    if b.startswith("g") and b[1:].isdigit():
                        grad_bytes += sizes[b]
                self.peak_live_bytes = max(self.peak_live_bytes, live_bytes)
                self.peak_grad_bytes = max(self.peak_grad_bytes, grad_bytes)
            elif ins.op == PipelineOp.SEND:
                self.mailbox.put(ins.tag, bufs[ins.buffer])
            elif ins.op == PipelineOp.FREE:
                bufs.pop(ins.buffer)
                freed = sizes.pop(ins.buffer, 0)
                live_bytes -= freed
                if ins.buffer.startswith("g") and ins.buffer[1:].isdigit():
                    grad_bytes -= freed


class PipelineExecutor:
    """Drives the static schedules over a :class:`SubmeshLayout`.

    ``run_round`` executes one training round: the policy stream consumes
    ``n_micro`` micro-batches and produces the updated TrainState; the WM
    stream (when a stage is attached via :meth:`set_wm_stage`) trains the
    world model on its own submesh concurrently. Per-round bubble
    fraction = 1 − busy/wall per stream, fed to the
    ``pipeline_bubble_frac`` histogram.
    """

    FEEDS = ("host:policy:state", "host:policy:micro{m}",
             "host:wm:micro{m}")
    COLLECTS = ("pipe:policy:state", "pipe:policy:metrics", "pipe:wm:out")

    def __init__(self, program: StepProgram, layout: SubmeshLayout, *,
                 n_micro: int = 0, metrics=None):
        import jax
        self.program = program
        self.layout = layout
        self.n_micro = n_micro or program.n_micro
        self.metrics = metrics
        self._wm_stage: Optional[Callable] = None
        self._wm_feed: Optional[Callable] = None
        self.wm_micro = 0
        self.last_bubble: Dict[str, float] = {}
        self.rounds = 0

        self._mailbox = _Mailbox()
        # single-device submesh: commit RECVd buffers to that device so
        # RUNs land there. Multi-device policy submeshes keep the state's
        # own (ZeRO-sharded) placement — a device_put to one device would
        # silently gather it.
        place = layout.disjoint and len(layout.policy.devices) == 1
        pol_fns = {
            "fwd_bwd": jax.jit(program.stage("fwd_bwd").fn),
            "grad_reduce/init": jax.jit(program.stage("grad_reduce").init),
            "grad_reduce": jax.jit(program.stage("grad_reduce").fn),
            "optim_update": jax.jit(program.stage("optim_update").fn),
        }
        self._policy = _Stream("policy", layout.policy, self._mailbox,
                               pol_fns, place=place)
        self._wm = _Stream("wm", layout.wm, self._mailbox,
                           {}, place=False)
        self._closed = False

    # -- WM stage attachment -----------------------------------------------------
    def set_wm_stage(self, stage_fn: Callable, feed_fn: Callable, *,
                     wm_micro: int = 1) -> None:
        """Attach the world-model stage: ``stage_fn(batch)`` runs one WM
        train cycle (host callable owning its own state, pinned to the WM
        submesh); ``feed_fn()`` returns the next WM batch or None."""
        import jax
        submesh = self.layout.wm

        def run(batch):
            with jax.default_device(submesh.device):
                return stage_fn(batch)

        self._wm.run_fns = {"wm_update": run}
        self._wm_stage = stage_fn
        self._wm_feed = feed_fn
        self.wm_micro = wm_micro

    # -- one round ---------------------------------------------------------------
    def run_round(self, state, batch):
        """One optimizer step through the pipeline. Returns
        ``(new_state, metrics_dict, wm_out)``."""
        if self._closed:
            raise RuntimeError("executor is closed")
        wm_batches = []
        if self._wm_feed is not None:
            for _ in range(self.wm_micro):
                b = self._wm_feed()
                if b is None:
                    break
                wm_batches.append(b)
        schedules = build_train_schedules(self.n_micro, len(wm_batches))

        self._mailbox.put("host:policy:state", state)
        for m, mb in enumerate(host_microbatches(batch, self.n_micro)):
            self._mailbox.put(f"host:policy:micro{m}", mb)
        for m, wb in enumerate(wm_batches):
            self._mailbox.put(f"host:wm:micro{m}", wb)

        t0 = time.perf_counter()
        self._policy.submit(schedules["policy"])
        self._wm.submit(schedules["wm"])
        self._policy.wait()
        self._wm.wait()
        wall = max(time.perf_counter() - t0, 1e-9)

        new_state = self._mailbox.take("pipe:policy:state", timeout=1.0)
        metrics = self._mailbox.take("pipe:policy:metrics", timeout=1.0)
        wm_out = (self._mailbox.take("pipe:wm:out", timeout=1.0)
                  if wm_batches else None)

        self.rounds += 1
        self.last_bubble = {
            s.name: max(0.0, 1.0 - s.busy_s / wall)
            for s in (self._policy, self._wm)
            if s is self._policy or wm_batches
        }
        if self.metrics is not None:
            for frac in self.last_bubble.values():
                self.metrics.observe("pipeline_bubble_frac", frac)
        if _tel is not None:
            _tel.instant("pipeline.round", cat="train",
                         args={"round": self.rounds, "wall_s": wall,
                               **{f"bubble_{k}": v
                                  for k, v in self.last_bubble.items()}})
        return new_state, metrics, wm_out

    @property
    def peak_grad_bytes(self) -> int:
        return self._policy.peak_grad_bytes

    @property
    def peak_live_bytes(self) -> Dict[str, int]:
        return {"policy": self._policy.peak_live_bytes,
                "wm": self._wm.peak_live_bytes}

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._policy.close()
            self._wm.close()
