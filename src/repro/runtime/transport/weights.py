"""WeightStoreTransport: the VersionedWeightStore contract over the wire.

Remote workers pull fresh policy weights by version (the LlamaRL-style
distributed broadcast, pull-flavored): this proxy exposes the exact
surface :class:`~repro.runtime.weight_store.VersionedWeightStore` gives
the in-process inference pool —

  * ``acquire(newer_than, timeout)`` — newest ``(params, version)``,
    blocking until something newer exists (long-polled in bounded slices
    so ``close()`` always unblocks it);
  * ``draining`` / ``version()`` — the drain-protocol poll (App. D.6),
    cached for ``state_ttl`` seconds so a hot inference loop does not
    turn every iteration into an RPC;
  * ``begin_publish()`` / ``publish(params, version)`` — the trainer side,
    so a trainer could live across the wire too (transport parity with
    the in-process store is what the tests pin down).

An :class:`~repro.runtime.inference.InferenceService` constructed with
this object instead of the local store is a *remote* inference worker —
no code change on its side, which is the whole point of the seam.
"""
from __future__ import annotations

import contextlib
import os
import time
from typing import Any, Optional, Tuple

from repro.runtime.transport.channel import (ChannelClosed, WireClient,
                                             long_poll)
from repro.runtime.transport.codec import decode_pytree, encode_pytree

# Import-gated tracing (see transport.faults for the idiom).
if os.environ.get("REPRO_TRACE"):
    from repro.runtime import telemetry as _tel
else:  # pragma: no cover - default path
    _tel = None

__all__ = ["WeightStoreTransport"]

_NULL_CTX = contextlib.nullcontext()


class WeightStoreTransport:
    """Client-side remote weight store (publish/acquire over the wire)."""

    def __init__(self, address: Tuple[str, int], *, use_shm: bool = False,
                 connect_timeout: float = 20.0,
                 shm_threshold: int = 1 << 16, state_ttl: float = 0.05,
                 reconnect_attempts: int = 0,
                 reconnect_backoff_s: float = 0.1,
                 use_lane: bool = False):
        self._client = WireClient(address, connect_timeout=connect_timeout,
                                  shm_threshold=shm_threshold,
                                  reconnect_attempts=reconnect_attempts,
                                  reconnect_backoff_s=reconnect_backoff_s,
                                  on_reconnect=self._on_reconnect)
        self._use_shm = use_shm
        # broadcast lane (same-host only): acquire replies may carry the
        # blob's position in the server's persistent lane ring instead of
        # a body; this reader attaches the lane ONCE and copies blobs out
        # positionally — no per-acquire segment churn
        self._use_lane = bool(use_lane)
        self._lane = None                          # attached lane ring
        self.lane_hits = 0
        self.lane_fallbacks = 0
        self._state_ttl = state_ttl
        self._state = (-float("inf"), -1, False)   # (stamp, version, drain)

    def _on_reconnect(self) -> None:
        """A server-side drop may have hidden publishes: bust the cached
        (version, draining) so the next poll re-acquires the true newest
        version instead of serving the pre-drop state for a TTL. A
        replacement server also means a fresh lane ring, so drop the
        stale attachment (re-attached lazily by name)."""
        self._state = (-float("inf"), -1, False)
        lane, self._lane = self._lane, None
        if lane is not None:
            lane.close()

    # -- broadcast lane (positional reads) ------------------------------------
    def _lane_read(self, resp: dict) -> Optional[bytes]:
        """Copy the blob out of the server's lane ring at the advertised
        position; None on any failure (stale attachment, torn read under
        a concurrent newer publish) — the caller falls back to an
        in-band re-acquire."""
        from repro.runtime.transport.ring import RingError, ShmRing
        name = resp["lane"]
        try:
            if self._lane is None or self._lane.name != name:
                if self._lane is not None:
                    self._lane.close()
                    self._lane = None
                self._lane = ShmRing.attach(name)
            return self._lane.read_at(int(resp["lane_pos"]),
                                      int(resp["lane_seq"]),
                                      int(resp["lane_nbytes"]))
        except (RingError, OSError, ValueError):
            return None

    # -- state poll (cached) --------------------------------------------------
    def _fresh_state(self) -> Tuple[int, bool]:
        stamp, version, draining = self._state
        if time.monotonic() - stamp < self._state_ttl:
            return version, draining
        try:
            resp, _ = self._client.request({"m": "store.state"})
        except ChannelClosed:
            # shutdown is a data-plane no-op here too: keep serving the
            # last known state (acquire/put already degrade the same way);
            # the worker's control loop is what notices the parent is gone
            return version, False
        version, draining = int(resp["version"]), bool(resp["draining"])
        self._state = (time.monotonic(), version, draining)
        return version, draining

    @property
    def draining(self) -> bool:
        return self._fresh_state()[1]

    def version(self) -> int:
        return self._fresh_state()[0]

    # -- inference side -------------------------------------------------------
    def acquire(self, newer_than: int = -1,
                timeout: Optional[float] = None
                ) -> Optional[Tuple[Any, int]]:
        """Newest (params, version) with version > ``newer_than``."""
        got = long_poll(
            self._client,
            lambda t: {"m": "store.acquire", "newer_than": newer_than,
                       "timeout": t, "want_shm": self._use_shm,
                       "want_lane": self._use_lane},
            timeout)
        if got is None:
            return None
        resp, body = got
        version = int(resp["version"])
        if resp.get("lane"):
            body = self._lane_read(resp)
            if body is not None:
                self.lane_hits += 1
            else:
                # torn or stale lane read: one in-band re-acquire (the
                # version exists, so newer_than = version - 1 succeeds
                # immediately with this version or a newer one)
                self.lane_fallbacks += 1
                try:
                    resp, body = self._client.request(
                        {"m": "store.acquire", "newer_than": version - 1,
                         "timeout": 5.0, "want_shm": self._use_shm})
                except ChannelClosed:
                    return None
                if not resp.get("ok"):
                    return None
                version = int(resp["version"])
        if _tel is not None:
            # wire leg of the policy-lag flow (version is the flow id):
            # a remote pool's fetch shows up on the publish timeline
            _tel.instant("weights.wire_acquire", cat="weights",
                         trace=version,
                         args={"version": version,
                               "bytes": len(body) if body else 0},
                         flow="step")
        return decode_pytree(body), version

    # -- trainer side ---------------------------------------------------------
    def begin_publish(self) -> None:
        self._client.request({"m": "store.drain"})
        self._state = (-float("inf"), *self._state[1:])   # bust the cache

    def publish(self, params: Any, version: int) -> None:
        blob = encode_pytree(params)
        with (_tel.span("weights.wire_publish", cat="weights",
                        trace=int(version),
                        args={"version": int(version),
                              "bytes": len(blob)}, flow="start")
              if _tel is not None else _NULL_CTX):
            self._client.request({"m": "store.publish", "version": version},
                                 blob, oob=self._use_shm)
        self._state = (-float("inf"), *self._state[1:])

    # -- lifecycle ------------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._client.closed

    def close(self) -> None:
        self._client.close()
        lane, self._lane = self._lane, None
        if lane is not None:
            lane.close()                 # attachment only — server unlinks
