"""Pytree wire codec: zero-copy-friendly serialization for the transport
layer.

Everything the runtime moves across a process boundary — trajectory
segments (numpy pytrees), policy weights (jnp pytrees, incl. bf16), and
imagined frames — is a nested structure of dict / list / tuple / scalars
over array leaves. The codec flattens that structure into one
self-describing blob:

    MAGIC "ACRL" | u16 wire version | u32 header len | u64 total len
    header JSON  { schema, leaves: [{dtype, shape, offset, nbytes}, ...] }
    leaf buffers, each 64-byte aligned

Design points:

  * **zero-copy decode** — leaf arrays are ``np.frombuffer`` views over
    the received buffer (read-only; pass ``copy=True`` for writable
    arrays). The 64-byte alignment keeps the views SIMD/cacheline
    friendly, so decoded segments can feed ``np.stack`` collation with no
    intermediate copy per leaf.
  * **bf16 and friends** — dtypes are carried by name; ``bfloat16``
    resolves through :mod:`ml_dtypes` (bundled with jax), so policy
    weights round-trip without an f32 detour.
  * **versioned, schema-first header** — a decoder never guesses: wrong
    magic, wire version, or a truncated body is a :class:`CodecError`,
    not silent garbage.

The framing helpers (``send_frame`` / ``recv_frame``) wrap the same
preamble around RPC messages: a small JSON header plus an optional binary
body (itself usually an encoded pytree).
"""
from __future__ import annotations

import json
import socket
import struct
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

MAGIC = b"ACRL"
WIRE_VERSION = 1
ALIGNMENT = 64

# magic, wire version, header length, total/body length
_PREAMBLE = struct.Struct("!4sHIQ")
PREAMBLE_SIZE = _PREAMBLE.size

__all__ = ["CodecError", "encode_pytree", "decode_pytree", "plan_pytree",
           "EncodePlan", "send_frame", "recv_frame", "recv_exact",
           "MAGIC", "WIRE_VERSION", "PREAMBLE_SIZE"]


class CodecError(ValueError):
    """Malformed wire data: bad magic/version, truncation, unknown dtype."""


def _contiguous(x: Any) -> np.ndarray:
    # NOT np.ascontiguousarray — that promotes 0-d arrays/scalars to 1-d,
    # which would break scalar round-trips; 0-d is always contiguous
    arr = np.asarray(x)
    return arr if arr.flags["C_CONTIGUOUS"] else np.ascontiguousarray(arr)


def _dtype_from_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        pass
    try:  # bfloat16 / float8 variants register through ml_dtypes
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))
    except (ImportError, AttributeError) as e:
        raise CodecError(f"cannot resolve wire dtype {name!r}: {e}") from e


def _build_schema(node: Any, leaves: List[np.ndarray],
                  recs: List[Dict]) -> Dict:
    """Recursively replace array leaves with indices into ``leaves``."""
    if node is None:
        return {"t": "none"}
    if isinstance(node, (bool, int, float, str)):
        # bool first — it is an int subclass; JSON carries all of these
        return {"t": "py", "v": node}
    if isinstance(node, np.generic):       # 0-d numpy scalar (np.int32(3))
        arr = _contiguous(node)
        recs.append({"d": arr.dtype.name, "s": list(arr.shape), "g": 1})
        leaves.append(arr)
        return {"t": "arr", "i": len(leaves) - 1}
    if isinstance(node, np.ndarray):
        arr = _contiguous(node)
        recs.append({"d": arr.dtype.name, "s": list(arr.shape)})
        leaves.append(arr)
        return {"t": "arr", "i": len(leaves) - 1}
    if isinstance(node, dict):
        keys = list(node.keys())
        if not all(isinstance(k, str) for k in keys):
            raise CodecError("wire pytrees support str dict keys only")
        return {"t": "dict", "k": keys,
                "c": [_build_schema(node[k], leaves, recs) for k in keys]}
    if isinstance(node, tuple):
        return {"t": "tuple",
                "c": [_build_schema(v, leaves, recs) for v in node]}
    if isinstance(node, list):
        return {"t": "list",
                "c": [_build_schema(v, leaves, recs) for v in node]}
    if hasattr(node, "dtype") and hasattr(node, "shape"):
        # device arrays (jnp) — np.asarray moves them to host, preserving
        # bf16 through the ml_dtypes-backed numpy dtype
        arr = _contiguous(node)
        recs.append({"d": arr.dtype.name, "s": list(arr.shape)})
        leaves.append(arr)
        return {"t": "arr", "i": len(leaves) - 1}
    raise CodecError(f"cannot encode leaf of type {type(node).__name__}")


def _align(n: int) -> int:
    return (n + ALIGNMENT - 1) // ALIGNMENT * ALIGNMENT


class EncodePlan:
    """A sized, ready-to-write encoding of one pytree.

    Splitting serialization into *plan* (size known) and *write* lets a
    caller reserve exactly ``nbytes`` in a preallocated destination — a
    :class:`~repro.runtime.transport.ring.ShmRing` reservation — and
    materialize the blob in place, skipping the intermediate ``bytes``
    copy that ``encode_pytree`` pays on the socket path.
    """

    __slots__ = ("nbytes", "_header", "_recs", "_leaves", "_data_start")

    def __init__(self, header: bytes, recs: List[Dict],
                 leaves: List[np.ndarray]):
        self._header = header
        self._recs = recs
        self._leaves = leaves
        self._data_start = _align(PREAMBLE_SIZE + len(header))
        data = _align(recs[-1]["o"] + recs[-1]["n"]) if recs else 0
        self.nbytes = self._data_start + data

    def write_into(self, out, offset: int = 0) -> int:
        """Write the full blob at ``out[offset:]``; returns ``nbytes``."""
        view = memoryview(out)
        _PREAMBLE.pack_into(view, offset, MAGIC, WIRE_VERSION,
                            len(self._header), self.nbytes)
        h0 = offset + PREAMBLE_SIZE
        view[h0:h0 + len(self._header)] = self._header
        base = offset + self._data_start
        for rec, arr in zip(self._recs, self._leaves):
            if rec["n"]:
                start = base + rec["o"]
                try:
                    # ONE memcpy leaf → destination; planned leaves are
                    # C-contiguous, so the cast is free
                    src = memoryview(arr).cast("B")
                except (TypeError, ValueError, BufferError):
                    # extension dtypes (bf16 et al.) may not export a
                    # PEP 3118 buffer — fall back to the tobytes copy
                    src = arr.tobytes()
                view[start:start + rec["n"]] = src
        return self.nbytes


def plan_pytree(tree: Any) -> EncodePlan:
    """Stage one of :func:`encode_pytree`: flatten + size, no data copy."""
    leaves: List[np.ndarray] = []
    recs: List[Dict] = []
    schema = _build_schema(tree, leaves, recs)
    offset = 0
    for rec, arr in zip(recs, leaves):
        rec["o"] = offset
        rec["n"] = arr.nbytes
        offset = _align(offset + arr.nbytes)
    header = json.dumps({"schema": schema, "leaves": recs},
                        separators=(",", ":")).encode()
    return EncodePlan(header, recs, leaves)


def encode_pytree(tree: Any) -> bytes:
    """Serialize a pytree into one self-describing, aligned blob.

    Leaf offsets are relative to the data section (which starts at the
    first alignment boundary after the header), so the header never
    depends on its own serialized length.
    """
    plan = plan_pytree(tree)
    buf = bytearray(plan.nbytes)
    plan.write_into(buf)
    return bytes(buf)


def _rebuild(schema: Dict, arrays: List[Any]) -> Any:
    t = schema["t"]
    if t == "none":
        return None
    if t == "py":
        return schema["v"]
    if t == "arr":
        return arrays[schema["i"]]
    if t == "dict":
        return {k: _rebuild(c, arrays)
                for k, c in zip(schema["k"], schema["c"])}
    if t == "tuple":
        return tuple(_rebuild(c, arrays) for c in schema["c"])
    if t == "list":
        return [_rebuild(c, arrays) for c in schema["c"]]
    raise CodecError(f"unknown schema node type {t!r}")


def decode_pytree(buf: Union[bytes, bytearray, memoryview], *,
                  copy: bool = False) -> Any:
    """Decode a blob produced by :func:`encode_pytree`.

    With ``copy=False`` (default) array leaves are read-only views into
    ``buf`` — zero-copy; the views keep ``buf`` alive. ``copy=True``
    returns independent writable arrays.
    """
    view = memoryview(buf)
    if len(view) < PREAMBLE_SIZE:
        raise CodecError(f"blob shorter than preamble ({len(view)} bytes)")
    magic, version, hlen, total = _PREAMBLE.unpack_from(view, 0)
    if magic != MAGIC:
        raise CodecError(f"bad magic {magic!r} (expected {MAGIC!r})")
    if version != WIRE_VERSION:
        raise CodecError(f"wire version {version} unsupported "
                         f"(speak {WIRE_VERSION})")
    if len(view) < total:
        raise CodecError(f"truncated blob: {len(view)} < {total} bytes")
    header = json.loads(bytes(view[PREAMBLE_SIZE:PREAMBLE_SIZE + hlen]))
    data_start = _align(PREAMBLE_SIZE + hlen)
    arrays: List[Any] = []
    for rec in header["leaves"]:
        dt = _dtype_from_name(rec["d"])
        start = data_start + rec["o"]
        raw = view[start:start + rec["n"]]
        arr = np.frombuffer(raw, dtype=dt).reshape(rec["s"])
        if copy:
            arr = arr.copy()
        if rec.get("g"):                   # round-trip 0-d numpy scalars
            arr = arr[()]
        arrays.append(arr)
    return _rebuild(header["schema"], arrays)


# ---------------------------------------------------------------------------
# message framing (RPC envelope: JSON header + optional binary body)
# ---------------------------------------------------------------------------

#: bodies up to this size are coalesced into the preamble+header sendall
#: — one syscall (and one thread wake on the receiver) per frame instead
#: of two; bigger bodies go separately to avoid the concat copy
_SEND_COALESCE_MAX = 1 << 18


def recv_exact(stream, n: int) -> Optional[bytearray]:
    """Read exactly ``n`` bytes; None on clean EOF before any byte.

    ``stream`` is a socket OR any buffered reader with ``readinto``
    (e.g. ``sock.makefile("rb")``) — a streaming consumer reads many
    small frames per syscall through the buffer, which is most of the
    pipelined put path's win on the ack stream.
    """
    buf = bytearray(n)
    view = memoryview(buf)
    reader = getattr(stream, "recv_into", None) or stream.readinto
    got = 0
    while got < n:
        k = reader(view[got:])
        if not k:
            if got == 0:
                return None
            raise CodecError(f"connection closed mid-frame "
                             f"({got}/{n} bytes)")
        got += k
    return buf


def frame_bytes(header: Dict, body: Union[bytes, memoryview] = b"") -> bytes:
    """One framed message as bytes — for senders that coalesce several
    frames into a single ``sendall`` (the pipelined put stream)."""
    hj = json.dumps(header, separators=(",", ":")).encode()
    pre = _PREAMBLE.pack(MAGIC, WIRE_VERSION, len(hj), len(body))
    return pre + hj + bytes(body)


def send_frame(sock: socket.socket, header: Dict,
               body: Union[bytes, memoryview] = b"") -> int:
    """Write one framed message; returns bytes sent."""
    hj = json.dumps(header, separators=(",", ":")).encode()
    pre = _PREAMBLE.pack(MAGIC, WIRE_VERSION, len(hj), len(body))
    if 0 < len(body) <= _SEND_COALESCE_MAX:
        if not isinstance(body, bytes):
            body = bytes(body)
        sock.sendall(pre + hj + body)
    else:
        sock.sendall(pre + hj)
        if len(body):
            sock.sendall(body)
    return len(pre) + len(hj) + len(body)


def recv_frame(stream) -> Optional[Tuple[Dict, bytes]]:
    """Read one framed message (socket or buffered reader — see
    :func:`recv_exact`); None when the peer closed cleanly."""
    pre = recv_exact(stream, PREAMBLE_SIZE)
    if pre is None:
        return None
    magic, version, hlen, blen = _PREAMBLE.unpack_from(pre, 0)
    if magic != MAGIC:
        raise CodecError(f"bad frame magic {magic!r}")
    if version != WIRE_VERSION:
        raise CodecError(f"frame wire version {version} unsupported")
    hdr = recv_exact(stream, hlen)
    if hdr is None:
        raise CodecError("connection closed before frame header")
    header = json.loads(bytes(hdr))
    body = b""
    if blen:
        got = recv_exact(stream, blen)
        if got is None:
            raise CodecError("connection closed before frame body")
        body = bytes(got)
    return header, body
