"""Cross-process transport subsystem (paper §3 "physical isolation").

PR 2 left ``pop_batch`` / ``publish``/``acquire`` as the seam for
crossing a process boundary; this package is the crossing:

  * :mod:`codec`   — versioned, zero-copy-friendly pytree wire format;
  * :mod:`channel` — :class:`SocketChannel` / :class:`ShmChannel` /
    :class:`ShmRingChannel`, the ExperienceChannel contract (incl.
    backpressure verdicts, batched ``put_many``, coalesced ``pop_many``)
    over the wire, on a reconnecting :class:`WireClient`, plus
    :class:`PutStream`, the pipelined windowed-ack put path;
  * :mod:`ring`    — :class:`ShmRing`, the persistent SPSC shared-memory
    ring replacing per-message segments on the highest-rate channels;
  * :mod:`server`  — :class:`TransportServer`, the parent-side endpoint
    (a Service on the bus) hosting channels + the weight store + the
    ``worker.hello`` token handshake;
  * :mod:`weights` — :class:`WeightStoreTransport`, remote
    publish/acquire with the drain protocol;
  * :mod:`remote`  — ``worker_main`` + :class:`RemoteWorkerSpec`, the
    worker process body (one body, two lifecycles);
  * :mod:`supervision` — :class:`Supervisor` / :class:`SupervisedWorker`
    / :class:`RestartPolicy` / :class:`ElasticPolicy` and the
    Spawned/Connected endpoints: worker lifecycle decoupled from
    transport, with restart budgets and elastic autoscaling;
  * :mod:`resilience` — :class:`TransportJournal` /
    :class:`JournaledChannel` / :func:`recover`: write-ahead journal +
    compacting snapshots for the server's hosted state, so a replacement
    server (``--resume-journal``) survives a SIGKILL with exactly-once
    stream replay; plus the stale-SHM sweep;
  * :mod:`inference_plane` — :class:`InferenceBroker` /
    :class:`RemoteInferenceClient` / :class:`InferencePlaneService`: the
    disaggregated inference tier — many rollout workers sharing one
    continuously-batched pool behind seq-numbered ``infer.*`` streams
    with reconnect replay and exactly-once result delivery;
  * :mod:`faults`  — :class:`FaultPlan`, env-gated deterministic fault
    injection (never imported unless ``REPRO_FAULTS`` is set).
"""
from repro.runtime.transport.codec import (  # noqa: F401
    CodecError,
    decode_pytree,
    encode_pytree,
)
from repro.runtime.transport.channel import (  # noqa: F401
    ChannelClosed,
    PutStream,
    ShmChannel,
    ShmRingChannel,
    SocketChannel,
    TransportError,
    WireClient,
)
from repro.runtime.transport.ring import RingError, ShmRing  # noqa: F401
from repro.runtime.transport.inference_plane import (  # noqa: F401
    InferenceBroker,
    InferencePlaneService,
    RemoteInferenceClient,
)
from repro.runtime.transport.server import TransportServer  # noqa: F401
from repro.runtime.transport.weights import WeightStoreTransport  # noqa: F401
from repro.runtime.transport.remote import (  # noqa: F401
    RemoteWorkerSpec,
    spec_from_wire,
    spec_to_wire,
    worker_main,
)
from repro.runtime.transport.resilience import (  # noqa: F401
    JournaledChannel,
    RecoveredState,
    TransportJournal,
    recover,
    sweep_stale_shm,
)
from repro.runtime.transport.supervision import (  # noqa: F401
    ConnectedEndpoint,
    ElasticPolicy,
    RestartPolicy,
    SpawnedEndpoint,
    SupervisedWorker,
    Supervisor,
    WorkerEndpoint,
)
