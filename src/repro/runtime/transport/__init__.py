"""Cross-process transport subsystem (paper §3 "physical isolation").

PR 2 left ``pop_batch`` / ``publish``/``acquire`` as the seam for
crossing a process boundary; this package is the crossing:

  * :mod:`codec`   — versioned, zero-copy-friendly pytree wire format;
  * :mod:`channel` — :class:`SocketChannel` / :class:`ShmChannel`, the
    ExperienceChannel contract (incl. backpressure verdicts) over the wire;
  * :mod:`server`  — :class:`TransportServer`, the parent-side endpoint
    (a Service on the bus) hosting channels + the weight store;
  * :mod:`weights` — :class:`WeightStoreTransport`, remote
    publish/acquire with the drain protocol;
  * :mod:`remote`  — :class:`RemoteRolloutHost` / ``worker_main``, the
    spawned worker process pair with metrics/health bridging and crash
    containment.
"""
from repro.runtime.transport.codec import (  # noqa: F401
    CodecError,
    decode_pytree,
    encode_pytree,
)
from repro.runtime.transport.channel import (  # noqa: F401
    ChannelClosed,
    ShmChannel,
    SocketChannel,
    TransportError,
    WireClient,
)
from repro.runtime.transport.server import TransportServer  # noqa: F401
from repro.runtime.transport.weights import WeightStoreTransport  # noqa: F401
from repro.runtime.transport.remote import (  # noqa: F401
    RemoteRolloutHost,
    RemoteServiceHost,
    RemoteWorkerSpec,
    worker_main,
)
