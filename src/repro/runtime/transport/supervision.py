"""Supervision layer: worker LIFECYCLE decoupled from worker TRANSPORT.

PR 3's ``RemoteRolloutHost`` conflated two orthogonal questions — *how a
worker comes to exist* and *how it is supervised* — into one Service with
a bespoke monitor thread, which locked the system into exactly one
lifecycle (parent-spawned child whose death fails the run). This module
splits them:

  * :class:`WorkerEndpoint` answers the first question for ONE incarnation
    of a worker. :class:`SpawnedEndpoint` is the PR 3 lifecycle (a
    ``spawn``-start-method child process; liveness = the process object);
    :class:`ConnectedEndpoint` is the multi-host lifecycle (a worker
    started elsewhere — ``python -m repro.launch.worker`` — dials the
    :class:`~repro.runtime.transport.server.TransportServer`, authenticates
    with the shared token, and receives its spec; liveness = the heartbeat
    report stream).

  * :class:`Supervisor` answers the second. It is ONE service owning N
    :class:`SupervisedWorker` slots; its thread runs the shared state
    machine (launch → up → failure → backoff → relaunch | FAILED) under a
    declarative :class:`RestartPolicy`. ``never`` reproduces PR 3 exactly
    (any failure marks the slot FAILED and schedulers fail fast);
    ``on_failure`` respawns (spawn mode) or re-opens the slot for a redial
    (connect mode) with exponential backoff, up to ``max_restarts`` within
    a sliding ``window_s`` — exhausting the budget surfaces FAILED with
    the same fail-fast behavior.

Each relaunch/re-accept begins a new *incarnation*: the slot's bridged
:class:`~repro.runtime.service.MetricsRegistry` folds the dead
incarnation's counters into a monotone base (``begin_remote_incarnation``)
so ``metrics()["services"]`` keeps ONE coherent, monotonically-counting
entry per worker across restarts, and stale-incarnation reports are
dropped (and answered with ``stop``) rather than corrupting the bridge.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import threading
import time
from typing import Dict, List, Optional

from repro.runtime.service import Service, ServiceState
from repro.runtime.transport.remote import (RemoteWorkerSpec, _child_entry,
                                            spec_to_wire)

__all__ = ["RestartPolicy", "WorkerEndpoint", "SpawnedEndpoint",
           "ConnectedEndpoint", "SupervisedWorker", "Supervisor"]

RESTART_MODES = ("never", "on_failure")


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """Declarative restart semantics for a supervised worker slot.

    ``never`` — any failure is terminal (PR 3 parity). ``on_failure`` —
    up to ``max_restarts`` relaunches within a sliding ``window_s``;
    restarts outside the window stop counting against the budget, so a
    long-lived worker that crashes once a day never exhausts it. Backoff
    before the k-th restart in the window is
    ``backoff_initial_s * backoff_factor**(k-1)`` capped at
    ``backoff_max_s``."""

    mode: str = "never"
    max_restarts: int = 2
    window_s: float = 60.0
    backoff_initial_s: float = 0.1
    backoff_factor: float = 2.0
    backoff_max_s: float = 2.0

    def __post_init__(self):
        if self.mode not in RESTART_MODES:
            raise ValueError(f"restart mode {self.mode!r} not in "
                             f"{RESTART_MODES}")

    def backoff_s(self, restarts_in_window: int) -> float:
        return min(self.backoff_initial_s
                   * self.backoff_factor ** max(restarts_in_window - 1, 0),
                   self.backoff_max_s)


# ---------------------------------------------------------------------------
# endpoints: how one incarnation of a worker comes to exist
# ---------------------------------------------------------------------------

class WorkerEndpoint:
    """One incarnation's existence + liveness. Stateless about policy —
    restarts, budgets, and backoff belong to the :class:`Supervisor`."""

    mode = "abstract"

    def launch(self, spec: RemoteWorkerSpec) -> None:
        """Begin an incarnation (spawn a child / open the slot for a
        dial-in)."""
        raise NotImplementedError

    def failure(self) -> Optional[str]:
        """Why the current incarnation is dead, or None while it lives
        (a connect slot still waiting inside its attach window is alive)."""
        raise NotImplementedError

    def note_report(self) -> None:
        """A heartbeat report from the current incarnation arrived."""

    def shutdown(self, timeout: float = 5.0) -> None:
        """Reap the incarnation if this side owns it (terminate → kill for
        a spawned child; nothing to do for a dialed-in peer — the stop
        flag in its report replies is the only lever)."""


class SpawnedEndpoint(WorkerEndpoint):
    """PR 3's lifecycle: the worker is a child process of this host."""

    mode = "spawn"

    def __init__(self):
        self.process: Optional[multiprocessing.process.BaseProcess] = None

    def launch(self, spec: RemoteWorkerSpec) -> None:
        ctx = multiprocessing.get_context("spawn")
        self.process = ctx.Process(target=_child_entry, args=(spec,),
                                   name=spec.name, daemon=True)
        self.process.start()

    def failure(self) -> Optional[str]:
        if self.process is None:
            return "never launched"
        if self.process.is_alive():
            return None
        return f"process died (exitcode={self.process.exitcode})"

    def shutdown(self, timeout: float = 5.0) -> None:
        proc = self.process
        if proc is None:
            return
        proc.join(timeout=timeout)
        if proc.is_alive():
            proc.terminate()
            proc.join(timeout=2.0)
        if proc.is_alive():                # pragma: no cover — last resort
            proc.kill()
            proc.join(timeout=2.0)


class ConnectedEndpoint(WorkerEndpoint):
    """Multi-host lifecycle: the worker lives elsewhere and dials in.

    ``launch`` only opens the slot (arms the attach window); the
    :class:`Supervisor`'s hello handler calls :meth:`attach` when a worker
    completes the token handshake. Liveness afterwards is the heartbeat
    stream: a report gap beyond ``liveness_timeout_s`` is this lifecycle's
    equivalent of a dead process (the peer may be SIGKILLed, partitioned,
    or wedged — indistinguishable from here, all handled by re-accepting
    a redial under the restart budget)."""

    mode = "connect"

    def __init__(self, *, liveness_timeout_s: float,
                 attach_timeout_s: float):
        self.liveness_timeout_s = liveness_timeout_s
        self.attach_timeout_s = attach_timeout_s
        self.attached_incarnation: Optional[int] = None
        self.last_report_t: Optional[float] = None
        self._opened_t: Optional[float] = None

    def launch(self, spec: RemoteWorkerSpec) -> None:
        self._opened_t = time.monotonic()
        self.attached_incarnation = None
        self.last_report_t = None

    def attach(self, incarnation: int) -> None:
        self.attached_incarnation = incarnation
        self.last_report_t = time.monotonic()

    def note_report(self) -> None:
        self.last_report_t = time.monotonic()

    def failure(self) -> Optional[str]:
        now = time.monotonic()
        if self.attached_incarnation is None:
            if (self._opened_t is not None
                    and now - self._opened_t > self.attach_timeout_s):
                return (f"no worker dialed in within "
                        f"{self.attach_timeout_s:.1f}s")
            return None                    # still inside the attach window
        if (self.last_report_t is not None
                and now - self.last_report_t > self.liveness_timeout_s):
            return (f"report stream stalled for more than "
                    f"{self.liveness_timeout_s:.1f}s (worker died or "
                    f"partitioned)")
        return None


# ---------------------------------------------------------------------------
# the supervised slot: one bus entry per worker, stable across incarnations
# ---------------------------------------------------------------------------

class SupervisedWorker(Service):
    """Passive Service (no thread of its own): the per-worker entry on the
    bus. It carries the slot's identity (`name`), the bridged metrics
    registry, and the report sink across every incarnation the Supervisor
    runs through its endpoint — so ``metrics()["services"]`` shows a
    single coherent worker entry no matter how many times the underlying
    process was replaced."""

    def __init__(self, spec: RemoteWorkerSpec, endpoint: WorkerEndpoint,
                 server, *, role: str = "rollout"):
        super().__init__(spec.name, role=role)
        self.spec = spec
        self.endpoint = endpoint
        self.server = server
        server.register_worker_sink(spec.name, self)
        self.lock = threading.Lock()
        self.incarnation = 0               # 0 = nothing launched yet
        self.restarts = 0
        self.phase = "new"                 # new|up|waiting|backoff|done
        self.relaunch_at = 0.0
        self.restart_times: List[float] = []
        self._stop_remote = False
        self._remote_error: Optional[str] = None
        self.reports_seen = 0
        self.remote_health: Dict = {}
        self.remote_services: Dict = {}

    def _thread_targets(self):
        return []                          # the Supervisor is the actor

    # -- report sink (called from a server connection thread) -----------------
    @property
    def stop_requested(self) -> bool:
        return self._stop_remote or self._stop.is_set()

    def stop_for(self, incarnation: int) -> bool:
        """Per-incarnation stop verdict for the report reply: superseded
        incarnations and exhausted slots are told to exit."""
        with self.lock:
            return (self.stop_requested or self.error is not None
                    or incarnation != self.incarnation)

    def apply_report(self, report: Dict, incarnation: int = 0) -> None:
        with self.lock:
            if incarnation != self.incarnation:
                return                     # stale incarnation — drop
            self.endpoint.note_report()
            if (self.phase == "waiting" and incarnation > 0
                    and getattr(self.endpoint, "attached_incarnation",
                                incarnation) is None):
                # the incarnation we presumed dead resumed reporting — it
                # was a stall, not a death: re-adopt it in place (the
                # restart the stall charged stays on the budget) instead
                # of stranding a live worker while the attach window
                # burns the rest of the budget
                self.endpoint.attach(incarnation)
                self.phase = "up"
            self.remote_health = report.get("health", {})
            self.remote_services = report.get("services", {})
            self.metrics.apply_remote(report.get("merged", {}))
            self.reports_seen += 1
            if not self.remote_health.get("healthy", True):
                self._remote_error = (self.remote_health.get("error")
                                      or "remote service failed")

    # -- lifecycle ------------------------------------------------------------
    def on_stop(self) -> None:
        self._stop_remote = True

    def join(self, timeout: float = 5.0) -> None:
        self.endpoint.shutdown(timeout=timeout)
        super().join(timeout=1.0)

    # -- the orchestrator's rollout-aggregation surface ------------------------
    @property
    def process(self):
        """The current incarnation's process (spawn mode; None otherwise)."""
        return getattr(self.endpoint, "process", None)

    @property
    def env_steps(self) -> int:
        return int(self.metrics.counter("env_steps"))

    @property
    def episodes_done(self) -> int:
        return int(self.metrics.counter("episodes"))

    @property
    def successes(self) -> int:
        return int(self.metrics.counter("successes"))

    @property
    def returns(self) -> List[float]:
        s = self.metrics.snapshot()["series"].get("return")
        if not s or not s["count"]:
            return []
        # the child ships a count/mean summary; expanding it preserves the
        # count-weighted global mean the orchestrator computes
        return [s["mean"]] * int(s["count"])


# ---------------------------------------------------------------------------
# the supervisor: one state machine for every non-local worker
# ---------------------------------------------------------------------------

class Supervisor(Service):
    """Owns N supervised worker slots under one :class:`RestartPolicy`.

    The single supervision thread launches each slot's endpoint, watches
    its liveness (process for spawn, heartbeat stream for connect), and on
    failure either relaunches within the restart budget (new incarnation,
    metrics folded monotonically) or marks the slot FAILED so schedulers
    fail fast — the one state machine PR 3's per-host monitor threads are
    replaced by."""

    def __init__(self, server, policy: RestartPolicy, *,
                 name: str = "supervisor", poll_s: float = 0.02):
        super().__init__(name, role="supervision")
        self.server = server
        self.policy = policy
        self.poll_s = poll_s
        self.slots: List[SupervisedWorker] = []
        server.set_hello_handler(self.handle_hello)

    # -- slot construction ----------------------------------------------------
    def add_spawned(self, spec: RemoteWorkerSpec) -> SupervisedWorker:
        """A slot whose incarnations are child processes of this host."""
        slot = SupervisedWorker(spec, SpawnedEndpoint(), self.server)
        self.slots.append(slot)
        return slot

    def add_connected(self, spec: RemoteWorkerSpec, *,
                      liveness_timeout_s: float = 0.0) -> SupervisedWorker:
        """A slot filled by a worker dialing in (``repro.launch.worker``).
        ``liveness_timeout_s`` 0 = auto: 10 heartbeats, floored at 2s."""
        timeout = liveness_timeout_s or max(10 * spec.heartbeat_s, 2.0)
        endpoint = ConnectedEndpoint(
            liveness_timeout_s=timeout,
            attach_timeout_s=spec.connect_timeout_s)
        slot = SupervisedWorker(spec, endpoint, self.server)
        self.slots.append(slot)
        return slot

    # -- the worker.hello responder (runs on a server connection thread) ------
    def handle_hello(self, header: Dict) -> Dict:
        """Assign the dialing worker a free connect slot (optionally the
        specific one it asked for) and ship its spec. The server has
        already verified the shared token."""
        want = header.get("worker")
        for slot in self.slots:
            if slot.endpoint.mode != "connect":
                continue
            if want and slot.name != want:
                continue
            assigned = self._try_attach(slot)
            if assigned is not None:
                return assigned
        detail = f" {want!r}" if want else ""
        return {"err": f"no open worker slot{detail} — every slot is "
                       f"live, failed, or stopping (redial after the "
                       f"liveness window if its worker just died)"}

    def _try_attach(self, slot: SupervisedWorker) -> Optional[Dict]:
        with slot.lock:
            endpoint = slot.endpoint
            if (slot.error is not None or slot.stop_requested
                    or slot.phase not in ("new", "waiting")):
                return None
            if endpoint.failure() is not None:
                # the attach window lapsed but the supervision thread has
                # not processed it yet — let it account for the failure
                # first so the budget stays exact
                return None
            slot.incarnation += 1
            if slot.incarnation > 1:
                slot.metrics.begin_remote_incarnation()
            slot._remote_error = None
            endpoint.attach(slot.incarnation)
            slot.phase = "up"
            spec = dataclasses.replace(slot.spec,
                                       incarnation=slot.incarnation)
            self.metrics.inc("attaches")
            return {"ok": True, "name": slot.name,
                    "incarnation": slot.incarnation,
                    "spec": spec_to_wire(spec)}

    # -- supervision state machine --------------------------------------------
    def _run(self) -> None:
        for slot in self.slots:
            with slot.lock:
                self._launch(slot)
        while not self._stop.is_set():
            now = time.monotonic()
            for slot in self.slots:
                self._step(slot, now)
            time.sleep(self.poll_s)

    def _launch(self, slot: SupervisedWorker) -> None:
        """Begin the next incarnation (caller holds ``slot.lock``)."""
        if slot.endpoint.mode == "spawn":
            slot.incarnation += 1
            if slot.incarnation > 1:
                slot.metrics.begin_remote_incarnation()
            slot._remote_error = None
            slot.endpoint.launch(dataclasses.replace(
                slot.spec, incarnation=slot.incarnation))
            slot.phase = "up"
        elif (slot.endpoint.attached_incarnation is None
              or slot.endpoint.failure() is not None):
            # connect mode: (re)open the slot; handle_hello does the
            # attach (launch drops any dead attachment)
            slot.endpoint.launch(slot.spec)
            slot.phase = "waiting"
        else:
            slot.phase = "up"      # a worker dialed in before this loop
                                   # first ran — keep the live attachment

    def _step(self, slot: SupervisedWorker, now: float) -> None:
        with slot.lock:
            if slot.error is not None or slot.phase == "done":
                return
            if slot.stop_requested:
                slot.phase = "done"
                return
            if slot.phase == "backoff":
                if (slot.endpoint.mode == "connect"
                        and slot.endpoint.attached_incarnation
                        == slot.incarnation
                        and slot.endpoint.failure() is None):
                    slot.phase = "up"      # the stalled worker's reports
                    return                 # resumed before the relaunch
                if now >= slot.relaunch_at:
                    self._launch(slot)
                return
            if slot._remote_error is not None:
                reason = (f"reported a failed service: "
                          f"{slot._remote_error}")
            else:
                reason = slot.endpoint.failure()
            if reason is None:
                return
            self._on_failure(slot, reason, now)

    def _on_failure(self, slot: SupervisedWorker, reason: str,
                    now: float) -> None:
        """Policy decision for a dead incarnation (caller holds the lock)."""
        self.metrics.inc("failures")
        slot._remote_error = None
        slot.endpoint.shutdown(timeout=0.2)   # reap a dead child quickly
        if self.policy.mode != "on_failure":
            self._fail(slot, reason)
            return
        slot.restart_times = [t for t in slot.restart_times
                              if now - t <= self.policy.window_s]
        if len(slot.restart_times) >= self.policy.max_restarts:
            self._fail(slot, f"restart budget exhausted "
                             f"({len(slot.restart_times)} restart(s) in "
                             f"{self.policy.window_s:.0f}s); last failure: "
                             f"{reason}")
            return
        slot.restart_times.append(now)
        slot.restarts += 1
        slot.metrics.inc("restarts")
        self.metrics.inc("restarts")
        delay = self.policy.backoff_s(len(slot.restart_times))
        slot.relaunch_at = now + delay
        slot.phase = "backoff"

    def _fail(self, slot: SupervisedWorker, reason: str) -> None:
        slot.phase = "done"
        slot.mark_failed(RuntimeError(
            f"remote worker {slot.name!r} {reason}"))

    def on_stop(self) -> None:
        # raise every slot's cooperative stop flag even if the registry
        # stops the supervisor first — no slot may be relaunched past here
        for slot in self.slots:
            slot._stop_remote = True
